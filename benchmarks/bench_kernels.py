"""Kernel benchmarks: interpret-mode allclose vs oracle + us/call, and the
XLA-reference path timing for context (kernels target TPU; interpret mode
measures correctness, not TPU speed)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def bench_flash_attention() -> None:
    b, s, h, kh, d = 1, 256, 4, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kh, d))
    v = jax.random.normal(ks[2], (b, s, kh, d))
    out = ops.flash_attention(q, k, v, causal=True, block_q=128, block_kv=128)
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True).transpose(0, 2, 1, 3)
    err = float(jnp.max(jnp.abs(out - want)))
    dt = timeit(lambda: ops.flash_attention(q, k, v, causal=True), iters=5)
    dt_ref = timeit(
        lambda: ref.flash_attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            causal=True), iters=5)
    emit("kernel/flash_attention_interp", dt, f"max_err={err:.2e};xla_ref_us={dt_ref * 1e6:.0f}")


def bench_ssm_scan() -> None:
    b, l, h, p, g, n = 1, 256, 4, 32, 1, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt_in = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, l, g, n))
    cm = jax.random.normal(ks[4], (b, l, g, n))
    y, _ = ops.ssm_scan(x, dt_in, a, bm, cm, chunk=64)
    yref, _ = ref.ssm_scan_ref(x, dt_in, a, jnp.repeat(bm, h, 2), jnp.repeat(cm, h, 2), chunk=64)
    err = float(jnp.max(jnp.abs(y - yref)))
    dt = timeit(lambda: ops.ssm_scan(x, dt_in, a, bm, cm, chunk=64), iters=5)
    emit("kernel/ssm_scan_interp", dt, f"max_err={err:.2e}")


def bench_mlstm_scan() -> None:
    b, l, h, p = 1, 256, 2, 32
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, l, h, p))
    k = jax.random.normal(ks[1], (b, l, h, p))
    v = jax.random.normal(ks[2], (b, l, h, p))
    il = jax.random.normal(ks[3], (b, l, h))
    fl = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, l, h)) + 3.0)
    hout, _ = ops.mlstm_scan(q, k, v, il, fl, chunk=64)
    want = ref.mlstm_scan_ref(q, k, v, il, fl)
    err = float(jnp.max(jnp.abs(hout - want)))
    dt = timeit(lambda: ops.mlstm_scan(q, k, v, il, fl, chunk=64), iters=5)
    emit("kernel/mlstm_scan_interp", dt, f"max_err={err:.2e}")


def main() -> None:
    bench_flash_attention()
    bench_ssm_scan()
    bench_mlstm_scan()


if __name__ == "__main__":
    main()
