"""Shared benchmark helpers.  Output convention (one line per measurement):

    name,us_per_call,derived
"""
from __future__ import annotations

import time
from typing import Callable


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median-ish wall time per call in seconds."""
    import jax

    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") or out is not None else None
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    try:
        jax.block_until_ready(out)
    except Exception:
        pass
    return (time.perf_counter() - t0) / iters


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)
