"""§Roofline table generator: reads results/dryrun/*.json (single-pod
cells), derives the three roofline terms + MODEL_FLOPS ratio, prints the
table as CSV and writes results/roofline.json for EXPERIMENTS.md."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit
from repro.configs import SHAPES, get_arch
from repro.envvars import read_env
from repro.evaluation.model_flops import model_flops
from repro.hwgen.roofline import roofline_from_record
from repro.hwgen.targets import TPU_V5E

DRYRUN_DIR = read_env("REPRO_DRYRUN_DIR", "results/dryrun")
N_CHIPS = 256


def build_table(dryrun_dir: str = DRYRUN_DIR):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*__single.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok" or "cost" not in rec:
            if rec.get("status") == "skipped":
                rows.append({"cell": rec.get("cell", os.path.basename(path)),
                             "status": "skipped", "reason": rec.get("reason", "")})
            continue
        arch = get_arch(rec["arch"])
        cell = SHAPES[rec["shape"]]
        spec = arch.spec(long_context=cell.long_context)
        mf_global = model_flops(spec, cell.kind, cell.batch, cell.seq)
        mf_per_chip = mf_global / N_CHIPS
        # compute-term floor: HLO flops cannot be below MODEL_FLOPS; the
        # mLSTM chunk scan's matmuls are invisible to HloCostAnalysis
        # (while body counted once), so xlstm cells would otherwise
        # under-report compute.  max() is a no-op for all other cells.
        rec = dict(rec)
        rec["cost"] = dict(rec.get("cost", {}))
        rec["cost"]["flops"] = max(float(rec["cost"].get("flops", 0.0)), mf_per_chip)
        rep = roofline_from_record(rec, chip=TPU_V5E, model_flops=mf_per_chip)
        rows.append({
            "cell": rec["cell"],
            "status": "ok",
            "kind": cell.kind,
            "n_params": rec.get("n_params"),
            "compute_s": rep.compute_s,
            "memory_s": rep.memory_s,
            "collective_s": rep.collective_s,
            "dominant": rep.dominant,
            "bound_s": rep.bound_s,
            "model_flops_per_chip": mf_per_chip,
            "hlo_flops_per_chip": rep.hlo_flops,
            "useful_ratio": rep.useful_ratio,
            "roofline_fraction": rep.roofline_fraction,
            "peak_gb": (rec.get("memory", {}).get("peak_bytes_per_device", 0)) / 2**30,
        })
    return rows


def main() -> None:
    rows = build_table()
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.json", "w") as f:
        json.dump(rows, f, indent=2)
    for r in rows:
        if r["status"] != "ok":
            emit(f"roofline/{r['cell']}", 0.0, "skipped")
            continue
        emit(
            f"roofline/{r['cell']}",
            r["bound_s"],
            f"dom={r['dominant']};comp={r['compute_s']:.3f}s;mem={r['memory_s']:.3f}s;"
            f"coll={r['collective_s']:.3f}s;frac={r['roofline_fraction']:.3f};"
            f"useful={r['useful_ratio'] if r['useful_ratio'] else 0:.3f};peak_gb={r['peak_gb']:.1f}",
        )


if __name__ == "__main__":
    main()
