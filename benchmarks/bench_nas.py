"""NAS-layer benchmarks mirroring the paper's claims:

  * sampler comparison (paper §III: Optuna-compatible optimization)
  * search-space translation + dynamic model construction throughput
    (paper §IV-C: models instantiated only after sampling)
  * estimator fidelity: analytical FLOPs/params vs XLA compiled truth
    (paper §V: cost estimators)
  * end-to-end HIL pipeline latency breakdown (paper §VI: generators)
  * pre-processing joint search benefit (paper §IV-E)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.builder import ModelBuilder
from repro.core.space import parse_search_space
from repro.core.translate import sample_architecture
from repro.data.pipeline import SyntheticClassificationData
from repro.evaluation import (
    CompiledLatencyEstimator,
    EvaluationCache,
    TrainedAccuracyEstimator,
)
from repro.search import (
    GridSampler,
    MedianPruner,
    ParallelStudy,
    RandomSampler,
    RegularizedEvolutionSampler,
    Study,
    TPESampler,
    TrialPruned,
    TrialState,
)
from repro.hwgen.generator import HardwareManager, XLAGenerator

SPACE_YAML = """
input: [4, 256]
output: 6
sequence:
  - block: "features"
    op_candidates: "conv-block"
    type_repeat:
      type: "vary_all"
      depth: [1, 2, 3, 4]
  - block: "head"
    op_candidates: "linear"
    linear:
      width: [32, 64, 128]
default_op_params:
  conv1d:
    kernel_size: [3, 5]
    out_channels: [8, 16]
    stride: [1, 2]
composites:
  conv-block:
    sequence:
      - block: "conv"
        op_candidates: "conv1d"
      - block: "pool"
        op_candidates: ["maxpool", "identity"]
"""


def bench_samplers() -> None:
    """Best objective value after N trials, per sampler (lower=better)."""
    space = parse_search_space(SPACE_YAML)
    builder = ModelBuilder(space.input_shape, space.output_dim)

    def objective(trial):
        arch = sample_architecture(space, trial)
        m = builder.build(arch)
        # synthetic hardware-cost surface: flops + param pressure
        return m.flops / 1e6 + m.n_params / 1e4

    for name, sampler in [
        ("random", RandomSampler(seed=0)),
        ("tpe", TPESampler(seed=0, n_startup=8)),
        ("evolution", RegularizedEvolutionSampler(seed=0, population=12)),
        ("grid", GridSampler(seed=0)),
    ]:
        t0 = time.perf_counter()
        study = Study(sampler=sampler)
        study.optimize(objective, 40)
        dt = (time.perf_counter() - t0) / 40
        emit(f"sampler/{name}", dt, f"best={study.best_trial.values[0]:.2f}")


def bench_builder_throughput() -> None:
    """sample+build latency (dynamic instantiation, paper §IV-C)."""
    space = parse_search_space(SPACE_YAML)
    builder = ModelBuilder(space.input_shape, space.output_dim)
    study = Study(sampler=RandomSampler(seed=1))

    def one():
        trial = study.ask()
        arch = sample_architecture(space, trial)
        return builder.build(arch)

    dt = timeit(one, warmup=3, iters=50)
    emit("builder/sample+build", dt, f"models_per_s={1 / dt:.0f}")

    dt_parse = timeit(lambda: parse_search_space(SPACE_YAML), warmup=2, iters=20)
    emit("builder/yaml_parse", dt_parse, "")


def bench_estimator_fidelity() -> None:
    """Analytical FLOPs vs XLA cost_analysis ground truth (paper §V)."""
    space = parse_search_space(SPACE_YAML)
    builder = ModelBuilder(space.input_shape, space.output_dim)
    study = Study(sampler=RandomSampler(seed=2))
    gen = XLAGenerator("host_cpu")
    rel_errs = []
    t_gen = 0.0
    n = 8
    for _ in range(n):
        arch = sample_architecture(space, study.ask())
        m = builder.build(arch)
        params = m.init(jax.random.PRNGKey(0))
        x = jnp.zeros((1, 256, 4))
        t0 = time.perf_counter()
        artifact = gen.generate(m.apply, (params, x))
        t_gen += time.perf_counter() - t0
        if artifact.flops > 0 and m.flops > 0:
            rel_errs.append(abs(artifact.flops - m.flops) / artifact.flops)
    emit("estimator/flops_vs_xla", t_gen / n,
         f"median_rel_err={np.median(rel_errs):.3f}")


def bench_hil_pipeline() -> None:
    """Generate vs benchmark latency per candidate (paper §VI mode 2)."""
    space = parse_search_space(SPACE_YAML)
    builder = ModelBuilder(space.input_shape, space.output_dim)
    study = Study(sampler=RandomSampler(seed=3))
    gen = XLAGenerator("host_cpu")
    mgr = HardwareManager(warmup=1, iters=5)
    arch = sample_architecture(space, study.ask())
    m = builder.build(arch)
    params = m.init(jax.random.PRNGKey(0))
    x = jnp.zeros((8, 256, 4))

    t0 = time.perf_counter()
    artifact = gen.generate(m.apply, (params, x))
    t_generate = time.perf_counter() - t0
    t1 = time.perf_counter()
    result = mgr.benchmark(artifact, (params, x))
    t_bench = time.perf_counter() - t1
    emit("hil/generate", t_generate, f"flops={artifact.flops:.0f}")
    emit("hil/benchmark", t_bench, f"latency_us={result['latency_s'] * 1e6:.0f}")


def bench_preprocessing_joint() -> None:
    """Joint pre-processing+arch search vs arch-only (paper §IV-E)."""
    base = SPACE_YAML
    joint = SPACE_YAML + """
preprocessing:
  normalize:
    kind: ["zscore", "minmax"]
  downsample:
    factor: [1, 2]
"""
    data = SyntheticClassificationData(n=240, length=256, channels=4, classes=6).split()
    acc_est = TrainedAccuracyEstimator(steps=30, batch=32)

    def run(yaml_text, seed):
        space = parse_search_space(yaml_text)
        builder = ModelBuilder(space.input_shape, space.output_dim)
        study = Study(sampler=RandomSampler(seed=seed), directions=("maximize",))

        def obj(trial):
            arch = sample_architecture(space, trial)
            m = builder.build(arch)
            return acc_est.estimate(m, {"data": data})

        study.optimize(obj, 6)
        return study.best_trial.values[0]

    t0 = time.perf_counter()
    acc_base = run(base, 0)
    acc_joint = run(joint, 0)
    dt = time.perf_counter() - t0
    emit("preprocess/joint_vs_base", dt / 12,
         f"acc_base={acc_base:.3f};acc_joint={acc_joint:.3f}")


PARALLEL_SPACE_YAML = """
input: [2, 128]
output: 4
sequence:
  - block: "features"
    op_candidates: "conv1d"
    type_repeat:
      type: "repeat_op"
      depth: [1, 2]
    conv1d:
      kernel_size: [3, 5]
      out_channels: [8]
  - block: "head"
    op_candidates: "linear"
    linear:
      width: [16, 32]
preprocessing:
  normalize:
    kind: ["zscore", "minmax"]
"""


PARALLEL_TRIALS, PARALLEL_SEED = 128, 5

# per-process lazy state for the picklable objective below: process-pool
# workers (spawn) re-import this module and build their own copy, sharing
# compiled values with the parent and each other through the disk cache
_WORKER_STATE = {}


class CompileBoundObjective:
    """Picklable compile-bound objective usable on every executor backend.

    Holds only strings; the heavy state (space, builder, estimator and
    its cache) is built lazily per process.  Each trial records a
    ``worker`` user-attr with the evaluating process's pid and its
    cumulative cache/compile counters, so the parent can aggregate
    "how many XLA compiles did this study really perform?" across
    processes it cannot otherwise observe.
    """

    def __init__(self, cache_dir: str | None = None, tag: str = "default"):
        self.cache_dir = cache_dir
        self.tag = tag

    def _state(self):
        key = (self.cache_dir, self.tag)
        state = _WORKER_STATE.get(key)
        if state is None:
            from repro.evaluation import EvaluationCache as _Cache

            space = parse_search_space(PARALLEL_SPACE_YAML)
            builder = ModelBuilder(space.input_shape, space.output_dim)
            cache = _Cache(disk=self.cache_dir) if self.cache_dir else _Cache()
            est = CompiledLatencyEstimator("host_cpu", batch=4, cache=cache,
                                           metric="modelled")
            state = _WORKER_STATE[key] = (space, builder, est)
        return state

    def __call__(self, trial):
        import os as _os

        from repro.hwgen.generator import generate_call_count

        space, builder, est = self._state()
        arch = sample_architecture(space, trial)
        value = est.estimate(builder.build(arch))
        trial.set_user_attr("worker", {
            "pid": _os.getpid(),
            "generates": generate_call_count(),
            **est.cache.stats.as_dict(),
        })
        return value


def _warm_worker():
    """Per-worker-process warmup: pay the jax import + XLA backend init
    before the measured region starts."""
    import os as _os

    import jax as _jax

    _jax.devices()
    return _os.getpid()


def aggregate_worker_stats(study) -> dict:
    """Sum each worker process's final cumulative counters (keyed by pid;
    counters are monotone, so the elementwise max per pid is its total)."""
    per_pid: dict = {}
    for t in study.trials:
        w = t.user_attrs.get("worker")
        if not w:
            continue
        cur = per_pid.setdefault(w["pid"], dict(w))
        for k in ("generates", "hits", "disk_hits", "misses"):
            cur[k] = max(cur[k], w[k])
    totals = {k: sum(c[k] for c in per_pid.values())
              for k in ("generates", "hits", "disk_hits", "misses")}
    lookups = totals["hits"] + totals["disk_hits"] + totals["misses"]
    totals["hit_rate"] = (totals["hits"] + totals["disk_hits"]) / lookups if lookups else 0.0
    totals["n_workers_seen"] = len(per_pid)
    return totals


def run_parallel_config(name: str, cache_dir: str | None = None) -> dict:
    """Run ONE serial/parallel configuration and return its measurements.

    Each configuration must run in a fresh process: jax/XLA keeps an
    in-process compilation cache, so any same-process rerun over the same
    architectures is several times faster and would corrupt the
    comparison (the later configuration always looks better).  The
    ``disk_*`` configurations share compiled values through the
    disk-persistent cache in ``cache_dir`` instead — pass a populated
    directory to measure a warm restart.
    """
    space = parse_search_space(PARALLEL_SPACE_YAML)
    builder = ModelBuilder(space.input_shape, space.output_dim)

    def make_objective(estimate):
        def objective(trial):
            arch = sample_architecture(space, trial)
            return estimate(builder.build(arch))
        return objective

    def cached_estimator():
        cache = EvaluationCache()
        return cache, CompiledLatencyEstimator("host_cpu", batch=4, cache=cache,
                                               metric="modelled")

    stats_cache = None  # in-process cache whose stats we report, if any
    if name == "serial":
        # baseline: serial loop, every candidate re-generated from scratch
        # (what the paper's framework and aw_nas do per trial)
        gen = XLAGenerator("host_cpu")

        def raw_estimate(m):
            import jax
            import jax.numpy as jnp

            l, c = m.input_shape[-1], m.input_shape[0]
            params = m.init(jax.random.PRNGKey(0))
            artifact = gen.generate(m.apply, (params, jnp.zeros((4, l, c), jnp.float32)))
            return float(artifact.roofline.bound_s)

        study, objective = Study(sampler=RandomSampler(seed=PARALLEL_SEED)), make_objective(raw_estimate)
        opt_kw = {}
    elif name == "serial_cached":
        stats_cache, est = cached_estimator()
        study, objective = Study(sampler=RandomSampler(seed=PARALLEL_SEED)), make_objective(est.estimate)
        opt_kw = {}
    elif name == "parallel4":
        stats_cache, est = cached_estimator()
        study = ParallelStudy(sampler=RandomSampler(seed=PARALLEL_SEED), n_workers=4)
        objective = make_objective(est.estimate)
        opt_kw = {"n_workers": 4}
    elif name == "disk_serial":
        study = Study(sampler=RandomSampler(seed=PARALLEL_SEED))
        objective = CompileBoundObjective(cache_dir, tag=name)
        opt_kw = {}
    elif name in ("disk_thread2", "disk_process2", "disk_remote2"):
        obj_cls = CompileBoundObjective
        if name == "disk_thread2":
            backend = "thread"
        elif name == "disk_remote2":
            # worker-daemon pool from REPRO_REMOTE_WORKERS (the bench
            # spawns the daemons); warmed like the process pool so the
            # measured region excludes jax import + XLA backend init
            from repro.search.remote.executor import RemoteExecutor

            backend = RemoteExecutor()
            backend.start(2)
            backend.warmup(_remote_safe("_warm_worker"))
            obj_cls = _remote_safe("CompileBoundObjective")
        else:
            # Pre-start + warm the worker processes (interpreter spawn,
            # jax import, XLA backend init) before the measured region:
            # the serial/thread configurations get those one-time costs
            # untimed too, via the parent's module imports.
            from repro.search import ProcessExecutor

            backend = ProcessExecutor()
            backend.start(2)
            backend.warmup(_warm_worker)
        study = ParallelStudy(sampler=RandomSampler(seed=PARALLEL_SEED),
                              n_workers=2, backend=backend)
        objective = obj_cls(cache_dir, tag=name)
        opt_kw = {"n_workers": 2}
    else:
        raise KeyError(name)

    t0 = time.perf_counter()
    study.optimize(objective, PARALLEL_TRIALS, **opt_kw)
    seconds = time.perf_counter() - t0
    best = study.best_trial
    out = {
        "name": name,
        "seconds": seconds,
        "hit_rate": stats_cache.stats.hit_rate if stats_cache is not None else 0.0,
        "best_number": best.number,
        "best_value": best.values[0],
    }
    if type(objective).__name__ == "CompileBoundObjective":
        # per-worker cumulative counters, aggregated across processes
        # (includes the authoritative hit_rate for these configs)
        out.update(aggregate_worker_stats(study))
    return out


def _remote_safe(name: str):
    """Resolve a module-level name via the importable ``benchmarks.bench_nas``
    path.  When this file runs as a script its globals pickle as
    ``__main__.X``, which a remote worker daemon (whose ``__main__`` is
    ``repro.worker``) cannot resolve — the twin from the real module can be."""
    import benchmarks.bench_nas as mod

    return getattr(mod, name)


def _run_config_subprocess(name: str, cache_dir: str | None = None,
                           extra_env: dict | None = None) -> dict:
    """Run one configuration in an isolated interpreter and parse its
    JSON result line (see run_parallel_config for why isolation matters)."""
    import json
    import os
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ, **(extra_env or {})}
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src"), repo] + env.get("PYTHONPATH", "").split(os.pathsep))
    cmd = [sys.executable, os.path.abspath(__file__), "--parallel-config", name]
    if cache_dir:
        cmd.append(cache_dir)
    r = subprocess.run(cmd, capture_output=True, text=True, env=env, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(f"config {name!r} failed:\n{r.stderr[-4000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def bench_parallel_engine() -> None:
    """Serial-recompile-everything vs ParallelStudy + shared EvaluationCache
    on the compiled-latency objective (the framework's hottest path).

    The space is deliberately compact so samplers revisit architectures —
    the regime where the cache matters.  metric="modelled" makes the
    objective value deterministic, so the serial and parallel runs at the
    same seed must find the same best trial.  Every configuration runs in
    its own subprocess (see run_parallel_config) so each pays its own cold
    XLA compiles.
    """
    results = {name: _run_config_subprocess(name)
               for name in ("serial", "serial_cached", "parallel4")}
    serial, cached, par = results["serial"], results["serial_cached"], results["parallel4"]
    best_match = (serial["best_number"] == par["best_number"]
                  and serial["best_value"] == par["best_value"]
                  and cached["best_value"] == par["best_value"])
    emit("parallel/serial", serial["seconds"] / PARALLEL_TRIALS,
         f"best={serial['best_value']:.3e}")
    emit("parallel/serial_cached", cached["seconds"] / PARALLEL_TRIALS,
         f"hit_rate={cached['hit_rate']:.2f}")
    emit("parallel/parallel4", par["seconds"] / PARALLEL_TRIALS,
         f"speedup_vs_serial={serial['seconds'] / par['seconds']:.2f}x;"
         f"speedup_vs_cached={cached['seconds'] / par['seconds']:.2f}x;"
         f"hit_rate={par['hit_rate']:.2f};"
         f"best_match={best_match}")


def bench_process_engine() -> None:
    """Thread vs process executor at n_workers=2 on the compile-bound
    objective, each against a cold disk store, then warm restarts over
    the populated store on all three backends.

    The process backend is the only configuration with real compile
    concurrency (each worker process owns its own XLA compiler; the
    in-process admission gate serializes sibling threads), so on a
    compile-bound objective it must be at least as fast as the thread
    backend.  A warm restart must perform ZERO XLA compiles (hit rate
    1.0) and reproduce the identical best trial on every backend.
    """
    import shutil
    import tempfile

    trials = PARALLEL_TRIALS
    dir_thread = tempfile.mkdtemp(prefix="bench-nas-cache-thread-")
    dir_process = tempfile.mkdtemp(prefix="bench-nas-cache-process-")
    try:
        cold_thread = _run_config_subprocess("disk_thread2", dir_thread)
        cold_process = _run_config_subprocess("disk_process2", dir_process)
        best_match = (cold_process["best_number"] == cold_thread["best_number"]
                      and cold_process["best_value"] == cold_thread["best_value"])
        emit("process/thread2", cold_thread["seconds"] / trials,
             f"compiles={cold_thread['generates']};hit_rate={cold_thread['hit_rate']:.2f}")
        emit("process/process2", cold_process["seconds"] / trials,
             f"speedup_vs_thread={cold_thread['seconds'] / cold_process['seconds']:.2f}x;"
             f"compiles={cold_process['generates']};"
             f"hit_rate={cold_process['hit_rate']:.2f};"
             f"best_match={best_match}")

        # warm restarts share the store the thread run populated
        for short in ("serial", "thread2", "process2"):
            r = _run_config_subprocess(f"disk_{short}", dir_thread)
            best_match = (r["best_number"] == cold_thread["best_number"]
                          and r["best_value"] == cold_thread["best_value"])
            emit(f"warm-restart/{short}", r["seconds"] / trials,
                 f"compiles={r['generates']};hit_rate={r['hit_rate']:.2f};"
                 f"best_match={best_match}")
    finally:
        shutil.rmtree(dir_thread, ignore_errors=True)
        shutil.rmtree(dir_process, ignore_errors=True)


def _spawn_worker_daemon(cache_dir: str):
    """Launch one ``python -m repro.worker`` daemon on an ephemeral port
    and return ``(proc, "host:port")`` once it prints its bound address."""
    import os
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ}
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src"), repo] + env.get("PYTHONPATH", "").split(os.pathsep))
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.worker", "--port", "0",
         "--cache-dir", cache_dir, "--no-warmup"],
        stdout=subprocess.PIPE, stderr=subprocess.DEVNULL, text=True, env=env)
    deadline = time.monotonic() + 120.0
    addr = None
    while time.monotonic() < deadline:
        line = proc.stdout.readline()
        if not line:
            break
        if line.startswith("listening on "):
            addr = line.split()[-1].strip()
            break
    if not addr:
        proc.kill()
        raise RuntimeError("worker daemon never printed its bound address")
    return proc, addr


def bench_remote_engine() -> None:
    """Remote worker daemons vs the local process pool at n_workers=2 on
    the compile-bound objective (each against its own cold disk store),
    then a kill-one-worker run over the remote pool's warm store.

    What must hold: (1) the remote run finds the identical best trial as
    the process run at the same seed — detached plans make the wire
    transparent to the search; (2) SIGKILLing one of the two daemons
    mid-run still completes every trial via bounded resubmission to the
    surviving sibling, again with the identical best trial."""
    import shutil
    import tempfile
    import threading
    import warnings as _warnings

    trials = PARALLEL_TRIALS
    dir_process = tempfile.mkdtemp(prefix="bench-nas-cache-rproc-")
    dir_remote = tempfile.mkdtemp(prefix="bench-nas-cache-remote-")
    daemons = []
    try:
        cold_process = _run_config_subprocess("disk_process2", dir_process)
        daemons = [_spawn_worker_daemon(dir_remote) for _ in range(2)]
        addrs = [a for _, a in daemons]
        cold_remote = _run_config_subprocess(
            "disk_remote2", dir_remote,
            extra_env={"REPRO_REMOTE_WORKERS": ",".join(addrs)})
        best_match = (cold_remote["best_number"] == cold_process["best_number"]
                      and cold_remote["best_value"] == cold_process["best_value"])
        if not best_match:
            raise AssertionError(
                f"remote best trial {cold_remote['best_number']} diverged from "
                f"process best {cold_process['best_number']} at the same seed")
        emit("remote/process2", cold_process["seconds"] / trials,
             f"compiles={cold_process['generates']};"
             f"hit_rate={cold_process['hit_rate']:.2f}")
        emit("remote/remote2", cold_remote["seconds"] / trials,
             f"vs_process={cold_process['seconds'] / cold_remote['seconds']:.2f}x;"
             f"compiles={cold_remote['generates']};"
             f"hit_rate={cold_remote['hit_rate']:.2f};"
             f"best_match={best_match}")

        # kill-one-worker: warm store, driven from this process so the
        # victim daemon can be SIGKILLed mid-run
        from repro.search.remote.executor import RemoteExecutor

        study = ParallelStudy(sampler=RandomSampler(seed=PARALLEL_SEED),
                              n_workers=2,
                              backend=RemoteExecutor(workers=list(addrs)),
                              schedule="sliding_window",
                              tell_order="completion")
        victim = daemons[0][0]
        # the warm-store run finishes in well under a second, so the kill
        # must land early to hit it mid-flight (killed_mid_run reports
        # whether it actually did)
        killer = threading.Timer(0.05, victim.kill)
        t0 = time.perf_counter()
        killer.start()
        with _warnings.catch_warnings():
            # the worker-lost + resubmit warning is the expected path here
            _warnings.simplefilter("ignore", RuntimeWarning)
            study.optimize(
                _remote_safe("CompileBoundObjective")(dir_remote, tag="kill"),
                trials)
        dt = time.perf_counter() - t0
        killer.cancel()
        killed_mid_run = victim.poll() is not None
        best = study.best_trial
        if (best.number != cold_remote["best_number"]
                or best.values[0] != cold_remote["best_value"]):
            raise AssertionError(
                f"kill-one-worker run diverged: best {best.number} vs "
                f"{cold_remote['best_number']} — resubmitted trials must "
                f"reproduce their original parameters")
        incomplete = [t for t in study.trials
                      if t.state not in (TrialState.COMPLETE, TrialState.PRUNED)]
        if incomplete:
            raise AssertionError(
                f"{len(incomplete)} trials did not complete after the kill")
        emit("remote/kill_one_worker", dt / trials,
             f"completed={len(study.trials)}/{trials};"
             f"killed_mid_run={killed_mid_run};best_match=True")
    finally:
        for proc, _ in daemons:
            proc.kill()
        shutil.rmtree(dir_process, ignore_errors=True)
        shutil.rmtree(dir_remote, ignore_errors=True)


def bench_explorer_facade() -> None:
    """Facade overhead: the declarative Explorer front door vs the same
    experiment hand-wired through the layered API.  Both drive identical
    analytic-estimator searches at a fixed seed, so they must find the
    identical best trial; the delta is pure composition overhead (spec
    validation, registry resolution, report assembly), which must stay
    negligible next to a single XLA compile."""
    import yaml as _yaml

    from repro import Explorer, ExperimentSpec
    from repro.evaluation import (
        CriteriaRunner,
        FlopsEstimator,
        OptimizationCriteria,
        ParamCountEstimator,
    )

    trials, seed = 40, 0

    def run_hand_wired():
        space = parse_search_space(SPACE_YAML)
        builder = ModelBuilder(space.input_shape, space.output_dim)
        runner = CriteriaRunner([
            OptimizationCriteria(FlopsEstimator(), kind="objective", weight=1.0),
            OptimizationCriteria(ParamCountEstimator(), kind="objective", weight=0.1),
        ])

        def objective(trial):
            arch = sample_architecture(space, trial)
            trial.set_user_attr("signature", arch.signature())
            return runner.evaluate(builder.build(arch), trial=trial)

        study = Study(sampler=TPESampler(seed=seed))
        study.optimize(objective, trials)
        return study.best_trial

    def run_facade():
        spec = ExperimentSpec.from_dict({
            "name": "bench-facade",
            "search_space": _yaml.safe_load(SPACE_YAML),
            "sampler": {"name": "tpe", "seed": seed},
            "executor": {"backend": "serial"},
            "criteria": [
                {"estimator": "flops", "kind": "objective", "weight": 1.0},
                {"estimator": "n_params", "kind": "objective", "weight": 0.1},
            ],
            "budget": {"n_trials": trials},
        })
        explorer = Explorer.from_spec(spec)
        report = explorer.run(save_report=False)
        return report.best

    t0 = time.perf_counter()
    hand_best = run_hand_wired()
    t_hand = time.perf_counter() - t0
    t1 = time.perf_counter()
    facade_best = run_facade()
    t_facade = time.perf_counter() - t1

    best_match = (hand_best.number == facade_best["number"]
                  and list(hand_best.values) == facade_best["values"])
    emit("explorer/hand_wired", t_hand / trials, f"best={hand_best.values[0]:.3e}")
    emit("explorer/facade", t_facade / trials,
         f"overhead_vs_hand_wired={(t_facade / t_hand - 1) * 100:+.0f}%;"
         f"best_match={best_match}")


# ---------------------------------------------------------------------------
# sweep group: one experiment fanned across targets/samplers over a
# SHARED disk cache — compile-derived values are scoped by mesh
# topology, so after the first target has paid for a candidate's
# compile, every later target with the same topology pays zero
# ---------------------------------------------------------------------------

SWEEP_SEED = 9


def bench_sweep_engine() -> None:
    """3-target x 2-sampler sweep on the compile-bound modelled-latency
    objective.  Expands through ``SweepSpec`` and runs cell by cell so
    per-target XLA compile counts are observable: the first target
    compiles every unique candidate; the second and third targets (same
    1x1 mesh topology, different chip constants) must compile ZERO —
    their modelled latencies come from the cached roofline terms.  A
    final ``run_sweep`` then resumes every completed cell from its
    persisted report (re-running nothing) and merges the SweepReport."""
    import shutil
    import tempfile

    import yaml as _yaml

    from repro.explorer.sweep import SweepSpec, run_sweep
    from repro.hwgen.generator import generate_call_count

    cache_dir = tempfile.mkdtemp(prefix="bench-nas-sweep-cache-")
    report_dir = tempfile.mkdtemp(prefix="bench-nas-sweep-report-")
    trials = 12
    try:
        spec = SweepSpec.from_dict({
            "name": "bench-sweep",
            "base": {
                "name": "bench-sweep-base",
                "search_space": _yaml.safe_load(PARALLEL_SPACE_YAML),
                "executor": {"backend": "serial"},
                "criteria": [
                    {"estimator": "latency_s", "kind": "objective",
                     "params": {"batch": 4, "metric": "modelled"}},
                    # second objective makes the cross-target Pareto
                    # union non-trivial; it shares the cached artifact
                    # with latency_s, so compile counts are unchanged
                    {"estimator": "peak_bytes", "kind": "objective",
                     "weight": 1.0e-9, "params": {"batch": 4}},
                ],
                "budget": {"n_trials": trials},
            },
            "axes": {
                "target": ["host_cpu", "edge_npu", "tpu_v5e"],
                "sampler": [{"name": "random", "seed": SWEEP_SEED},
                            {"name": "grid", "seed": SWEEP_SEED}],
            },
            "cache": cache_dir,
            "report_dir": report_dir,
        })
        from repro.explorer import Explorer

        per_target: dict = {}
        for cell in spec.expand():
            c0, t0 = generate_call_count(), time.perf_counter()
            Explorer.from_spec(cell.spec).run()
            dt = time.perf_counter() - t0
            compiles = generate_call_count() - c0
            agg = per_target.setdefault(cell.axes["target"],
                                        {"seconds": 0.0, "compiles": 0})
            agg["seconds"] += dt
            agg["compiles"] += compiles
        first = spec.axes["target"][0]
        for target, agg in per_target.items():
            note = f"compiles={agg['compiles']}"
            if target != first:
                note += (f";reuses_first_target_compiles="
                         f"{agg['compiles'] == 0}")
            emit(f"sweep/{target}", agg["seconds"] / (2 * trials), note)

        # merge pass: every cell's report is on disk, so the sweep engine
        # must resume all of them (re-running nothing) and just merge
        t0 = time.perf_counter()
        merged = run_sweep(spec)
        dt = time.perf_counter() - t0
        winners = {k: (v[0]["target"] if v else None)
                   for k, v in merged.target_rankings.items()}
        emit("sweep/merged_resume", dt,
             f"cells={merged.n_cells};resumed={merged.n_resumed};"
             f"pareto_union={len(merged.pareto_union)};"
             f"winners={winners}")
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)
        shutil.rmtree(report_dir, ignore_errors=True)


# ---------------------------------------------------------------------------
# cascade group: zero-cost screening vs flat compiled evaluation at the
# SAME trial budget and seed — the multi-fidelity cascade's whole value
# proposition is that screened-out candidates never pay an XLA compile
# ---------------------------------------------------------------------------

CASCADE_TRIALS, CASCADE_SEED, CASCADE_GENERATION = 64, 11, 16

# Deep-thin models: the regime where screening pays.  Many layers make
# the XLA compile expensive (graph-size-bound) while tiny channel counts
# keep the eager zero-cost proxy cheap (dispatch-bound, per-op kernels
# shared across the few distinct layer shapes).  The depth axis is
# bimodal on purpose: per-layer parameter sampling makes the deep
# candidates pairwise-unique (the flat baseline compiles every one of
# them), but the depth-1 low-capacity corner the synflow-minimize screen
# promotes from is small AND cheap to compile — the cascade pays a few
# small compiles where the baseline pays dozens of big ones.
CASCADE_SPACE_YAML = """
input: [4, 128]
output: 6
sequence:
  - block: "features"
    op_candidates: "conv1d"
    type_repeat:
      type: "repeat_op"
      depth: [1, 32, 48, 64]
    conv1d:
      kernel_size: [3, 5]
      out_channels: [4, 8]
      stride: [1]
  - block: "head"
    op_candidates: "linear"
    linear:
      width: [16, 32]
"""


def _cascade_spec(with_screen: bool, trials: int) -> dict:
    """Experiment dict for the cascade comparison.  Both configurations
    ask the IDENTICAL trial sequence (same sampler seed; the per-trial
    RNG streams key on the trial number, and the cascade pre-samples the
    same suggestions in-parent), so the flat run's best trial either
    survives the screen — and then the cascade must find it too — or was
    screened out, which the benchmark reports instead of hiding.  The
    synflow screen runs with ``direction: minimize`` because the final
    objective minimizes modelled latency: low-capacity candidates are
    the fast ones, so proxy rank and final rank point the same way."""
    import yaml as _yaml

    spec = {
        "name": f"bench-cascade-{'screen' if with_screen else 'flat'}",
        "search_space": _yaml.safe_load(CASCADE_SPACE_YAML),
        "sampler": {"name": "random", "seed": CASCADE_SEED},
        "executor": {"backend": "serial"},
        "criteria": [
            {"estimator": "latency_s", "kind": "objective",
             "params": {"batch": 4, "metric": "modelled"}},
        ],
        "budget": {"n_trials": trials},
    }
    if with_screen:
        spec["fidelity"] = {
            "generation": CASCADE_GENERATION,
            "stages": [
                {"name": "zero_cost",
                 "criteria": [{"estimator": "synflow", "kind": "objective",
                               "direction": "minimize"}],
                 "keep": {"top_frac": 0.25}},
            ],
        }
    return spec


def _warm_cascade_process() -> None:
    """One build + proxy + compile OUTSIDE the timed window (both
    configurations, identically): first-touch JAX backend init and the
    eager per-op kernel compiles are one-time process costs, not
    screening throughput.  Uses its own estimator instances, so nothing
    lands in the measured run's evaluation cache."""
    import yaml as _yaml

    from repro.core.builder import ModelBuilder
    from repro.core.space import parse_search_space
    from repro.core.translate import sample_architecture
    from repro.evaluation.estimators import CompiledLatencyEstimator
    from repro.evaluation.proxies import SynFlowEstimator
    from repro.search.samplers import RandomSampler
    from repro.search.study import Study

    space = parse_search_space(_yaml.safe_load(CASCADE_SPACE_YAML))
    study = Study(sampler=RandomSampler(seed=997))
    builder = ModelBuilder(space.input_shape, space.output_dim)
    syn = SynFlowEstimator()
    lat = CompiledLatencyEstimator("host_cpu", batch=4, metric="modelled")
    for _ in range(2):
        model = builder.build(sample_architecture(space, study.ask()))
        syn.estimate(model)
        lat.estimate(model)


def run_cascade_config(name: str, trials: int = CASCADE_TRIALS) -> dict:
    """Run ONE cascade configuration (fresh process — same in-process XLA
    cache reasoning as run_parallel_config) and return its measurements."""
    from repro.explorer import Explorer
    from repro.hwgen.generator import generate_call_count

    with_screen = name == "cascade"
    _warm_cascade_process()
    base_compiles = generate_call_count()
    explorer = Explorer.from_dict(_cascade_spec(with_screen, trials))
    t0 = time.perf_counter()
    report = explorer.run(save_report=False)
    seconds = time.perf_counter() - t0
    out = {
        "name": name,
        "seconds": seconds,
        "compiles": generate_call_count() - base_compiles,
        "best_number": report.best["number"],
        "best_value": report.best["values"][0],
        "states": report.states,
    }
    if with_screen:
        out["funnel"] = report.fidelity["funnel"]
        out["spearman"] = report.fidelity["spearman"]
        out["promoted_numbers"] = [
            t.number for t in explorer.study.trials
            if t.user_attrs.get("fidelity_stage") == "promoted"]
    return out


def _run_cascade_subprocess(name: str, trials: int) -> dict:
    import json
    import os
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ}
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src"), repo] + env.get("PYTHONPATH", "").split(os.pathsep))
    cmd = [sys.executable, os.path.abspath(__file__), "--cascade-config",
           name, str(trials)]
    r = subprocess.run(cmd, capture_output=True, text=True, env=env, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(f"cascade config {name!r} failed:\n{r.stderr[-4000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def bench_cascade(quick: bool = False) -> None:
    """Flat compiled evaluation vs the zero-cost -> compiled cascade at
    the same budget/seed on the compile-bound modelled-latency objective.

    What must hold: (1) candidates evaluated per unit wall-clock goes up
    by >= 4x — the screen pays milliseconds of eager proxy math to skip
    75% of the compiles, and concentrates the survivors on few unique
    (cached) architectures; (2) screened-out candidates never compile,
    so the cascade's total compile count stays <= its promoted count;
    (3) the flat run's winner, when it survives the screen, is exactly
    the cascade's winner (`best_match`)."""
    trials = 32 if quick else CASCADE_TRIALS
    flat = _run_cascade_subprocess("nocascade", trials)
    casc = _run_cascade_subprocess("cascade", trials)
    throughput = flat["seconds"] / casc["seconds"]
    funnel = casc["funnel"]
    screened_compiles_zero = casc["compiles"] <= funnel["promoted"]
    winner_survived = flat["best_number"] in casc["promoted_numbers"]
    best_match = (not winner_survived) or (
        casc["best_number"] == flat["best_number"]
        and casc["best_value"] == flat["best_value"])
    if not screened_compiles_zero:
        raise AssertionError(
            f"screened-out candidates compiled: {casc['compiles']} compiles "
            f"for {funnel['promoted']} promotions")
    if not best_match:
        raise AssertionError(
            f"flat winner {flat['best_number']} survived the screen but the "
            f"cascade best is {casc['best_number']} — fixed-seed runs must "
            f"agree when the winner is promoted")
    rho = casc["spearman"].get("zero_cost")
    emit("cascade/flat", flat["seconds"] / trials,
         f"compiles={flat['compiles']};best={flat['best_value']:.3e}")
    emit("cascade/screened", casc["seconds"] / trials,
         f"throughput_vs_flat={throughput:.2f}x;"
         f"compiles={casc['compiles']};"
         f"promoted={funnel['promoted']};screened={funnel['screened']};"
         f"screened_compiles_zero={screened_compiles_zero};"
         f"winner_survived={winner_survived};best_match={best_match};"
         f"spearman={rho if rho is None else round(rho, 2)}")


# ---------------------------------------------------------------------------
# async scheduler group: sliding window vs batch barrier on a
# latency-skewed objective (the regime hardware-in-the-loop NAS lives in)
# ---------------------------------------------------------------------------

ASYNC_SEED = 7


class LognormalSkewObjective:
    """Synthetic latency-skew objective: a deterministic lognormal
    per-trial evaluation cost (sleep, seeded by trial number — identical
    across schedulers and backends) plus an analytic quality surface, so
    fixed-seed best trials must agree between schedulers.  Lognormal
    skew models real compile+benchmark latency: most candidates are
    cheap, a heavy tail stalls whole batches behind one straggler."""

    def __init__(self, median_s: float = 0.05, sigma: float = 1.2):
        self.median_s = median_s
        self.sigma = sigma

    def __call__(self, trial):
        import math as _math
        import random as _random

        x = trial.suggest_float("x", 0.0, 1.0)
        width = trial.suggest_int("width", 16, 128, step=16)
        rng = _random.Random(f"async-cost/{trial.number}")
        time.sleep(self.median_s * _math.exp(self.sigma * rng.gauss(0.0, 1.0)))
        return (x - 0.7) ** 2 + abs(width - 64) / 640.0


PRUNE_BUDGET_STEPS = 12


def worker_prune_objective(trial):
    """Picklable stepped objective for the worker-side pruning demo:
    every fourth trial is obviously doomed (a minority, so the peer
    median stays at the good level); a worker consulting its shipped
    pruner snapshot should abandon them after a fraction of the step
    budget."""
    bad = trial.number % 4 == 3
    base = 100.0 if bad else 1.0
    steps = 0
    for step in range(PRUNE_BUDGET_STEPS):
        trial.report(step, base + 0.01 * step)
        steps += 1
        if trial.should_prune():
            trial.set_user_attr("steps_run", steps)
            raise TrialPruned()
        time.sleep(0.01)
    trial.set_user_attr("steps_run", steps)
    return base


def bench_async_scheduler(quick: bool = False) -> None:
    """Sliding-window vs batch scheduling at n_workers=4 on the
    lognormal latency-skew objective (thread backend: the objective
    sleeps, so threads are the realistic backend), plus best-trial
    parity on Random AND Grid, plus worker-side pruning on the process
    backend.  All runs share one process — the objective compiles
    nothing, so there is no warm-state bias between configurations."""
    trials = 16 if quick else 48
    median_s = 0.02 if quick else 0.05
    workers = 4

    def run(schedule, make_sampler):
        study = ParallelStudy(sampler=make_sampler(), n_workers=workers,
                              backend="thread", schedule=schedule,
                              tell_order="completion")
        t0 = time.perf_counter()
        study.optimize(LognormalSkewObjective(median_s=median_s), trials)
        return time.perf_counter() - t0, study.best_trial

    t_batch, best_batch = run("batch", lambda: RandomSampler(seed=ASYNC_SEED))
    t_slide, best_slide = run("sliding_window", lambda: RandomSampler(seed=ASYNC_SEED))
    best_match = (best_batch.number == best_slide.number
                  and best_batch.values == best_slide.values)
    emit("async/batch", t_batch / trials, f"wall_s={t_batch:.2f}")
    emit("async/sliding", t_slide / trials,
         f"speedup_vs_batch={t_batch / t_slide:.2f}x;wall_s={t_slide:.2f};"
         f"best_match={best_match}")

    gt_batch, g_batch = run("batch", lambda: GridSampler(seed=ASYNC_SEED))
    gt_slide, g_slide = run("sliding_window", lambda: GridSampler(seed=ASYNC_SEED))
    grid_match = (g_batch.number == g_slide.number
                  and g_batch.values == g_slide.values)
    emit("async/grid_parity", (gt_batch + gt_slide) / (2 * trials),
         f"speedup_vs_batch={gt_batch / gt_slide:.2f}x;best_match={grid_match}")

    # worker-side pruning: process backend + median pruner — doomed
    # trials must stop inside the worker, well short of the step budget
    n_prune = 10 if quick else 16
    study = ParallelStudy(sampler=RandomSampler(seed=ASYNC_SEED), n_workers=2,
                          backend="process", schedule="sliding_window",
                          tell_order="completion",
                          pruner=MedianPruner(n_startup_trials=2))
    t0 = time.perf_counter()
    study.optimize(worker_prune_objective, n_prune)
    dt = time.perf_counter() - t0
    pruned = [t for t in study.trials if t.state == TrialState.PRUNED]
    steps = [t.user_attrs["steps_run"] for t in pruned if "steps_run" in t.user_attrs]
    mean_steps = sum(steps) / len(steps) if steps else float("nan")
    emit("async/worker_prune", dt / n_prune,
         f"pruned={len(pruned)}/{n_prune};budget_steps={PRUNE_BUDGET_STEPS};"
         f"mean_steps_when_pruned={mean_steps:.1f}")


# ---------------------------------------------------------------------------
# kernel-tune group: Pallas block/chunk schedules as a tunable layer —
# tuned vs default wall-clock on the real kernels, plus warm-restart
# zero-re-tune and fixed-seed best-trial parity through the facade
# ---------------------------------------------------------------------------

KERNEL_TUNE_SPEC = {
    "name": "bench-kernel-tune",
    "search_space": {
        "input": [8, 256],  # l=256 divides every candidate chunk
        "output": 6,
        "sequence": [
            {"block": "mixer", "op_candidates": "ssm",
             "ssm": {"impl": ["pallas"], "d_state": [8, 16]}},
            {"block": "head", "op_candidates": "linear",
             "linear": {"width": [16, 32]}},
        ],
    },
    "sampler": {"name": "random", "seed": 3},
    "executor": {"backend": "serial"},
    "criteria": [{"estimator": "latency_s", "kind": "objective",
                  "params": {"batch": 2, "metric": "modelled"}}],
    "kernel_tuning": {"mode": "cached", "budget": 4},
    "budget": {"n_trials": 4},
}


def run_kernel_tune_config(cache_dir: str) -> dict:
    """One facade run of the kernel-tuning experiment over ``cache_dir``
    (subprocess mode: a fresh process proves warm restarts re-tune
    nothing from disk alone, with no in-process tuner state)."""
    from repro import Explorer, ExperimentSpec

    spec = ExperimentSpec.from_dict({**KERNEL_TUNE_SPEC, "cache": cache_dir})
    t0 = time.perf_counter()
    report = Explorer.from_spec(spec).run(save_report=False)
    seconds = time.perf_counter() - t0
    kt = report.kernel_tuning or {}
    best = report.best or {}
    return {
        "seconds": seconds,
        "tunes": kt.get("tunes"),
        "cache_hits": kt.get("cache_hits"),
        "schedules": kt.get("schedules"),
        "best_number": best.get("number"),
        "best_params": best.get("params"),
        "best_values": best.get("values"),
    }


def _run_kernel_tune_subprocess(cache_dir: str) -> dict:
    import json
    import os
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ}
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src"), repo] + env.get("PYTHONPATH", "").split(os.pathsep))
    cmd = [sys.executable, os.path.abspath(__file__), "--kernel-tune-config",
           cache_dir]
    r = subprocess.run(cmd, capture_output=True, text=True, env=env, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(f"kernel-tune config failed:\n{r.stderr[-4000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def bench_kernel_tune(quick: bool = False) -> None:
    """Kernel schedules as a tunable layer.  What must hold: (1) on at
    least one scan kernel the tuner's winner strictly beats the named
    ``default`` schedule's wall-clock on this host; (2) a cold facade
    run tunes and picks a non-default schedule, and a warm restart in a
    *fresh process* over the same disk cache re-tunes nothing
    (``tunes == 0``); (3) at a fixed seed the warm run's best trial is
    identical to the cold run's (``best_match``)."""
    import shutil
    import tempfile

    from repro.hwgen.autotune import ScheduleTuner, discover_kernel_calls
    from repro.hwgen.targets import get_target
    from repro.kernels import ops as kops
    from repro.kernels.schedule import default_schedule

    # (1) direct tuned-vs-default sweeps at the demo's shapes
    b, l, h, p, g, n = 2, 256, 4, 16, 1, 16
    zeros = jnp.zeros
    sweeps = {
        "ssm_scan": (lambda x, dt, a, bb, c: kops.ssm_scan(x, dt, a, bb, c)[0],
                     (zeros((b, l, h, p)), zeros((b, l, h)), zeros((h,)),
                      zeros((b, l, g, n)), zeros((b, l, g, n)))),
        "mlstm_scan": (lambda q, k, v, i, f: kops.mlstm_scan(q, k, v, i, f)[0],
                       (zeros((b, l, h, p)), zeros((b, l, h, p)),
                        zeros((b, l, h, p)), zeros((b, l, h)), zeros((b, l, h)))),
    }
    tuner = ScheduleTuner(get_target("host_cpu"), warmup=1,
                          iters=2 if quick else 3)
    strict_wins = 0
    for kernel, (fn, args) in sweeps.items():
        (entry,) = discover_kernel_calls(fn, args).values()
        record = tuner.tune(kernel, entry["shapes"], entry["meta"])
        default = default_schedule(kernel).to_dict()
        win = (record["schedule"] != default
               and record["latency_s"] < record["default_latency_s"])
        strict_wins += win
        emit(f"kernel_tune/{kernel}", record["latency_s"],
             f"schedule={record['schedule']};default={default};"
             f"speedup_vs_default="
             f"{record['default_latency_s'] / record['latency_s']:.2f}x;"
             f"candidates={record['n_candidates']};strict_win={win}")
    if not strict_wins:
        raise AssertionError(
            "no kernel's tuned schedule beat the default wall-clock — "
            "schedules are not a useful tuning dimension on this host")

    # (2) + (3) cold tune vs warm restart through the facade, separate
    # processes sharing one disk cache
    cache_dir = tempfile.mkdtemp(prefix="bench_kernel_tune_")
    try:
        cold = _run_kernel_tune_subprocess(cache_dir)
        warm = _run_kernel_tune_subprocess(cache_dir)
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)
    if not cold["tunes"]:
        raise AssertionError(f"cold run tuned nothing: {cold}")
    defaults = {k: default_schedule(k).to_dict() for k in (cold["schedules"] or {})}
    non_default = any(sched != defaults[k]
                      for k, sched in (cold["schedules"] or {}).items())
    if not non_default:
        raise AssertionError(
            f"cold run selected only default schedules: {cold['schedules']}")
    if warm["tunes"] != 0:
        raise AssertionError(
            f"warm restart re-tuned {warm['tunes']} sweeps — disk-cached "
            f"schedules must make re-tuning zero")
    best_match = (cold["best_number"] == warm["best_number"]
                  and cold["best_params"] == warm["best_params"])
    if not best_match:
        raise AssertionError(
            f"fixed-seed cold/warm best trials diverged: "
            f"{cold['best_number']} vs {warm['best_number']}")
    sched_str = "+".join(f"{k}.{f}={v}"
                         for k, s in sorted((cold["schedules"] or {}).items())
                         for f, v in sorted(s.items()))
    emit("kernel_tune/cold", cold["seconds"],
         f"tunes={cold['tunes']};schedules={sched_str}")
    emit("kernel_tune/warm", warm["seconds"],
         f"tunes=0;cache_hits={warm['cache_hits']};"
         f"speedup_vs_cold={cold['seconds'] / warm['seconds']:.2f}x;"
         f"best_match={best_match}")


# ---------------------------------------------------------------------------
# serve group: exploration -> serving hand-off through the
# content-addressed artifact store.  A warm boot (same cache dir the
# exploration populated) must perform ZERO XLA compiles; a cold boot of
# the same report against an empty store pays the compile — the delta is
# what the store is for.
# ---------------------------------------------------------------------------

SERVE_EXPERIMENT = {
    "name": "bench-serve",
    "search_space": {
        "input": [2, 64],
        "output": 3,
        "sequence": [
            {"block": "features", "op_candidates": "conv1d",
             "conv1d": {"kernel_size": [3, 5], "out_channels": [4, 8]}},
            {"block": "head", "op_candidates": "linear",
             "linear": {"width": [8, 16]}},
        ],
    },
    "sampler": {"name": "random", "seed": 7},
    "executor": {"backend": "serial"},
    "criteria": [
        {"estimator": "p99_latency_s", "kind": "objective", "weight": 1.0},
        {"estimator": "throughput_tok_s", "kind": "objective",
         "direction": "maximize", "weight": 1e-6},
    ],
    "serving": {
        "max_batch": 2, "queue_limit": 4,
        "traffic": {"seed": 3, "n_requests": 16, "arrival": "poisson",
                    "rate_rps": 50.0, "prompt_lens": [4, 8], "gen_lens": 4},
    },
}


def run_serve_explore(cache_dir: str, report_dir: str, trials: int) -> dict:
    """Subprocess mode: one exploration under serving criteria, report +
    artifact store persisted for the boot configurations to consume."""
    from repro import Explorer, ExperimentSpec
    from repro.hwgen.generator import generate_call_count

    spec = ExperimentSpec.from_dict({
        **SERVE_EXPERIMENT, "cache": cache_dir, "report_dir": report_dir,
        "budget": {"n_trials": trials},
    })
    t0 = time.perf_counter()
    report = Explorer.from_spec(spec).run()
    return {
        "seconds": time.perf_counter() - t0,
        "compiles": generate_call_count(),
        "artifacts": (report.artifacts or {}).get("entries", 0),
        "report": report.artifact,
    }


def _run_serve_boot(report_path: str) -> dict:
    """Boot ``repro.launch.serve --from-report`` in a fresh interpreter
    (compile counters are process-local) and parse its JSON summary."""
    import json
    import os
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ}
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src"), repo] + env.get("PYTHONPATH", "").split(os.pathsep))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve",
         "--from-report", report_path],
        capture_output=True, text=True, env=env, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(f"serve boot failed:\n{r.stderr[-4000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def bench_serve(quick: bool = False) -> None:
    """Exploration -> serving hand-off.  What must hold: (1) the warm
    boot — same cache dir the exploration populated — performs ZERO XLA
    compiles and serves the full declared traffic; (2) the cold boot of
    the same report over an emptied store compiles at least once; (3)
    both boots serve the identical winning signature."""
    import json
    import shutil
    import tempfile

    trials = 6 if quick else 12
    cache_dir = tempfile.mkdtemp(prefix="bench-serve-cache-")
    report_dir = tempfile.mkdtemp(prefix="bench-serve-report-")
    cold_cache = tempfile.mkdtemp(prefix="bench-serve-cold-")
    try:
        explore = _run_serve_subprocess(cache_dir, report_dir, trials)
        emit("serve/explore", explore["seconds"] / trials,
             f"compiles={explore['compiles']};artifacts={explore['artifacts']}")

        warm = _run_serve_boot(explore["report"])
        if warm["compiles"] != 0:
            raise AssertionError(
                f"warm boot performed {warm['compiles']} XLA compile(s); the "
                f"artifact store must make it zero")
        if warm["served"] != warm["traffic"]["n_requests"]:
            raise AssertionError(
                f"warm boot served {warm['served']} of "
                f"{warm['traffic']['n_requests']} requests")

        # cold boot: same report, but pointed at an empty store
        with open(explore["report"]) as f:
            report = json.load(f)
        report["spec"]["cache"]["dir"] = cold_cache
        cold_path = explore["report"] + ".cold.json"
        with open(cold_path, "w") as f:
            json.dump(report, f)
        cold = _run_serve_boot(cold_path)
        if cold["compiles"] < 1:
            raise AssertionError("cold boot compiled nothing — the warm "
                                 "measurement is not measuring the store")
        if cold["signature"] != warm["signature"]:
            raise AssertionError(
                f"boots served different programs: {cold['signature']} vs "
                f"{warm['signature']}")
        emit("serve/warm_boot", warm["boot_s"],
             f"compiles=0;served={warm['served']};shed={warm['shed']};"
             f"speedup_vs_cold={cold['boot_s'] / max(warm['boot_s'], 1e-9):.2f}x")
        emit("serve/cold_boot", cold["boot_s"],
             f"compiles={cold['compiles']};served={cold['served']}")
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)
        shutil.rmtree(report_dir, ignore_errors=True)
        shutil.rmtree(cold_cache, ignore_errors=True)


def _run_serve_subprocess(cache_dir: str, report_dir: str, trials: int) -> dict:
    import json
    import os
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ}
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src"), repo] + env.get("PYTHONPATH", "").split(os.pathsep))
    cmd = [sys.executable, os.path.abspath(__file__), "--serve-explore",
           cache_dir, report_dir, str(trials)]
    r = subprocess.run(cmd, capture_output=True, text=True, env=env, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(f"serve exploration failed:\n{r.stderr[-4000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def bench_faults(quick: bool = False) -> None:
    """Fault-injection hot-path cost.  The contract: with no plan
    installed, every ``fault_point`` call is one global load + ``is
    None`` test — storage and transport seams pay nothing for being
    injectable.  Armed cost (a plan whose rules all target *other*
    sites) bounds the rule-scan overhead chaos runs actually pay."""
    import tempfile

    from repro import faults
    from repro.evaluation.disk_cache import DiskEvaluationCache
    from repro.faults import FaultPlan

    n = 20_000 if quick else 200_000
    line = '{"kind": "trial", "number": 7}\n'

    faults.uninstall()
    t0 = time.perf_counter()
    for _ in range(n):
        faults.fault_point("study.persist", line)
    off = (time.perf_counter() - t0) / n
    emit("faults/point_disabled", off, f"n={n}")

    faults.install(FaultPlan.from_string(
        "compile:delay@p=0.01;transport.send:drop@p=0.01"))
    t0 = time.perf_counter()
    for _ in range(n):
        faults.fault_point("study.persist", line)
    armed = (time.perf_counter() - t0) / n
    faults.uninstall()
    emit("faults/point_armed_other_sites", armed,
         f"x{armed / max(off, 1e-12):.1f} vs disabled")

    # the seam in situ: disk-cache store+lookup throughput, plan off
    rounds = 200 if quick else 1000
    with tempfile.TemporaryDirectory() as d:
        cache = DiskEvaluationCache(path=d)
        t0 = time.perf_counter()
        for i in range(rounds):
            cache.store(("bench", i), {"v": i})
            cache.lookup(("bench", i))
        dt = (time.perf_counter() - t0) / rounds
    emit("faults/disk_cache_roundtrip_off", dt, f"rounds={rounds}")


def main() -> None:
    bench_samplers()
    bench_builder_throughput()
    bench_estimator_fidelity()
    bench_hil_pipeline()
    bench_preprocessing_joint()
    bench_explorer_facade()
    bench_sweep_engine()
    bench_cascade()
    bench_async_scheduler()
    bench_kernel_tune()
    bench_serve()
    bench_faults()
    bench_parallel_engine()
    bench_process_engine()
    bench_remote_engine()


if __name__ == "__main__":
    import sys

    if len(sys.argv) in (3, 4) and sys.argv[1] == "--parallel-config":
        # subprocess mode for bench_parallel_engine / bench_process_engine:
        # emit one JSON line (optional third arg: disk-cache store dir)
        import json

        print(json.dumps(run_parallel_config(
            sys.argv[2], sys.argv[3] if len(sys.argv) == 4 else None)))
    elif len(sys.argv) == 4 and sys.argv[1] == "--cascade-config":
        # subprocess mode for bench_cascade: emit one JSON line
        import json

        print(json.dumps(run_cascade_config(sys.argv[2], int(sys.argv[3]))))
    elif len(sys.argv) == 3 and sys.argv[1] == "--kernel-tune-config":
        # subprocess mode for bench_kernel_tune: emit one JSON line
        import json

        print(json.dumps(run_kernel_tune_config(sys.argv[2])))
    elif len(sys.argv) == 5 and sys.argv[1] == "--serve-explore":
        # subprocess mode for bench_serve: emit one JSON line
        import json

        print(json.dumps(run_serve_explore(sys.argv[2], sys.argv[3],
                                           int(sys.argv[4]))))
    elif "--quick" in sys.argv[1:]:
        # CI mode: the scheduler + cascade + kernel-tune + serve groups,
        # small sizes, so scheduler, screening, schedule-tuning, and
        # serving-hand-off regressions surface in every PR log
        bench_async_scheduler(quick=True)
        bench_cascade(quick=True)
        bench_kernel_tune(quick=True)
        bench_serve(quick=True)
        bench_faults(quick=True)
    else:
        main()
