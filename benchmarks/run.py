"""Benchmark harness: one section per paper claim.  Prints
``name,us_per_call,derived`` CSV lines (see benchmarks/common.py).

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run nas kernels roofline
"""
from __future__ import annotations

import sys


def main() -> None:
    sections = sys.argv[1:] or ["nas", "kernels", "roofline"]
    print("name,us_per_call,derived")
    if "nas" in sections:
        from benchmarks import bench_nas

        bench_nas.main()
    if "kernels" in sections:
        from benchmarks import bench_kernels

        bench_kernels.main()
    if "roofline" in sections:
        from benchmarks import bench_roofline

        bench_roofline.main()


if __name__ == "__main__":
    main()
