"""Hardware-in-the-loop NAS over LM backbones for a TPU-pod target.

This is the paper's §VI mode-2 workflow scaled to the assigned
architectures: the search space ranges over pod-scale LM *backbone*
dimensions (block kind, depth, width, experts), every candidate is
compiled for the production mesh by the XLA generator, and the
roofline-modelled step latency + per-device memory feed back into the
study as cost criteria (a hard HBM constraint + latency objective).

Needs spoofed devices for the 256-chip target:

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=256 \
        python examples/hw_in_loop_nas_lm.py --trials 8

(without the flag it falls back to the host_cpu target with measured
wall-clock latency on a reduced shape.)
"""
import argparse
import functools
import json

import jax
import jax.numpy as jnp

from repro.distributed.sharding import default_rules, shapes_shardings_from_axes
from repro.hwgen.generator import XLAGenerator
from repro.models.lm import LM
from repro.models.specs import LayerSpec, ModelSpec, SubBlock, moe_layer, transformer_layer
from repro.nn.ssm import Mamba2Config
from repro.nn.types import split
from repro.search import Study, TPESampler
from repro.search.study import HardConstraintViolated


def sample_spec(trial) -> ModelSpec:
    d_model = trial.suggest_categorical("d_model", [1024, 2048, 4096])
    n_layers = trial.suggest_categorical("n_layers", [8, 16, 24])
    kind = trial.suggest_categorical("block_kind", ["dense", "moe", "mamba2"])
    heads = d_model // 128
    if kind == "dense":
        ff_mult = trial.suggest_categorical("ff_mult", [3, 4])
        layer = transformer_layer(d_model, heads, max(heads // 2, 1), ff_mult * d_model)
    elif kind == "moe":
        experts = trial.suggest_categorical("experts", [8, 16])
        layer = moe_layer(d_model, heads, max(heads // 2, 1), 2 * d_model,
                          n_experts=experts, top_k=2)
    else:
        layer = LayerSpec(subs=(SubBlock("mamba2", Mamba2Config(d_model)),))
    return ModelSpec(name=f"nas-{kind}", d_model=d_model, vocab=32000,
                     layers=(layer,) * n_layers, positional="none" if kind == "mamba2" else "rope")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--trials", type=int, default=8)
    p.add_argument("--seq", type=int, default=2048)
    p.add_argument("--batch", type=int, default=32)
    args = p.parse_args()

    n_dev = len(jax.devices())
    target = "tpu_v5e_pod" if n_dev >= 256 else "host_cpu"
    if target == "host_cpu":
        args.seq, args.batch = 128, 2
        print("NOTE: <256 devices; using host_cpu target with measured latency")
    gen = XLAGenerator(target)

    def objective(trial):
        spec = sample_spec(trial)
        model = LM(spec)
        annotated = jax.eval_shape(
            functools.partial(model.init, dtype=jnp.bfloat16), jax.random.PRNGKey(0))
        param_sds, axes = split(annotated)
        tokens = jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32)
        if target == "host_cpu":
            # concrete small run, measured
            params, _ = split(model.init(jax.random.PRNGKey(0), dtype=jnp.float32))
            artifact = gen.generate(model.apply, (params, jnp.zeros((args.batch, args.seq), jnp.int32)))
        else:
            from repro.launch.mesh import make_mesh

            mesh = make_mesh(gen.target.mesh_shape, gen.target.mesh_axes)
            rules = default_rules(mesh)
            param_sh = shapes_shardings_from_axes(param_sds, axes, mesh, rules)
            tok_sh = shapes_shardings_from_axes(
                {"t": tokens}, {"t": ("batch", None)}, mesh, rules)["t"]
            artifact = gen.generate(
                lambda p, t: model.apply(p, t), (param_sds, tokens),
                in_shardings=(param_sh, tok_sh))
        peak = artifact.memory.get("peak_bytes_per_device", 0)
        trial.set_user_attr("peak_gb", peak / 2**30)
        trial.set_user_attr("latency_ms", artifact.roofline.bound_s * 1e3)
        trial.set_user_attr("dominant", artifact.roofline.dominant)
        if peak > gen.target.chip.hbm_bytes:
            raise HardConstraintViolated("peak_bytes", peak, gen.target.chip.hbm_bytes)
        # objective: modelled (or measured) step latency per token
        return artifact.roofline.bound_s / (args.batch * args.seq)

    study = Study(name="hil-lm", sampler=TPESampler(seed=0, n_startup=4))
    study.optimize(objective, args.trials)
    best = study.best_trial
    if best is None:
        print("no feasible candidate found")
        return
    print(json.dumps({
        "best_params": best.params,
        "latency_ms": best.user_attrs["latency_ms"],
        "peak_gb": best.user_attrs["peak_gb"],
        "dominant_term": best.user_attrs["dominant"],
    }, indent=2))


if __name__ == "__main__":
    main()
