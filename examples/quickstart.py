"""Quickstart: one YAML experiment -> Explorer.run() -> report + best model.

    PYTHONPATH=src python examples/quickstart.py

The declarative front door (paper's unified interface): the experiment
file names the search space, sampler, criteria, and budget; the Explorer
composes the layered API (parse_search_space + ModelBuilder + estimators
+ CriteriaRunner + ParallelStudy + executor) that earlier revisions of
this script wired by hand.  The hand-wired path still works — see
``hand_wired()`` below, which the facade reproduces trial-for-trial at
the same seed (asserted in tests/test_explorer.py).
"""
import os

import jax
import jax.numpy as jnp

from repro import Explorer

EXPERIMENT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "experiments", "quickstart.yaml")


def hand_wired():
    """The same experiment through the layered API — kept as the
    reference wiring the facade is sugar over."""
    import yaml

    from repro.core.builder import ModelBuilder
    from repro.core.space import parse_search_space
    from repro.core.translate import sample_architecture
    from repro.evaluation import FlopsEstimator, ParamCountEstimator
    from repro.search import Study, TPESampler

    with open(EXPERIMENT) as f:
        raw = yaml.safe_load(f)
    space = parse_search_space(raw["search_space"])
    builder = ModelBuilder(space.input_shape, space.output_dim)
    flops, nparams = FlopsEstimator(), ParamCountEstimator()

    def objective(trial):
        arch = sample_architecture(space, trial)
        model = builder.build(arch)
        trial.set_user_attr("signature", arch.signature())
        # minimize FLOPs subject to an (implicit) param budget via weighted sum
        return flops.estimate(model) + 0.1 * nparams.estimate(model)

    study = Study(name="quickstart", sampler=TPESampler(seed=0))
    study.optimize(objective, raw["budget"]["n_trials"])
    return study


def main():
    explorer = Explorer.from_yaml(EXPERIMENT)
    report = explorer.run()

    best = report.best
    print(f"best score {best['values'][0]:,.0f} — {best['signature']}")
    print(f"per-criterion: {report.criteria_values}")
    print(f"report artifact: {report.artifact}")

    # rebuild + run the winning architecture
    model = explorer.best_model()
    params = model.init(jax.random.PRNGKey(0))
    y = model.apply(params, jnp.ones((2, 256, 3)))
    print("output:", y.shape, "| params:", f"{model.n_params:,}")
    print(model.summary())


if __name__ == "__main__":
    main()
