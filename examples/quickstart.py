"""Quickstart: declarative search space -> NAS -> best model in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.builder import ModelBuilder
from repro.core.space import parse_search_space
from repro.core.translate import sample_architecture
from repro.evaluation import FlopsEstimator, ParamCountEstimator
from repro.search import Study, TPESampler

SPACE = parse_search_space("""
input: [3, 256]
output: 4
sequence:
  - block: "features"
    op_candidates: "conv1d"
    type_repeat:
      type: "repeat_op"
      depth: [1, 2, 3]
  - block: "head"
    op_candidates: "linear"
    linear:
      width: [16, 32, 64]
default_op_params:
  conv1d:
    kernel_size: [3, 5]
    out_channels: [8, 16, 32]
    stride: [1, 2]
""")

builder = ModelBuilder(SPACE.input_shape, SPACE.output_dim)
flops, nparams = FlopsEstimator(), ParamCountEstimator()


def objective(trial):
    arch = sample_architecture(SPACE, trial)
    model = builder.build(arch)
    trial.set_user_attr("signature", arch.signature())
    # minimize FLOPs subject to an (implicit) param budget via weighted sum
    return flops.estimate(model) + 0.1 * nparams.estimate(model)


def main():
    study = Study(name="quickstart", sampler=TPESampler(seed=0))
    study.optimize(objective, 25)
    best = study.best_trial
    print(f"best score {best.values[0]:,.0f} — {best.user_attrs['signature']}")

    # rebuild + run the winning architecture
    arch = sample_architecture(SPACE, best)
    model = builder.build(arch)
    params = model.init(jax.random.PRNGKey(0))
    y = model.apply(params, jnp.ones((2, 256, 3)))
    print("output:", y.shape, "| params:", f"{model.n_params:,}")
    print(model.summary())


if __name__ == "__main__":
    main()
