"""Elastic restart demo: train -> checkpoint -> "lose" devices -> resume
on a different mesh with resharded state.

On a real pod this is the failure path: a host dies, the job restarts
with fewer chips, `elastic_remesh` builds the largest viable mesh and the
checkpoint restores onto it (the Checkpointer stores host arrays;
device_put reshards).  On this 1-device container the two meshes are
(1,1) -> (1,1), but the code path — save under mesh A, restore under an
independently constructed mesh B with new NamedShardings — is identical.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import os
import shutil

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import SyntheticLMData
from repro.distributed.fault import StragglerMonitor, elastic_remesh
from repro.distributed.sharding import default_rules, shapes_shardings_from_axes
from repro.models.lm import LM
from repro.models.specs import ModelSpec, transformer_layer
from repro.nn.types import split
from repro.train.optimizer import Optimizer, OptimizerConfig
from repro.train.step import make_train_step

CKPT = "results/elastic_demo_ckpt"


def build():
    spec = ModelSpec(name="elastic-demo", d_model=64, vocab=512,
                     layers=(transformer_layer(64, 4, 2, 128),) * 2, remat=False)
    model = LM(spec)
    annotated = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    params, axes = split(annotated)
    opt = Optimizer(OptimizerConfig(name="adamw", learning_rate=1e-3))
    return spec, model, params, axes, opt


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    spec, model, params, axes, opt = build()
    data = SyntheticLMData(spec.vocab, seq=32, global_batch=4)
    ckpt = Checkpointer(CKPT, keep=2)

    # ---- phase 1: train on mesh A ----------------------------------------
    mesh_a = elastic_remesh((16, 16), ("data", "model"))
    rules = default_rules(mesh_a)
    sh_a = shapes_shardings_from_axes(params, axes, mesh_a, rules)
    params = jax.device_put(params, sh_a)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    monitor = StragglerMonitor()
    import time

    with mesh_a:
        for i in range(10):
            t0 = time.time()
            params, opt_state, metrics = step(params, opt_state, data.batch_at(i))
            monitor.record(time.time() - t0)
    ckpt.save(10, {"params": params, "opt": opt_state})
    print(f"phase 1 (mesh {dict(zip(mesh_a.axis_names, mesh_a.devices.shape))}): "
          f"loss {float(metrics['loss']):.4f}, checkpoint at step 10")

    # ---- phase 2: "restart" with a re-built mesh + resharded restore ------
    spec, model, params_like, axes, opt = build()  # fresh process state
    mesh_b = elastic_remesh((16, 8), ("data", "model"))  # degraded topology
    rules_b = default_rules(mesh_b)
    sh_b = shapes_shardings_from_axes(params_like, axes, mesh_b, rules_b)
    from jax.sharding import NamedSharding, PartitionSpec

    rep_b = NamedSharding(mesh_b, PartitionSpec())
    step_idx, restored = ckpt.restore(
        like={"params": params_like, "opt": opt.init(params_like)},
        shardings={"params": sh_b, "opt": {"step": rep_b, "mu": sh_b, "nu": sh_b}},
    )
    params, opt_state = restored["params"], restored["opt"]
    step = jax.jit(make_train_step(model, opt))
    with mesh_b:
        for i in range(step_idx, step_idx + 10):
            params, opt_state, metrics = step(params, opt_state, data.batch_at(i))
    print(f"phase 2 resumed at step {step_idx} on mesh "
          f"{dict(zip(mesh_b.axis_names, mesh_b.devices.shape))}: "
          f"loss {float(metrics['loss']):.4f} after 10 more steps")
    print("elastic restart OK")


if __name__ == "__main__":
    main()
