"""Paper-faithful end-to-end NAS (Listing 3 of the paper): 1-D conv
classifier over a sensor stream, with the pre-processing design space
searched jointly, staged criteria (hard param budget -> accuracy objective
+ hardware-in-the-loop latency soft constraint), TPE sampler + ASHA
pruning, and final deployment through the generator pipeline.

    PYTHONPATH=src python examples/nas_conv1d.py --trials 15
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core.builder import ModelBuilder
from repro.core.space import parse_search_space
from repro.core.translate import sample_architecture
from repro.data.pipeline import SyntheticClassificationData
from repro.evaluation import (
    CompiledLatencyEstimator,
    CriteriaRunner,
    OptimizationCriteria,
    ParamCountEstimator,
    TrainedAccuracyEstimator,
)
from repro.hwgen.generator import HardwareManager, XLAGenerator
from repro.search import Study, SuccessiveHalvingPruner, TPESampler

# Listing 3, with the paper's pre-processing space (§IV-E) attached.
SPACE_YAML = """
input: [4, 1250]
output: 6
sequence:
  - block: "features"
    op_candidates: "conv-block"
    type_repeat:
      type: "vary_all"
      depth: [1, 2, 3, 4, 5, 6]
  - block: "head"
    op_candidates: "linear"
    linear:
      width: [32, 64, 128]
default_op_params:
  conv1d:
    kernel_size: [3, 5]
    out_channels: [8, 16]
composites:
  conv-block:
    sequence:
      - block: "conv"
        op_candidates: "conv1d"
      - block: "pool"
        op_candidates: ["maxpool", "identity"]
preprocessing:
  normalize:
    kind: ["zscore", "minmax"]
  downsample:
    factor: [1, 2]
"""


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--trials", type=int, default=12)
    p.add_argument("--train-steps", type=int, default=40)
    args = p.parse_args()

    space = parse_search_space(SPACE_YAML)
    # reflection (paper §VI): only ops the deployment backend supports
    generator = XLAGenerator("host_cpu")
    allowed = generator.supported_ops()
    builder = ModelBuilder(space.input_shape, space.output_dim)
    data = SyntheticClassificationData(n=480, length=1250, channels=4, classes=6).split()

    runner = CriteriaRunner([
        OptimizationCriteria(ParamCountEstimator(), kind="hard_constraint", limit=2e6),
        OptimizationCriteria(TrainedAccuracyEstimator(steps=args.train_steps),
                             kind="objective", direction="maximize", weight=1.0),
        OptimizationCriteria(CompiledLatencyEstimator("host_cpu", batch=8),
                             kind="soft_constraint", limit=0.050, weight=0.5),
    ])

    def objective(trial):
        arch = sample_architecture(space, trial, allowed_ops=allowed)
        model = builder.build(arch)
        trial.set_user_attr("signature", arch.signature())
        return runner.evaluate(model, context={"data": data, "trial": trial}, trial=trial)

    study = Study(
        name="nas-conv1d",
        sampler=TPESampler(seed=0, n_startup=5),
        pruner=SuccessiveHalvingPruner(min_resource=20, reduction_factor=2),
        storage="results/nas_conv1d_study.jsonl",
    )
    study.optimize(objective, args.trials)

    best = study.best_trial
    print(f"\nbest trial #{best.number}: score={best.values[0]:.4f} "
          f"acc={best.user_attrs.get('val_accuracy'):.3f} "
          f"latency={best.user_attrs.get('latency_s', float('nan')) * 1e3:.2f} ms")
    print("arch:", best.user_attrs["signature"])

    # paper §VI mode 1: deploy the winner through the generator pipeline
    arch = sample_architecture(space, best)
    model = builder.build(arch)
    params = model.init(jax.random.PRNGKey(0))
    x = jnp.zeros((8, 1250, 4))
    artifact = generator.generate(model.apply, (params, x))
    bench = HardwareManager().benchmark(artifact, (params, x))
    print(f"deployed artifact: measured latency {bench['latency_s'] * 1e3:.2f} ms, "
          f"flops={artifact.flops:,.0f}, fits_memory={artifact.fits_memory}")


if __name__ == "__main__":
    main()
