"""End-to-end LM pretraining driver on the framework substrate.

Default runs a CPU-sized model for a quick demo; ``--full`` selects the
~100M-parameter configuration (the assignment's end-to-end driver) —
identical code path, bigger numbers:

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --full --steps 300   # ~100M
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import Prefetcher, SyntheticLMData
from repro.models.lm import LM
from repro.models.specs import ModelSpec, transformer_layer
from repro.nn.types import param_count, split
from repro.train.optimizer import Optimizer, OptimizerConfig, cosine_schedule
from repro.train.step import make_train_step


def model_spec(full: bool) -> ModelSpec:
    if full:  # ~100M params
        d, layers, ff, vocab, heads = 640, 10, 2560, 32000, 10
    else:  # CPU demo (~11M)
        d, layers, ff, vocab, heads = 192, 4, 768, 8192, 6
    return ModelSpec(
        name="lm-100m" if full else "lm-demo",
        d_model=d, vocab=vocab,
        layers=(transformer_layer(d, heads, max(heads // 2, 1), ff, qk_norm=True),) * layers,
        tie_embeddings=True, remat=False,
    )


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--full", action="store_true")
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--ckpt-dir", default="results/train_lm_ckpt")
    args = p.parse_args()

    spec = model_spec(args.full)
    model = LM(spec)
    params, _ = split(model.init(jax.random.PRNGKey(0), dtype=jnp.float32))
    print(f"model {spec.name}: {param_count(params):,} params")

    opt = Optimizer(OptimizerConfig(
        name="adamw",
        learning_rate=cosine_schedule(3e-3, warmup=args.steps // 10, total=args.steps),
        weight_decay=0.01,
    ))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))

    data = SyntheticLMData(spec.vocab, args.seq, args.batch)
    ckpt = Checkpointer(args.ckpt_dir, keep=2)
    start = ckpt.latest_step() or 0
    if start:
        start, restored = ckpt.restore(like={"params": params, "opt": opt_state})
        params, opt_state = restored["params"], restored["opt"]
        print(f"resumed from step {start}")
    prefetch = Prefetcher(data, start_step=start)

    t0 = time.time()
    tokens_seen = 0
    for _ in range(start, args.steps):
        i, batch = prefetch.next()
        params, opt_state, metrics = step(params, opt_state, batch)
        tokens_seen += args.batch * args.seq
        if (i + 1) % 25 == 0:
            dt = time.time() - t0
            print(f"step {i + 1:4d}  loss {float(metrics['loss']):.4f}  "
                  f"{tokens_seen / max(dt, 1e-9):,.0f} tok/s")
        if (i + 1) % 100 == 0:
            ckpt.save_async(i + 1, {"params": params, "opt": opt_state})
    ckpt.wait()
    prefetch.close()
    print(f"done: final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
