"""Parallel, cache-aware hardware-in-the-loop NAS — via the Explorer facade.

The serial trial loop is the framework's hottest path: every candidate
pays an XLA generate + benchmark, and samplers revisit architectures
constantly.  This example runs the same staged-criteria search twice —
serial, then on a parallel executor backend — by building two
:class:`ExperimentSpec`s that differ only in their ``executor:`` block,
and compares the resulting :class:`ExplorationReport`s:

  * the facade composes ``ParallelStudy`` + the executor + one shared
    ``EvaluationCache`` from the spec, so the latency and memory
    estimators compile each distinct candidate once — across all workers;
  * with ``--cache-dir`` the scalar values also persist to a disk store,
    so a re-run (or the process workers, which each build their own
    in-memory cache) compiles each architecture at most once per host;
  * at a fixed seed both runs must find the identical best trial
    (per-trial sampler RNG streams, tell-in-trial-order) — asserted.

    PYTHONPATH=src python examples/nas_parallel.py --trials 24 --workers 4
    PYTHONPATH=src python examples/nas_parallel.py --backend process \\
        --trials 12 --workers 2 --cache-dir results/cache

The equivalent hand-wired wiring (space/builder/runner/study built
explicitly) lives in benchmarks/bench_nas.py; the layered API remains
fully available underneath the facade.
"""
import argparse

import yaml

from repro import Explorer, ExperimentSpec

SPACE_YAML = """
input: [4, 256]
output: 6
sequence:
  - block: "features"
    op_candidates: "conv-block"
    type_repeat:
      type: "vary_all"
      depth: [1, 2, 3]
  - block: "head"
    op_candidates: "linear"
    linear:
      width: [32, 64]
default_op_params:
  conv1d:
    kernel_size: [3, 5]
    out_channels: [8, 16]
composites:
  conv-block:
    sequence:
      - block: "conv"
        op_candidates: "conv1d"
      - block: "pool"
        op_candidates: ["maxpool", "identity"]
preprocessing:
  normalize:
    kind: ["zscore", "minmax"]
"""

# compact variant for smoke runs (CI exercises the process backend on it)
TINY_SPACE_YAML = """
input: [2, 128]
output: 4
sequence:
  - block: "features"
    op_candidates: "conv1d"
    conv1d:
      kernel_size: [3, 5]
      out_channels: [8]
  - block: "head"
    op_candidates: "linear"
    linear:
      width: [16, 32]
"""


def make_spec(args, tag: str, backend: str, n_workers: int,
              n_trials: int = None, seed: int = None) -> ExperimentSpec:
    """One declarative experiment; serial and parallel runs differ only
    in the ``executor`` block (and their name/report artifact)."""
    return ExperimentSpec.from_dict({
        "name": f"nas-parallel-{tag}",
        "search_space": yaml.safe_load(TINY_SPACE_YAML if args.tiny else SPACE_YAML),
        "sampler": {"name": "random", "seed": args.seed if seed is None else seed},
        "executor": {"backend": backend, "n_workers": n_workers},
        # sliding_window streams tells as evaluations finish (no batch
        # barrier); with the random sampler "auto" picks it anyway — the
        # flag exists so --schedule batch can reproduce the old behavior
        "schedule": {"mode": args.schedule, "tell_order": "completion"},
        # hard memory budget -> latency objective; the shared cache means
        # the two compiled estimators generate ONE artifact per candidate
        "criteria": [
            {"estimator": "n_params", "kind": "hard_constraint", "limit": 1e6},
            {"estimator": "peak_bytes", "kind": "soft_constraint",
             "limit": 64e6, "weight": 0.1, "params": {"batch": 8}},
            {"estimator": "latency_s", "kind": "objective",
             "params": {"batch": 8, "metric": "modelled"}},
        ],
        "target": "host_cpu",
        "cache": {"dir": args.cache_dir},
        "budget": {"n_trials": args.trials if n_trials is None else n_trials},
        "report_dir": "results",
    })


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--trials", type=int, default=24)
    p.add_argument("--workers", type=int, default=4)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--backend", choices=("serial", "thread", "process"), default="thread",
                   help="executor backend for the parallel run")
    p.add_argument("--cache-dir", default=None,
                   help="disk-persistent value store (e.g. results/cache); "
                        "re-runs and process workers then skip every compile "
                        "the host already paid for")
    p.add_argument("--schedule", choices=("auto", "batch", "sliding_window"),
                   default="auto",
                   help="trial scheduler: sliding_window streams asks/tells "
                        "as slots free; batch re-creates the legacy barrier")
    p.add_argument("--tiny", action="store_true",
                   help="use the compact smoke-test search space")
    args = p.parse_args()
    if args.trials < 1:
        raise SystemExit("--trials must be >= 1")

    # untimed warmup so the serial run doesn't absorb jax's one-time
    # tracing/backend-init cost and skew the speedup
    Explorer.from_spec(make_spec(args, "warmup", "serial", 1,
                                 n_trials=1, seed=999)).run(save_report=False)

    serial = Explorer.from_spec(make_spec(args, "serial", "serial", 1)).run()
    par = Explorer.from_spec(
        make_spec(args, args.backend, args.backend, args.workers)).run()

    print(f"\nserial:   {serial.n_trials} trials in {serial.wall_clock_s:.1f}s "
          f"({serial.n_trials / serial.wall_clock_s:.2f} trials/s, "
          f"cache {serial.cache})")
    print(f"{args.backend}: {par.n_trials} trials in {par.wall_clock_s:.1f}s "
          f"({par.n_trials / par.wall_clock_s:.2f} trials/s, cache {par.cache})")
    caveat = (
        "cache-assisted: both runs share the persistent store, so this measures "
        "disk-cache reuse, not the executor backend"
        if args.cache_dir else
        "same-process runs share jax's warm caches — see benchmarks/bench_nas.py "
        "parallel/ and process/ for isolated measurements"
    )
    print(f"speedup: {serial.wall_clock_s / par.wall_clock_s:.2f}x with "
          f"{args.workers} {args.backend} workers ({caveat})")

    bs, bp = serial.best, par.best
    print(f"\nserial best        #{bs['number']}: score={bs['values'][0]:.3e}")
    print(f"{args.backend} best #{bp['number']}: score={bp['values'][0]:.3e}")
    assert bs["values"] == bp["values"], "fixed seed + modelled latency must reproduce"
    print("arch:", bp["signature"])
    print("reports:", serial.artifact, "+", par.artifact)


if __name__ == "__main__":
    main()
