"""Parallel, cache-aware hardware-in-the-loop NAS.

The serial trial loop is the framework's hottest path: every candidate
pays an XLA generate + benchmark, and samplers revisit architectures
constantly.  This example runs the same staged-criteria search as
``nas_conv1d.py`` through the parallel evaluation engine:

  * ``ParallelStudy`` overlaps objective evaluations on a thread pool
    while keeping results reproducible (per-trial sampler RNG streams,
    tell-in-trial-order);
  * one shared ``EvaluationCache`` memoizes compiled artifacts and
    estimator values by the candidate's full signature (layers AND
    pre-processing), so the latency and memory estimators compile each
    distinct candidate once — across all workers.

    PYTHONPATH=src python examples/nas_parallel.py --trials 24 --workers 4
"""
import argparse
import time

from repro.core.builder import ModelBuilder
from repro.core.space import parse_search_space
from repro.core.translate import sample_architecture
from repro.evaluation import (
    CompiledLatencyEstimator,
    CompiledMemoryEstimator,
    CriteriaRunner,
    EvaluationCache,
    OptimizationCriteria,
    ParamCountEstimator,
)
from repro.search import ParallelStudy, RandomSampler, Study

SPACE_YAML = """
input: [4, 256]
output: 6
sequence:
  - block: "features"
    op_candidates: "conv-block"
    type_repeat:
      type: "vary_all"
      depth: [1, 2, 3]
  - block: "head"
    op_candidates: "linear"
    linear:
      width: [32, 64]
default_op_params:
  conv1d:
    kernel_size: [3, 5]
    out_channels: [8, 16]
composites:
  conv-block:
    sequence:
      - block: "conv"
        op_candidates: "conv1d"
      - block: "pool"
        op_candidates: ["maxpool", "identity"]
preprocessing:
  normalize:
    kind: ["zscore", "minmax"]
"""


def build_runner(cache: EvaluationCache) -> CriteriaRunner:
    # hard memory budget -> latency objective; the shared cache means the
    # two compiled estimators generate ONE artifact per candidate
    return CriteriaRunner([
        OptimizationCriteria(ParamCountEstimator(), kind="hard_constraint", limit=1e6),
        OptimizationCriteria(CompiledMemoryEstimator("host_cpu", batch=8),
                             kind="soft_constraint", limit=64e6, weight=0.1),
        OptimizationCriteria(CompiledLatencyEstimator("host_cpu", batch=8, metric="modelled"),
                             kind="objective", direction="minimize"),
    ], cache=cache)


def run(study, space, runner, trials, **opt_kw):
    builder = ModelBuilder(space.input_shape, space.output_dim)

    def objective(trial):
        arch = sample_architecture(space, trial)
        model = builder.build(arch)
        trial.set_user_attr("signature", arch.signature())
        return runner.evaluate(model, trial=trial)

    t0 = time.perf_counter()
    study.optimize(objective, trials, **opt_kw)
    return time.perf_counter() - t0


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--trials", type=int, default=24)
    p.add_argument("--workers", type=int, default=4)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    space = parse_search_space(SPACE_YAML)
    if args.trials < 1:
        raise SystemExit("--trials must be >= 1")

    # untimed warmup so the serial run doesn't absorb jax's one-time
    # tracing/backend-init cost and skew the speedup
    run(Study(sampler=RandomSampler(seed=999)), space,
        build_runner(EvaluationCache()), 1)

    serial_cache = EvaluationCache()
    serial = Study(sampler=RandomSampler(seed=args.seed))
    t_serial = run(serial, space, build_runner(serial_cache), args.trials)

    par_cache = EvaluationCache()
    par = ParallelStudy(sampler=RandomSampler(seed=args.seed), n_workers=args.workers)
    t_par = run(par, space, build_runner(par_cache), args.trials, n_workers=args.workers)

    print(f"\nserial:   {args.trials} trials in {t_serial:.1f}s "
          f"({args.trials / t_serial:.2f} trials/s, cache {serial_cache.stats.as_dict()})")
    print(f"parallel: {args.trials} trials in {t_par:.1f}s "
          f"({args.trials / t_par:.2f} trials/s, cache {par_cache.stats.as_dict()})")
    print(f"speedup: {t_serial / t_par:.2f}x with {args.workers} workers "
          "(same-process runs share jax's warm caches — see "
          "benchmarks/bench_nas.py parallel/ for isolated measurements)")

    bs, bp = serial.best_trial, par.best_trial
    print(f"\nserial best   #{bs.number}: score={bs.values[0]:.3e}")
    print(f"parallel best #{bp.number}: score={bp.values[0]:.3e}")
    assert bs.values == bp.values, "fixed seed + modelled latency must reproduce"
    print("arch:", bp.user_attrs["signature"])


if __name__ == "__main__":
    main()
