"""Parallel, cache-aware hardware-in-the-loop NAS.

The serial trial loop is the framework's hottest path: every candidate
pays an XLA generate + benchmark, and samplers revisit architectures
constantly.  This example runs the same staged-criteria search as
``nas_conv1d.py`` through the parallel evaluation engine:

  * ``ParallelStudy`` overlaps objective evaluations on a pluggable
    executor backend — ``thread`` (pool in-process) or ``process``
    (worker processes, real compile concurrency) — while keeping results
    reproducible (per-trial sampler RNG streams, tell-in-trial-order);
  * one shared ``EvaluationCache`` memoizes compiled artifacts and
    estimator values by the candidate's full signature (layers AND
    pre-processing), so the latency and memory estimators compile each
    distinct candidate once — across all workers;
  * with ``--cache-dir`` the scalar values also persist to a disk store,
    so a re-run (or the process workers, which each build their own
    in-memory cache) compiles each architecture at most once per host.

    PYTHONPATH=src python examples/nas_parallel.py --trials 24 --workers 4
    PYTHONPATH=src python examples/nas_parallel.py --backend process \\
        --trials 12 --workers 2 --cache-dir results/cache
"""
import argparse
import time

from repro.core.builder import ModelBuilder
from repro.core.space import parse_search_space
from repro.core.translate import sample_architecture
from repro.evaluation import (
    CompiledLatencyEstimator,
    CompiledMemoryEstimator,
    CriteriaRunner,
    EvaluationCache,
    OptimizationCriteria,
    ParamCountEstimator,
)
from repro.search import ParallelStudy, RandomSampler, Study

SPACE_YAML = """
input: [4, 256]
output: 6
sequence:
  - block: "features"
    op_candidates: "conv-block"
    type_repeat:
      type: "vary_all"
      depth: [1, 2, 3]
  - block: "head"
    op_candidates: "linear"
    linear:
      width: [32, 64]
default_op_params:
  conv1d:
    kernel_size: [3, 5]
    out_channels: [8, 16]
composites:
  conv-block:
    sequence:
      - block: "conv"
        op_candidates: "conv1d"
      - block: "pool"
        op_candidates: ["maxpool", "identity"]
preprocessing:
  normalize:
    kind: ["zscore", "minmax"]
"""

# compact variant for smoke runs (CI exercises the process backend on it)
TINY_SPACE_YAML = """
input: [2, 128]
output: 4
sequence:
  - block: "features"
    op_candidates: "conv1d"
    conv1d:
      kernel_size: [3, 5]
      out_channels: [8]
  - block: "head"
    op_candidates: "linear"
    linear:
      width: [16, 32]
"""


def build_runner(cache: EvaluationCache) -> CriteriaRunner:
    # hard memory budget -> latency objective; the shared cache means the
    # two compiled estimators generate ONE artifact per candidate
    return CriteriaRunner([
        OptimizationCriteria(ParamCountEstimator(), kind="hard_constraint", limit=1e6),
        OptimizationCriteria(CompiledMemoryEstimator("host_cpu", batch=8),
                             kind="soft_constraint", limit=64e6, weight=0.1),
        OptimizationCriteria(CompiledLatencyEstimator("host_cpu", batch=8, metric="modelled"),
                             kind="objective", direction="minimize"),
    ], cache=cache)


# Per-process lazy state keyed by (space, cache_dir, tag): the objective
# below holds only strings, so it pickles across the process boundary;
# each process-pool worker re-imports this module and builds its own
# space/builder/runner, sharing compiled values via the disk store.
_STATE = {}


class NASObjective:
    def __init__(self, space_yaml: str, cache_dir=None, tag: str = "shared"):
        self.space_yaml = space_yaml
        self.cache_dir = cache_dir
        self.tag = tag

    def _setup(self):
        key = (self.space_yaml, self.cache_dir, self.tag)
        state = _STATE.get(key)
        if state is None:
            space = parse_search_space(self.space_yaml)
            builder = ModelBuilder(space.input_shape, space.output_dim)
            cache = EvaluationCache(disk=self.cache_dir) if self.cache_dir else EvaluationCache()
            state = _STATE[key] = (space, builder, build_runner(cache), cache)
        return state

    @property
    def cache(self) -> EvaluationCache:
        return self._setup()[3]

    def __call__(self, trial):
        space, builder, runner, _ = self._setup()
        arch = sample_architecture(space, trial)
        model = builder.build(arch)
        trial.set_user_attr("signature", arch.signature())
        return runner.evaluate(model, trial=trial)


def run(study, objective, trials, **opt_kw) -> float:
    t0 = time.perf_counter()
    study.optimize(objective, trials, **opt_kw)
    return time.perf_counter() - t0


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--trials", type=int, default=24)
    p.add_argument("--workers", type=int, default=4)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--backend", choices=("serial", "thread", "process"), default="thread",
                   help="executor backend for the parallel study")
    p.add_argument("--cache-dir", default=None,
                   help="disk-persistent value store (e.g. results/cache); "
                        "re-runs and process workers then skip every compile "
                        "the host already paid for")
    p.add_argument("--tiny", action="store_true",
                   help="use the compact smoke-test search space")
    args = p.parse_args()
    if args.trials < 1:
        raise SystemExit("--trials must be >= 1")
    space_yaml = TINY_SPACE_YAML if args.tiny else SPACE_YAML

    # untimed warmup so the serial run doesn't absorb jax's one-time
    # tracing/backend-init cost and skew the speedup
    run(Study(sampler=RandomSampler(seed=999)),
        NASObjective(space_yaml, tag="warmup"), 1)

    serial_obj = NASObjective(space_yaml, args.cache_dir, tag="serial")
    serial = Study(sampler=RandomSampler(seed=args.seed))
    t_serial = run(serial, serial_obj, args.trials)

    par_obj = NASObjective(space_yaml, args.cache_dir, tag="parallel")
    par = ParallelStudy(sampler=RandomSampler(seed=args.seed),
                        n_workers=args.workers, backend=args.backend)
    t_par = run(par, par_obj, args.trials, n_workers=args.workers)

    print(f"\nserial:   {args.trials} trials in {t_serial:.1f}s "
          f"({args.trials / t_serial:.2f} trials/s, cache {serial_obj.cache.stats.as_dict()})")
    print(f"{args.backend}: {args.trials} trials in {t_par:.1f}s "
          f"({args.trials / t_par:.2f} trials/s, parent cache {par_obj.cache.stats.as_dict()})")
    caveat = (
        "cache-assisted: both runs share the persistent store, so this measures "
        "disk-cache reuse, not the executor backend"
        if args.cache_dir else
        "same-process runs share jax's warm caches — see benchmarks/bench_nas.py "
        "parallel/ and process/ for isolated measurements"
    )
    print(f"speedup: {t_serial / t_par:.2f}x with {args.workers} {args.backend} workers "
          f"({caveat})")

    bs, bp = serial.best_trial, par.best_trial
    print(f"\nserial best        #{bs.number}: score={bs.values[0]:.3e}")
    print(f"{args.backend} best #{bp.number}: score={bp.values[0]:.3e}")
    assert bs.values == bp.values, "fixed seed + modelled latency must reproduce"
    print("arch:", bp.user_attrs["signature"])


if __name__ == "__main__":
    main()
