#!/usr/bin/env python
"""Verify every relative markdown link in README.md and docs/ resolves.

    python scripts/check_links.py

External (http/https/mailto) links are skipped — CI must not flake on
the network; what this guards is the internal docs graph: a renamed
file, a moved section, a typo'd path.  Anchors (``file.md#section``)
are checked against the target file's headings.
"""
from __future__ import annotations

import glob
import os
import re
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _headings(path: str) -> set:
    anchors = set()
    with open(path) as f:
        for line in f:
            m = re.match(r"#+\s+(.*)", line)
            if m:
                text = re.sub(r"[`*]", "", m.group(1)).strip().lower()
                anchors.add(re.sub(r"[^a-z0-9\- ]", "", text).replace(" ", "-"))
    return anchors


def check_file(md_path: str) -> list:
    errors = []
    base = os.path.dirname(md_path)
    with open(md_path) as f:
        text = f.read()
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            if target.startswith("#"):
                if target[1:] not in _headings(md_path):
                    errors.append(f"{md_path}: broken anchor {target!r}")
            continue
        path, _, anchor = target.partition("#")
        resolved = os.path.normpath(os.path.join(base, path))
        if not os.path.exists(resolved):
            errors.append(f"{md_path}: broken link {target!r} "
                          f"(no such file: {os.path.relpath(resolved, REPO_ROOT)})")
        elif anchor and resolved.endswith(".md") and anchor not in _headings(resolved):
            errors.append(f"{md_path}: broken anchor {target!r}")
    return errors


def main() -> int:
    files = [os.path.join(REPO_ROOT, "README.md")] + sorted(
        glob.glob(os.path.join(REPO_ROOT, "docs", "**", "*.md"), recursive=True))
    errors = []
    for path in files:
        errors.extend(check_file(path))
    for e in errors:
        print(e, file=sys.stderr)
    if not errors:
        print(f"all internal links resolve ({len(files)} files)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
