"""Dev smoke: tiny versions of each family, forward + decode parity."""
import jax
import jax.numpy as jnp

from repro.models.lm import LM
from repro.models.specs import (LayerSpec, ModelSpec, SubBlock, moe_layer,
                                transformer_layer)
from repro.nn.moe import MoEConfig
from repro.nn.ssm import Mamba2Config
from repro.nn.xlstm import MLSTMConfig, SLSTMConfig
from repro.nn.types import split, param_count

key = jax.random.PRNGKey(0)


def check(name, spec, decode=True):
    model = LM(spec)
    annotated = model.init(key, jnp.float32)
    params, axes = split(annotated)
    tokens = jax.random.randint(key, (2, 16), 0, spec.vocab)
    logits = model.apply(params, tokens)
    assert logits.shape == (2, 16, spec.vocab), logits.shape
    assert jnp.isfinite(logits).all(), f"{name}: non-finite logits"
    print(f"{name}: fwd ok, params={param_count(params):,}")
    if decode:
        cache = model.init_cache(params, 2, 32)
        lg, cache = model.decode(params, tokens[:, :1], cache, 0) if False else model.decode(params, cache, tokens[:, :1], 0)
        assert lg.shape == (2, 1, spec.vocab)
        assert jnp.isfinite(lg).all(), f"{name}: non-finite decode"
        print(f"{name}: decode ok")


d = 64
dense = ModelSpec(
    name="tiny-dense", d_model=d, vocab=128,
    layers=(transformer_layer(d, 4, 2, 128, qk_norm=True),) * 3,
)
check("dense", dense)

moe = ModelSpec(
    name="tiny-moe", d_model=d, vocab=128,
    layers=(moe_layer(d, 4, 2, 96, n_experts=4, top_k=2, dense_residual=True),) * 2,
)
check("moe", moe)

mamba = ModelSpec(
    name="tiny-mamba", d_model=d, vocab=128,
    layers=(LayerSpec(subs=(SubBlock("mamba2", Mamba2Config(d, d_state=16, d_head=16, chunk=8)),)),) * 2,
    positional="none",
)
check("mamba", mamba)

xl = ModelSpec(
    name="tiny-xlstm", d_model=d, vocab=128,
    layers=(
        LayerSpec(subs=(SubBlock("mlstm", MLSTMConfig(d, n_heads=2, chunk=8)),)),
        LayerSpec(subs=(SubBlock("slstm", SLSTMConfig(d, n_heads=2)),)),
    ),
    positional="none",
)
check("xlstm", xl)

# hybrid with a shared attention block
shared_attn = LayerSpec(
    subs=transformer_layer(d, 4, 4, 128).subs, shared=True
)
hyb_layers = []
for i in range(4):
    hyb_layers.append(LayerSpec(subs=(SubBlock("mamba2", Mamba2Config(d, d_state=16, d_head=16, chunk=8)),)))
    if i % 2 == 1:
        hyb_layers.append(shared_attn)
hybrid = ModelSpec(name="tiny-hybrid", d_model=d, vocab=128, layers=tuple(hyb_layers), positional="none")
check("hybrid", hybrid)

# enc-dec (whisper-like)
from repro.nn.attention import AttentionConfig
from repro.nn.mlp import MLPConfig

enc_layer = LayerSpec(subs=(
    SubBlock("attention", AttentionConfig(d, 4, 4, causal=False, rope=False)),
    SubBlock("mlp", MLPConfig(d, 128, activation="gelu", gated=False, use_bias=True)),
))
dec_layer = LayerSpec(subs=(
    SubBlock("attention", AttentionConfig(d, 4, 4, causal=True, rope=False)),
    SubBlock("cross_attention", AttentionConfig(d, 4, 4, causal=False, rope=False)),
    SubBlock("mlp", MLPConfig(d, 128, activation="gelu", gated=False, use_bias=True)),
))
encdec = ModelSpec(
    name="tiny-encdec", d_model=d, vocab=128,
    layers=(dec_layer,) * 2, encoder_layers=(enc_layer,) * 2,
    norm="layernorm", positional="learned", max_position=64,
)
model = LM(encdec)
annotated = model.init(key, jnp.float32)
params, axes = split(annotated)
frames = jax.random.normal(key, (2, 12, d))
enc_out = model.encode(params, frames)
tokens = jax.random.randint(key, (2, 16), 0, 128)
logits = model.apply(params, tokens, enc_out=enc_out)
assert logits.shape == (2, 16, 128)
assert jnp.isfinite(logits).all()
cache = model.init_cache(params, 2, 32, enc_out=enc_out)
lg, cache = model.decode(params, cache, tokens[:, :1], 0)
assert lg.shape == (2, 1, 128) and jnp.isfinite(lg).all()
print("encdec: fwd+decode ok")

# vlm-style prefix embeddings
pg = ModelSpec(name="tiny-vlm", d_model=d, vocab=128,
               layers=(transformer_layer(d, 4, 1, 128),) * 2, num_prefix_tokens=4)
model = LM(pg)
params, axes = split(model.init(key, jnp.float32))
tokens = jax.random.randint(key, (2, 16), 0, 128)
pe = jax.random.normal(key, (2, 4, d))
logits = model.apply(params, tokens, prefix_embeds=pe)
assert logits.shape == (2, 16, 128) and jnp.isfinite(logits).all()
print("vlm: fwd ok")

print("ALL DEV SMOKE PASSED")
