#!/usr/bin/env python
"""Write (or verify) the generated reference docs.

    PYTHONPATH=src python scripts/gen_docs.py           # regenerate in place
    PYTHONPATH=src python scripts/gen_docs.py --check   # fail on drift (CI)

The content comes from :mod:`repro.explorer.docgen`, which walks the
spec dataclasses' validation metadata, the component registries, and the
``repro.envvars.ENV_VARS`` registry — see that module for why generation
beats hand-maintenance.  ``--check`` renders into memory and diffs
against the committed files, so CI fails any PR that changes the YAML
surface, a registry, or an env knob without regenerating.
"""
from __future__ import annotations

import argparse
import difflib
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO_ROOT, "src"))

from repro.explorer.docgen import generated_files  # noqa: E402


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--check", action="store_true",
                   help="verify the committed files match the generated "
                        "output instead of writing (exit 1 on drift)")
    args = p.parse_args(argv)

    drifted = []
    for rel_path, content in generated_files().items():
        path = os.path.join(REPO_ROOT, rel_path)
        if args.check:
            try:
                with open(path) as f:
                    committed = f.read()
            except OSError:
                committed = ""
            if committed != content:
                drifted.append(rel_path)
                diff = difflib.unified_diff(
                    committed.splitlines(keepends=True),
                    content.splitlines(keepends=True),
                    fromfile=f"{rel_path} (committed)",
                    tofile=f"{rel_path} (generated)")
                sys.stderr.writelines(diff)
        else:
            os.makedirs(os.path.dirname(path), exist_ok=True)
            with open(path, "w") as f:
                f.write(content)
            print(f"wrote {rel_path}")

    if drifted:
        print(f"\nreference docs drifted from the code: {drifted}\n"
              f"regenerate with: PYTHONPATH=src python scripts/gen_docs.py",
              file=sys.stderr)
        return 1
    if args.check:
        print(f"docs in sync ({len(generated_files())} files)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
