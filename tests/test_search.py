"""Search substrate: samplers, pruners, storage, Pareto."""
import math
import os

import pytest

from repro.search import (
    GridSampler,
    MedianPruner,
    NSGA2Sampler,
    RandomSampler,
    RegularizedEvolutionSampler,
    Study,
    SuccessiveHalvingPruner,
    TPESampler,
    TrialPruned,
    TrialState,
)


def quadratic(trial):
    x = trial.suggest_float("x", -4.0, 4.0)
    y = trial.suggest_float("y", -4.0, 4.0)
    return (x - 1.0) ** 2 + (y + 0.5) ** 2


def test_random_sampler_minimizes_eventually():
    study = Study(sampler=RandomSampler(seed=0))
    study.optimize(quadratic, 60)
    assert study.best_trial.values[0] < 1.5


def test_tpe_beats_random_on_quadratic():
    r = Study(sampler=RandomSampler(seed=1))
    r.optimize(quadratic, 80)
    t = Study(sampler=TPESampler(seed=1, n_startup=10))
    t.optimize(quadratic, 80)
    assert t.best_trial.values[0] <= r.best_trial.values[0] * 1.5


def test_evolution_improves_over_startup():
    study = Study(sampler=RegularizedEvolutionSampler(seed=2, population=10))
    study.optimize(quadratic, 80)
    first10 = min(t.values[0] for t in study.completed_trials[:10])
    assert study.best_trial.values[0] <= first10


def test_grid_sampler_covers_grid():
    study = Study(sampler=GridSampler())

    seen = set()

    def obj(trial):
        a = trial.suggest_categorical("a", ["x", "y"])
        b = trial.suggest_int("b", 0, 2)
        seen.add((a, b))
        return 0.0

    study.optimize(obj, 6)
    assert len(seen) == 6  # full 2x3 cartesian product


def test_categorical_suggestion_consistency():
    study = Study(sampler=RandomSampler(seed=0))
    trial = study.ask()
    v1 = trial.suggest_categorical("c", [1, 2, 3])
    v2 = trial.suggest_categorical("c", [1, 2, 3])
    assert v1 == v2  # same name -> same value within a trial


def test_median_pruner_prunes_bad_trial():
    study = Study(sampler=RandomSampler(seed=0), pruner=MedianPruner(n_startup_trials=2))
    # seed two good completed trials with intermediate histories
    for _ in range(2):
        t = study.ask()
        for s in (1, 2, 3):
            t.report(s, 0.1 * s)
        study.tell(t, 0.3)
    bad = study.ask()
    bad.report(1, 100.0)
    assert bad.should_prune()


def test_asha_pruner_promotes_top_fraction():
    study = Study(sampler=RandomSampler(seed=0),
                  pruner=SuccessiveHalvingPruner(min_resource=1, reduction_factor=2))
    values = [1.0, 2.0, 3.0, 4.0]
    for v in values:
        t = study.ask()
        t.report(1, v)
        study.tell(t, v)
    worst = study.ask()
    worst.report(1, 10.0)
    assert worst.should_prune()
    best = study.ask()
    best.report(1, 0.5)
    assert not best.should_prune()


def test_study_storage_resume(tmp_path):
    path = os.path.join(tmp_path, "study.jsonl")
    s1 = Study(sampler=RandomSampler(seed=0), storage=path)
    s1.optimize(quadratic, 10)
    best1 = s1.best_trial.values[0]
    s2 = Study(sampler=RandomSampler(seed=1), storage=path)
    assert len(s2.trials) == 10
    assert s2.best_trial.values[0] == best1
    s2.optimize(quadratic, 5)
    assert len(s2.trials) == 15


def test_pruned_trials_recorded():
    study = Study(sampler=RandomSampler(seed=0))

    def obj(trial):
        trial.suggest_float("x", 0, 1)
        raise TrialPruned()

    study.optimize(obj, 3)
    assert all(t.state == TrialState.PRUNED for t in study.trials)
    assert study.best_trial is None


def test_multiobjective_pareto_front():
    study = Study(sampler=RandomSampler(seed=0), directions=("minimize", "minimize"))

    def obj(trial):
        x = trial.suggest_float("x", 0.0, 1.0)
        return x, 1.0 - x  # every point is Pareto-optimal

    study.optimize(obj, 12)
    assert len(study.best_trials) == 12

    study2 = Study(sampler=RandomSampler(seed=0), directions=("minimize", "minimize"))

    def obj2(trial):
        x = trial.suggest_float("x", 0.0, 1.0)
        return x, x  # totally ordered: single non-dominated point

    study2.optimize(obj2, 12)
    assert len(study2.best_trials) == 1


def test_nsga2_runs_multiobjective():
    study = Study(sampler=NSGA2Sampler(seed=0, population=8),
                  directions=("minimize", "minimize"))

    def obj(trial):
        x = trial.suggest_float("x", -2.0, 2.0)
        return x ** 2, (x - 1.0) ** 2

    study.optimize(obj, 40)
    front = study.best_trials
    assert front
    xs = [t.params["x"] for t in front]
    assert all(-0.5 <= x <= 1.5 for x in xs)  # front lies between optima


def test_int_log_suggestion_bounds():
    study = Study(sampler=RandomSampler(seed=0))
    for _ in range(20):
        t = study.ask()
        v = t.suggest_int("n", 1, 1024, log=True)
        assert 1 <= v <= 1024
        study.tell(t, 0.0)
