"""Explorer facade: registries, declarative ExperimentSpec, and the
end-to-end run() contract (hand-wired parity at a fixed seed, report
fields, JSON artifact)."""
import json
import os

import pytest
import yaml

from repro import Explorer, ExperimentSpec
from repro.core.builder import ModelBuilder
from repro.core.space import parse_search_space
from repro.core.translate import sample_architecture
from repro.evaluation import (
    CriteriaRunner,
    Estimator,
    FlopsEstimator,
    OptimizationCriteria,
    ParamCountEstimator,
)
from repro.explorer.experiment import ExperimentError
from repro.explorer.registry import (
    ESTIMATORS,
    SAMPLERS,
    ExplorerError,
    UnknownComponentError,
    register,
)
from repro.search import Study, TPESampler

# the tiny conv1d space: 2 blocks, a handful of distributions — fast to
# sample, fast to build, no compilation needed for analytic criteria.
# Shared with the cross-backend parity matrix so every parity check in
# the suite runs the same spec.
from test_parity_matrix import CANONICAL_SPACE as TINY_SPACE

BASE_EXPERIMENT = {
    "name": "tiny",
    "search_space": TINY_SPACE,
    "sampler": {"name": "tpe", "seed": 0},
    "executor": {"backend": "serial"},
    "criteria": [
        {"estimator": "flops", "kind": "objective", "weight": 1.0},
        {"estimator": "n_params", "kind": "objective", "weight": 0.1},
    ],
    "budget": {"n_trials": 8},
}


def make_experiment(tmp_path, **overrides):
    raw = {**{k: (dict(v) if isinstance(v, dict) else v)
              for k, v in BASE_EXPERIMENT.items()},
           "report_dir": str(tmp_path / "results")}
    raw["criteria"] = [dict(c) for c in BASE_EXPERIMENT["criteria"]]
    raw.update(overrides)
    return raw


def hand_wired_study(n_trials=8, seed=0):
    space = parse_search_space(dict(TINY_SPACE))
    builder = ModelBuilder(space.input_shape, space.output_dim)
    flops, nparams = FlopsEstimator(), ParamCountEstimator()

    def objective(trial):
        arch = sample_architecture(space, trial)
        model = builder.build(arch)
        return flops.estimate(model) + 0.1 * nparams.estimate(model)

    study = Study(sampler=TPESampler(seed=seed))
    study.optimize(objective, n_trials)
    return study


# ---------------------------------------------------------------------------
# spec parsing + validation
# ---------------------------------------------------------------------------

def test_yaml_spec_round_trip(tmp_path):
    path = tmp_path / "exp.yaml"
    path.write_text(yaml.safe_dump(make_experiment(tmp_path)))
    spec = ExperimentSpec.from_yaml(str(path))
    d = spec.to_dict()
    spec2 = ExperimentSpec.from_dict(d)
    assert spec2.to_dict() == d  # stable fixpoint
    assert spec2.name == "tiny"
    assert spec2.sampler.name == "tpe" and spec2.sampler.options == {"seed": 0}
    assert spec2.executor.backend == "serial" and spec2.executor.n_workers == 1
    assert [c.estimator for c in spec2.criteria] == ["flops", "n_params"]
    assert spec2.budget.n_trials == 8
    assert json.dumps(d)  # fully JSON-able (picklable across process workers)


def test_search_space_file_ref_resolves_relative_to_experiment(tmp_path):
    (tmp_path / "spaces").mkdir()
    (tmp_path / "spaces" / "tiny.yaml").write_text(yaml.safe_dump(TINY_SPACE))
    raw = make_experiment(tmp_path, search_space={"file": "spaces/tiny.yaml"})
    path = tmp_path / "exp.yaml"
    path.write_text(yaml.safe_dump(raw))
    spec = ExperimentSpec.from_yaml(str(path))
    # the file ref comes back inlined: the spec is self-contained
    assert spec.search_space["input"] == [2, 64]
    assert spec.to_dict()["search_space"]["output"] == 3


def test_unknown_top_level_key_names_key_and_alternatives(tmp_path):
    raw = make_experiment(tmp_path)
    raw["sampler_seed"] = 3
    with pytest.raises(ExperimentError) as e:
        ExperimentSpec.from_dict(raw)
    assert "sampler_seed" in str(e.value)
    assert "'sampler'" in str(e.value)  # allowed keys are listed


def test_unknown_sampler_lists_registered_names(tmp_path):
    raw = make_experiment(tmp_path, sampler={"name": "anneal"})
    with pytest.raises(UnknownComponentError) as e:
        ExperimentSpec.from_dict(raw)
    msg = str(e.value)
    assert "anneal" in msg and "tpe" in msg and "random" in msg


def test_unknown_estimator_and_backend_list_alternatives(tmp_path):
    raw = make_experiment(tmp_path)
    raw["criteria"][0]["estimator"] = "flopz"
    with pytest.raises(UnknownComponentError, match="flopz.*flops"):
        ExperimentSpec.from_dict(raw)
    raw = make_experiment(tmp_path, executor={"backend": "ray"})
    with pytest.raises(UnknownComponentError, match="ray.*process"):
        ExperimentSpec.from_dict(raw)


def test_bad_component_kwarg_fails_at_parse_time(tmp_path):
    raw = make_experiment(tmp_path, sampler={"name": "tpe", "sed": 0})
    with pytest.raises(ExperimentError, match="sed"):
        ExperimentSpec.from_dict(raw)
    raw = make_experiment(tmp_path)
    raw["criteria"][0]["params"] = {"batchsize": 4}
    with pytest.raises(ExperimentError, match="batchsize"):
        ExperimentSpec.from_dict(raw)


def test_spec_requires_objective_and_rejects_duplicates(tmp_path):
    raw = make_experiment(tmp_path, criteria=[
        {"estimator": "n_params", "kind": "hard_constraint", "limit": 1e6}])
    with pytest.raises(ExperimentError, match="objective"):
        ExperimentSpec.from_dict(raw)
    raw = make_experiment(tmp_path, criteria=[
        {"estimator": "flops", "kind": "objective"},
        {"estimator": "flops", "kind": "objective", "weight": 0.5}])
    with pytest.raises(ExperimentError, match="flops"):
        ExperimentSpec.from_dict(raw)


def test_constraint_requires_limit_and_bad_kind_rejected(tmp_path):
    raw = make_experiment(tmp_path)
    raw["criteria"].append({"estimator": "activation_bytes", "kind": "soft_constraint"})
    with pytest.raises(ExperimentError, match="limit"):
        ExperimentSpec.from_dict(raw)
    raw = make_experiment(tmp_path)
    raw["criteria"][0]["kind"] = "goal"
    with pytest.raises(ExperimentError, match="goal"):
        ExperimentSpec.from_dict(raw)


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------

def test_registry_plugin_registration_and_use_in_spec(tmp_path):
    @register("estimator", "test_depth_cost")
    class DepthCostEstimator(Estimator):
        name = "test_depth_cost"

        def __init__(self, scale=1.0):
            self.scale = scale

        def estimate(self, candidate, context=None):
            return self.scale * len(candidate.layers)

    assert "test_depth_cost" in ESTIMATORS
    raw = make_experiment(tmp_path, criteria=[
        {"estimator": "test_depth_cost", "kind": "objective",
         "params": {"scale": 2.0}}])
    report = Explorer.from_dict(raw).run(save_report=False)
    assert report.best is not None
    # depth is constant in the tiny space: every candidate scores 2 * n_layers
    assert report.best["values"][0] == report.criteria_values["test_depth_cost"] * 1.0


def test_registry_rejects_shadowing_but_allows_reregistration():
    sampler = SAMPLERS.get("random")
    SAMPLERS.register("random", sampler)  # same object: no-op
    with pytest.raises(ExplorerError, match="already registered"):
        SAMPLERS.register("random", object())


# ---------------------------------------------------------------------------
# end-to-end run(): hand-wired parity, report fields, artifact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ("serial", "process"))
def test_run_reproduces_hand_wired_quickstart(tmp_path, backend):
    """The facade composes exactly the wiring the quickstart builds by
    hand, so at a fixed seed it must find the identical best trial — on
    the in-process backend and across the process boundary (detached
    sampling plans)."""
    ref = hand_wired_study(n_trials=8, seed=0)
    raw = make_experiment(
        tmp_path, executor={"backend": backend, "n_workers": 1})
    explorer = Explorer.from_dict(raw)
    report = explorer.run()

    assert report.best["number"] == ref.best_trial.number
    assert report.best["values"] == list(ref.best_trial.values)

    # report integrity
    assert report.n_trials == 8
    assert report.states == {"complete": 8}
    assert report.backend == backend
    assert report.directions == ["minimize"]
    assert set(report.criteria_values) == {"flops", "n_params"}
    assert report.best["values"][0] == pytest.approx(
        report.criteria_values["flops"] + 0.1 * report.criteria_values["n_params"])
    assert report.best["signature"].startswith("conv1d(")
    assert report.pareto_front  # 2 objectives -> trade-off surface reported
    assert report.wall_clock_s > 0
    assert report.toolchain["jax"] not in ("", "unavailable")

    # JSON artifact under report_dir
    assert report.artifact and os.path.exists(report.artifact)
    with open(report.artifact) as f:
        persisted = json.load(f)
    assert persisted["experiment"] == "tiny"
    assert persisted["best"] == report.best

    # the winning architecture rebuilds into a runnable model
    model = explorer.best_model()
    assert model.n_params > 0


def test_multi_objective_rejects_soft_constraints(tmp_path):
    """evaluate_multi only runs hard constraints + objectives, so a
    soft constraint under scalarize:false would be silently ignored —
    the spec must refuse it."""
    raw = make_experiment(tmp_path, scalarize=False)
    raw["criteria"].append({"estimator": "activation_bytes",
                            "kind": "soft_constraint", "limit": 1e9})
    with pytest.raises(ExperimentError, match="soft"):
        ExperimentSpec.from_dict(raw)


def test_plugin_executor_resolves_through_make_executor():
    from repro.search import BaseExecutor, make_executor
    from repro.search.executors import SerialExecutor

    @register("executor", "test_inline")
    class InlineExecutor(SerialExecutor):
        name = "test_inline"

    assert isinstance(make_executor("test_inline"), InlineExecutor)
    assert isinstance(make_executor("test_inline"), BaseExecutor)


def test_report_artifact_field_round_trips(tmp_path):
    report = Explorer.from_dict(make_experiment(tmp_path)).run()
    with open(report.artifact) as f:
        assert json.load(f)["artifact"] == report.artifact


def test_multi_objective_mode_reports_pareto_front(tmp_path):
    raw = make_experiment(tmp_path, scalarize=False, name="tiny-mo")
    raw["sampler"] = {"name": "random", "seed": 1}
    report = Explorer.from_dict(raw).run(save_report=False)
    assert report.directions == ["minimize", "minimize"]
    front = report.pareto_front
    assert front
    for entry in front:
        assert len(entry["values"]) == 2


def test_persistence_resume_counts_against_budget(tmp_path):
    storage = str(tmp_path / "study.jsonl")
    raw = make_experiment(tmp_path, persistence=storage,
                          budget={"n_trials": 5})
    r1 = Explorer.from_dict(raw).run(save_report=False)
    assert r1.n_trials == 5
    # a re-run resumes the stored trials and only tops up to the budget
    raw2 = make_experiment(tmp_path, persistence=storage,
                           budget={"n_trials": 7})
    r2 = Explorer.from_dict(raw2).run(save_report=False)
    assert r2.n_trials == 7


def test_rerun_in_same_process_gets_fresh_objective_state(tmp_path):
    # Two runs of the SAME spec in one process must not share pipeline
    # state: the report reads cumulative cache/tuner counters from the
    # objective's per-process state, so inheriting run 1's state would
    # attribute its work (e.g. kernel tunes) to run 2.  Disk-tier values
    # still flow between runs — only the counters/instances are fresh.
    from repro.explorer.explorer import SpecObjective

    raw = make_experiment(tmp_path, cache={"dir": str(tmp_path / "cache")})
    e1, e2 = Explorer.from_dict(raw), Explorer.from_dict(raw)
    r1 = e1.run(save_report=False)
    r2 = e2.run(save_report=False)
    assert r1.best["number"] == r2.best["number"]
    assert e1._objective.run_token != e2._objective.run_token
    assert e1._objective.cache is not e2._objective.cache
    # run 2's report counts only its own lookups, not run 1's as well
    assert r2.cache["misses"] <= r1.cache["misses"]
    # same token -> same state (what keeps per-worker memoization alive
    # across submissions within one run); run 1's entry was evicted
    spec_dict = e2._objective.spec_dict
    token = e2._objective.run_token
    assert (SpecObjective(spec_dict, token)._state()
            is e2._objective._state())


# ---------------------------------------------------------------------------
# satellite fixes: criteria validation survives -O, duplicate detection
# ---------------------------------------------------------------------------

def test_criteria_kind_and_direction_raise_value_error():
    est = FlopsEstimator()
    with pytest.raises(ValueError, match="goal"):
        OptimizationCriteria(est, kind="goal")
    with pytest.raises(ValueError, match="sideways"):
        OptimizationCriteria(est, direction="sideways")
    with pytest.raises(ValueError, match="limit"):
        OptimizationCriteria(est, kind="hard_constraint")


def test_criteria_runner_rejects_duplicate_estimator_names():
    a, b = FlopsEstimator(), FlopsEstimator()
    with pytest.raises(ValueError) as e:
        CriteriaRunner([
            OptimizationCriteria(a, kind="objective"),
            OptimizationCriteria(b, kind="soft_constraint", limit=1.0),
        ])
    msg = str(e.value)
    assert "flops" in msg
    assert "objective" in msg and "soft_constraint" in msg  # both offenders named


# ---------------------------------------------------------------------------
# satellite fix: disk-cache toolchain salt
# ---------------------------------------------------------------------------

def test_canonical_key_salted_with_toolchain_versions(tmp_path):
    import jax

    from repro.evaluation import DiskEvaluationCache
    from repro.evaluation import disk_cache as dc

    ck = dc.canonical_key(("latency_s", "host_cpu", 2, "sig"))
    rec = json.loads(ck)
    assert rec["toolchain"]["jax"] == jax.__version__
    assert rec["toolchain"]["jaxlib"] not in ("", None)
    assert rec["key"] == ["latency_s", "host_cpu", 2, "sig"]

    # same toolchain: values round-trip between instances
    store = DiskEvaluationCache(str(tmp_path / "store"))
    assert store.store(("k",), 1.5)
    assert DiskEvaluationCache(str(tmp_path / "store")).lookup(("k",)) == (True, 1.5)

    # a different toolchain must structurally miss the persisted entry
    old = dc._TOOLCHAIN
    try:
        dc._TOOLCHAIN = {"jax": "0.0.0-other", "jaxlib": "0.0.0-other"}
        fresh = DiskEvaluationCache(str(tmp_path / "store"))
        assert fresh.lookup(("k",)) == (False, None)
    finally:
        dc._TOOLCHAIN = old


# ---------------------------------------------------------------------------
# schedule spec: validation + wiring into the study
# ---------------------------------------------------------------------------

def test_schedule_spec_validation(tmp_path):
    raw = make_experiment(tmp_path, schedule={"mode": "eventually"})
    with pytest.raises(ExperimentError, match="mode.*auto.*batch.*sliding_window"):
        ExperimentSpec.from_dict(raw)
    raw = make_experiment(tmp_path, schedule={"tell_order": "sometimes"})
    with pytest.raises(ExperimentError, match="tell_order"):
        ExperimentSpec.from_dict(raw)
    raw = make_experiment(tmp_path, schedule={"window": 0})
    with pytest.raises(ExperimentError, match="window"):
        ExperimentSpec.from_dict(raw)
    raw = make_experiment(tmp_path, schedule={"modus": "batch"})
    with pytest.raises(ExperimentError, match="unknown key"):
        ExperimentSpec.from_dict(raw)
    # bare string shorthand selects the mode
    spec = ExperimentSpec.from_dict(make_experiment(tmp_path, schedule="batch"))
    assert spec.schedule.mode == "batch"
    assert spec.schedule.tell_order == "trial" and spec.schedule.window is None


def test_explorer_wires_schedule_and_timeout(tmp_path, monkeypatch):
    from repro.search import ParallelStudy

    captured = {}
    orig = ParallelStudy.optimize

    def spy(self, objective, n_trials, **kw):
        captured.update(kw, n_trials=n_trials)
        return orig(self, objective, n_trials, **kw)

    monkeypatch.setattr(ParallelStudy, "optimize", spy)
    raw = make_experiment(
        tmp_path,
        sampler={"name": "random", "seed": 0},
        schedule={"mode": "sliding_window", "tell_order": "completion",
                  "window": 2},
        budget={"n_trials": 4, "timeout_s": 120.0},
    )
    explorer = Explorer.from_dict(raw)
    report = explorer.run(save_report=False)
    assert captured["timeout_s"] == 120.0 and captured["n_trials"] == 4
    assert explorer.study.default_schedule == "sliding_window"
    assert explorer.study.default_tell_order == "completion"
    assert explorer.study.default_window == 2
    assert report.schedule == {"mode": "sliding_window",
                               "tell_order": "completion", "window": 2}
    assert report.n_trials == 4


def test_facade_sliding_window_matches_batch_best_trial(tmp_path):
    def run(mode):
        raw = make_experiment(
            tmp_path,
            sampler={"name": "random", "seed": 11},
            executor={"backend": "thread", "n_workers": 3},
            schedule={"mode": mode, "tell_order": "completion"},
            budget={"n_trials": 10},
        )
        return Explorer.from_dict(raw).run(save_report=False)

    batch, sliding = run("batch"), run("sliding_window")
    assert batch.best is not None
    assert sliding.best["number"] == batch.best["number"]
    assert sliding.best["values"] == batch.best["values"]
