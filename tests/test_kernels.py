"""Per-kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,h,kh,d", [(128, 4, 2, 64), (256, 2, 2, 32), (128, 8, 1, 64)])
def test_flash_attention_sweep(s, h, kh, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (2, s, h, d), dtype)
    k = _rand(ks[1], (2, s, kh, d), dtype)
    v = _rand(ks[2], (2, s, kh, d), dtype)
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_kv=64)
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                               atol=4 * _tol(dtype), rtol=4 * _tol(dtype))


def test_flash_attention_sliding_window():
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (1, 256, 4, 32), jnp.float32)
    k = _rand(ks[1], (1, 256, 2, 32), jnp.float32)
    v = _rand(ks[2], (1, 256, 2, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=64, block_q=64, block_kv=64)
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True, window=64,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4, rtol=1e-4)


@settings(max_examples=8, deadline=None)
@given(
    s=st.sampled_from([64, 128, 192]),
    heads=st.sampled_from([(2, 1), (4, 2), (4, 4)]),
    d=st.sampled_from([32, 64]),
)
def test_flash_attention_property(s, heads, d):
    h, kh = heads
    ks = jax.random.split(jax.random.PRNGKey(s * h * d), 3)
    q = _rand(ks[0], (1, s, h, d), jnp.float32)
    k = _rand(ks[1], (1, s, kh, d), jnp.float32)
    v = _rand(ks[2], (1, s, kh, d), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_kv=64)
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# ssm scan (mamba2 / SSD)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("l,h,p,g,n,chunk", [
    (64, 4, 32, 2, 16, 16), (128, 2, 64, 1, 32, 32), (96, 3, 16, 3, 8, 16),
])
def test_ssm_scan_sweep(l, h, p, g, n, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    x = _rand(ks[0], (2, l, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = _rand(ks[3], (2, l, g, n), dtype)
    cm = _rand(ks[4], (2, l, g, n), dtype)
    y, st_ = ops.ssm_scan(x, dt, a, bm, cm, chunk=chunk)
    yref, stref = ref.ssm_scan_ref(
        x, dt, a, jnp.repeat(bm, h // g, 2), jnp.repeat(cm, h // g, 2), chunk=chunk
    )
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yref, np.float32),
                               atol=8 * _tol(dtype), rtol=8 * _tol(dtype))
    np.testing.assert_allclose(np.asarray(st_), np.asarray(stref),
                               atol=8 * _tol(dtype), rtol=8 * _tol(dtype))


def test_ssm_scan_matches_recurrence():
    """Chunked kernel == step-by-step recurrence (the strictest oracle)."""
    from repro.nn.ssm import ssd_recurrent_step

    l, h, p, n = 32, 2, 16, 8
    ks = jax.random.split(KEY, 5)
    x = _rand(ks[0], (1, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = _rand(ks[3], (1, l, 1, n), jnp.float32)
    cm = _rand(ks[4], (1, l, 1, n), jnp.float32)
    y, _ = ops.ssm_scan(x, dt, a, bm, cm, chunk=8)
    state = jnp.zeros((1, h, n, p))
    outs = []
    for t in range(l):
        yt, state = ssd_recurrent_step(state, x[:, t], dt[:, t], a, bm[:, t], cm[:, t])
        outs.append(yt[:, None])
    want = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# mlstm scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("l,h,p,chunk", [(64, 2, 32, 16), (128, 4, 16, 32)])
def test_mlstm_scan_sweep(l, h, p, chunk):
    ks = jax.random.split(KEY, 5)
    q = _rand(ks[0], (2, l, h, p), jnp.float32)
    k = _rand(ks[1], (2, l, h, p), jnp.float32)
    v = _rand(ks[2], (2, l, h, p), jnp.float32)
    il = jax.random.normal(ks[3], (2, l, h)) * 2.0
    fl = jax.nn.log_sigmoid(jax.random.normal(ks[4], (2, l, h)) + 3.0)
    hout, _ = ops.mlstm_scan(q, k, v, il, fl, chunk=chunk)
    want = ref.mlstm_scan_ref(q, k, v, il, fl)
    np.testing.assert_allclose(np.asarray(hout), np.asarray(want), atol=2e-4, rtol=2e-3)


@settings(max_examples=6, deadline=None)
@given(chunk=st.sampled_from([8, 16, 32]), gate_bias=st.sampled_from([-2.0, 1.0, 5.0]))
def test_mlstm_chunk_invariance(chunk, gate_bias):
    """Output must not depend on the chunk size (pure reformulation)."""
    l, h, p = 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(int(gate_bias * 10) + chunk), 5)
    q = _rand(ks[0], (1, l, h, p), jnp.float32)
    k = _rand(ks[1], (1, l, h, p), jnp.float32)
    v = _rand(ks[2], (1, l, h, p), jnp.float32)
    il = jax.random.normal(ks[3], (1, l, h))
    fl = jax.nn.log_sigmoid(jax.random.normal(ks[4], (1, l, h)) + gate_bias)
    h1, _ = ops.mlstm_scan(q, k, v, il, fl, chunk=chunk)
    want = ref.mlstm_scan_ref(q, k, v, il, fl)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(want), atol=3e-4, rtol=3e-3)
