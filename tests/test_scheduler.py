"""Sliding-window scheduler: batch parity, straggler throughput,
worker-side pruning, per-submission timeouts, error-path cancellation.
Objectives are module-level so they pickle across the process boundary
(spawn workers re-import this module)."""
import threading
import time

import pytest

from repro.search import (
    GridSampler,
    MedianPruner,
    ParallelStudy,
    RandomSampler,
    Study,
    ThreadExecutor,
    TPESampler,
    TrialPruned,
    TrialState,
)

BACKENDS = ("serial", "thread", "process", "remote")


@pytest.fixture(scope="module")
def remote_pool():
    """Two in-process loopback worker daemons shared by this module's
    remote-backend parametrizations."""
    from repro.search.remote.worker import WorkerServer

    servers = [WorkerServer() for _ in range(2)]
    addrs = ["%s:%d" % s.start() for s in servers]
    yield addrs
    for s in servers:
        s.stop()


def _backend(name, request):
    """Resolve a BACKENDS entry for ParallelStudy: plain names pass
    through; `remote` needs a constructed executor holding the loopback
    pool (one instance per study, like a YAML-built run)."""
    if name == "remote":
        from repro.search.remote.executor import RemoteExecutor

        return RemoteExecutor(workers=list(request.getfixturevalue("remote_pool")))
    return name


def _quadratic(trial):
    x = trial.suggest_float("x", -4.0, 4.0)
    y = trial.suggest_float("y", -4.0, 4.0)
    return (x - 1.0) ** 2 + (y + 0.5) ** 2


def _grid_obj(trial):
    b = trial.suggest_categorical("b", ["p", "q", "r"])
    a = trial.suggest_int("a", 0, 1)
    return float(a) + (0.0 if b == "p" else 1.0)


def _fingerprint(study):
    return [(t.number, dict(t.params), t.values) for t in study.trials]


# ---------------------------------------------------------------------------
# parity: batch vs sliding window, fixed seed, every backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("tell_order", ("trial", "completion"))
def test_sliding_matches_batch_random(backend, tell_order, request):
    ref = ParallelStudy(sampler=RandomSampler(seed=3), n_workers=3,
                        backend=_backend(backend, request), schedule="batch")
    ref.optimize(_quadratic, 11)
    s = ParallelStudy(sampler=RandomSampler(seed=3), n_workers=3,
                      backend=_backend(backend, request),
                      schedule="sliding_window",
                      tell_order=tell_order)
    s.optimize(_quadratic, 11)
    assert _fingerprint(s) == _fingerprint(ref)
    assert s.best_trial.number == ref.best_trial.number
    assert s.best_trial.values == ref.best_trial.values


@pytest.mark.parametrize("backend", BACKENDS)
def test_sliding_matches_batch_grid(backend, request):
    ref = ParallelStudy(sampler=GridSampler(seed=0), n_workers=3,
                        backend=_backend(backend, request), schedule="batch")
    ref.optimize(_grid_obj, 6)
    s = ParallelStudy(sampler=GridSampler(seed=0), n_workers=3,
                      backend=_backend(backend, request),
                      schedule="sliding_window",
                      tell_order="completion")
    s.optimize(_grid_obj, 6)
    # full 2x3 product, identical coverage and winner
    cover = lambda st: sorted((t.params["a"], t.params["b"]) for t in st.trials)
    assert cover(s) == cover(ref) and len(set(cover(s))) == 6
    assert s.best_trial.values == ref.best_trial.values


def test_auto_schedule_resolution():
    assert ParallelStudy(sampler=RandomSampler(seed=0))._resolve_schedule(None) \
        == "sliding_window"
    assert ParallelStudy(sampler=GridSampler(seed=0))._resolve_schedule(None) \
        == "sliding_window"
    assert ParallelStudy(sampler=TPESampler(seed=0))._resolve_schedule(None) \
        == "batch"
    assert ParallelStudy(
        sampler=TPESampler(seed=0), schedule="sliding_window",
    )._resolve_schedule(None) == "sliding_window"  # explicit overrides auto


def test_schedule_validation():
    with pytest.raises(ValueError, match="schedule"):
        ParallelStudy(schedule="eventually")
    with pytest.raises(ValueError, match="tell_order"):
        ParallelStudy(tell_order="sometimes")


def test_sliding_tell_trial_preserves_storage_order(tmp_path):
    path = str(tmp_path / "s.jsonl")
    s = ParallelStudy(sampler=RandomSampler(seed=1), n_workers=4,
                      backend="thread", schedule="sliding_window",
                      tell_order="trial", storage=path)
    s.optimize(_staggered, 9)
    # the reorder buffer tells (and persists) strictly in trial order even
    # though completions arrive out of order
    import json

    with open(path) as f:
        numbers = [json.loads(line)["trial"]["number"] for line in f if line.strip()]
    assert numbers == list(range(9))


def test_sliding_tell_completion_records_every_trial(tmp_path):
    path = str(tmp_path / "s.jsonl")
    s = ParallelStudy(sampler=RandomSampler(seed=1), n_workers=4,
                      backend="thread", schedule="sliding_window",
                      tell_order="completion", storage=path)
    s.optimize(_staggered, 9)
    s2 = Study(storage=path)  # resumable regardless of append order
    assert sorted(t.number for t in s2.trials) == list(range(9))
    assert all(t.state == TrialState.COMPLETE for t in s2.trials)


# ---------------------------------------------------------------------------
# straggler throughput: the whole point of killing the barrier
# ---------------------------------------------------------------------------

_SLOW, _FAST = 0.6, 0.12


def _staggered(trial):
    x = trial.suggest_float("x", 0.0, 1.0)
    time.sleep(_SLOW if trial.number == 1 else _FAST)
    return (x - 0.5) ** 2


def test_straggler_sliding_beats_simulated_batch_wall_clock():
    """1 slow trial vs 7 fast at n_workers=4: the batch scheduler's wall
    clock is (by construction) the sum of per-batch maxima, which the
    sliding window must beat — the fast lane keeps moving while the
    straggler runs."""
    durations = {n: (_SLOW if n == 1 else _FAST) for n in range(9)}
    # untimed warmup: the first make_executor() lazily imports the
    # registry built-ins (jax included) — that one-time cost must not
    # land inside the measured region
    warm = ParallelStudy(sampler=RandomSampler(seed=5), n_workers=2,
                         backend="thread", schedule="sliding_window")
    warm.optimize(lambda t: t.suggest_float("x", 0.0, 1.0), 2)
    s = ParallelStudy(sampler=RandomSampler(seed=5), n_workers=4,
                      backend="thread", schedule="sliding_window",
                      tell_order="completion")
    t0 = time.perf_counter()
    s.optimize(_staggered, 9)
    sliding_wall = time.perf_counter() - t0
    # batch mode: trial 0 synchronous, then [1,2,3,4] gated on the slow
    # trial, then [5,6,7,8]
    simulated_batch = (durations[0]
                       + max(durations[n] for n in (1, 2, 3, 4))
                       + max(durations[n] for n in (5, 6, 7, 8)))
    assert all(t.state == TrialState.COMPLETE for t in s.trials)
    assert sliding_wall < simulated_batch - 0.5 * _FAST, (
        f"sliding {sliding_wall:.2f}s vs simulated batch {simulated_batch:.2f}s")


# ---------------------------------------------------------------------------
# worker-side pruning (process backend)
# ---------------------------------------------------------------------------

_PRUNE_BUDGET = 10


def _prunable(trial):
    bad = trial.number % 4 == 3
    base = 100.0 if bad else 1.0
    for step in range(_PRUNE_BUDGET):
        trial.report(step, base + 0.01 * step)
        if trial.should_prune():
            trial.set_user_attr("steps_run", step + 1)
            raise TrialPruned()
        time.sleep(0.01)
    trial.set_user_attr("steps_run", _PRUNE_BUDGET)
    return base


def test_process_backend_prunes_worker_side():
    """A process-backend trial whose submit-time snapshot marks it doomed
    must come back PRUNED having executed a fraction of its step budget —
    the pruner ran *inside* the worker, not after full evaluation."""
    s = ParallelStudy(sampler=RandomSampler(seed=0), n_workers=2,
                      backend="process", schedule="sliding_window",
                      tell_order="completion",
                      pruner=MedianPruner(n_startup_trials=2))
    s.optimize(_prunable, 12)
    pruned = [t for t in s.trials if t.state == TrialState.PRUNED]
    assert pruned, "expected doomed trials to be pruned inside workers"
    for t in pruned:
        assert t.user_attrs["steps_run"] < _PRUNE_BUDGET
        assert t.intermediate  # streamed reports merged back
    # good trials ran to completion
    complete = [t for t in s.trials if t.state == TrialState.COMPLETE]
    assert all(t.user_attrs["steps_run"] == _PRUNE_BUDGET for t in complete)


def test_unpicklable_pruner_degrades_to_no_worker_pruning():
    class LockedPruner(MedianPruner):
        def __init__(self):
            super().__init__(n_startup_trials=2)
            self.lock = threading.Lock()  # cannot cross the process boundary

    s = ParallelStudy(sampler=RandomSampler(seed=0), n_workers=2,
                      backend="process", schedule="sliding_window",
                      pruner=LockedPruner())
    s.optimize(_prunable, 8)  # must not raise; trials just run to budget
    assert all(t.state == TrialState.COMPLETE for t in s.trials)
    assert all(t.user_attrs["steps_run"] == _PRUNE_BUDGET for t in s.trials)


def test_thread_backend_still_prunes_live():
    s = ParallelStudy(sampler=RandomSampler(seed=0), n_workers=2,
                      backend="thread", schedule="sliding_window",
                      tell_order="completion",
                      pruner=MedianPruner(n_startup_trials=2))
    s.optimize(_prunable, 12)
    assert any(t.state == TrialState.PRUNED for t in s.trials)


# ---------------------------------------------------------------------------
# per-submission timeout (stubbed clock)
# ---------------------------------------------------------------------------

class _FakeClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now


def test_timeout_enforced_per_submission(monkeypatch):
    """Each trial costs 10 fake seconds; with a 25 s budget the scheduler
    must stop after the submission that crosses the deadline instead of
    overshooting by a whole batch (serial backend: submit evaluates
    inline, so the fill loop's deadline check is exactly per-submission)."""
    from repro.search import parallel

    clock = _FakeClock()
    monkeypatch.setattr(parallel, "_monotonic", clock)

    def costly(trial):
        trial.suggest_float("x", 0.0, 1.0)
        clock.now += 10.0
        return 1.0

    s = ParallelStudy(sampler=RandomSampler(seed=0), n_workers=4,
                      backend="serial", schedule="sliding_window")
    s.optimize(costly, 50, timeout_s=25.0)
    # t0 (sync) -> 10s, t1 -> 20s (< 25, submitted), t2 -> 30s (>= 25
    # after t2's submission check? no: the check BEFORE t2 sees 20 < 25,
    # so t2 runs and the next check stops) => exactly 3 trials, not a
    # batch-quantized 1 + 2*n_workers
    assert len(s.trials) == 3
    assert all(t.state == TrialState.COMPLETE for t in s.trials)


def test_timeout_batch_mode_checks_between_batches(monkeypatch):
    from repro.search import parallel

    clock = _FakeClock()
    monkeypatch.setattr(parallel, "_monotonic", clock)

    def costly(trial):
        trial.suggest_float("x", 0.0, 1.0)
        clock.now += 10.0
        return 1.0

    s = ParallelStudy(sampler=RandomSampler(seed=0), n_workers=2,
                      backend="serial", schedule="batch")
    s.optimize(costly, 50, timeout_s=25.0)
    # t0 sync (10s), batch [t1, t2] -> 30s, deadline stops the next batch
    assert len(s.trials) == 3


# ---------------------------------------------------------------------------
# error path: cancellation of queued submissions
# ---------------------------------------------------------------------------

def _boom_then_slow(trial):
    trial.suggest_float("x", 0.0, 1.0)
    if trial.number == 1:
        raise ValueError("boom")
    time.sleep(0.4)
    return 1.0


def test_error_cancels_queued_submissions():
    """With window > pool capacity, submissions queue behind the running
    ones; an uncaught error must cancel the queued ones (FAIL, with the
    cancellation recorded) rather than run them or leave them RUNNING."""
    s = ParallelStudy(sampler=RandomSampler(seed=0), n_workers=2,
                      backend="thread", schedule="sliding_window",
                      tell_order="completion", window=6)
    with pytest.raises(ValueError, match="boom"):
        s.optimize(_boom_then_slow, 12)
    assert all(t.state != TrialState.RUNNING for t in s.trials)
    assert s.trials[1].state == TrialState.FAIL
    assert "boom" in s.trials[1].user_attrs["error"]
    cancelled = [t for t in s.trials if "cancelled" in t.user_attrs]
    assert cancelled, "queued submissions should have been cancelled"
    assert all(t.state == TrialState.FAIL for t in cancelled)
    # the already-running sibling still drained to a real result
    assert any(t.state == TrialState.COMPLETE for t in s.trials if t.number > 0)


def test_error_drains_running_siblings_sliding_process():
    s = ParallelStudy(sampler=RandomSampler(seed=0), n_workers=3,
                      backend="process", schedule="sliding_window")
    with pytest.raises(ValueError, match="boom"):
        s.optimize(_boom_then_slow, 9)
    assert all(t.state != TrialState.RUNNING for t in s.trials)


# ---------------------------------------------------------------------------
# executor streaming surface
# ---------------------------------------------------------------------------

def test_executor_streaming_surface_direct():
    ex = ThreadExecutor()
    ex.start(2)
    try:
        study = ParallelStudy(sampler=RandomSampler(seed=2), backend=ex)
        trials = [study.ask() for _ in range(3)]
        for t in trials:
            ex.submit(study, _quadratic, t, ())
        assert ex.pending_count() == 3
        seen = set()
        while ex.pending_count():
            t, outcome = ex.next_completed()
            values, state = outcome
            assert state == TrialState.COMPLETE
            seen.add(t.number)
            study.tell(t, values, state)
        assert seen == {0, 1, 2}
        with pytest.raises(RuntimeError, match="no in-flight"):
            ex.next_completed()
    finally:
        ex.shutdown()


def test_run_batch_shim_over_streaming():
    ex = ThreadExecutor()
    ex.start(2)
    try:
        study = ParallelStudy(sampler=RandomSampler(seed=2), backend=ex)
        trials = [study.ask() for _ in range(4)]
        outcomes = ex.run_batch(study, _quadratic, trials, ())
        assert len(outcomes) == 4
        for t, (values, state) in zip(trials, outcomes):
            assert state == TrialState.COMPLETE
            study.tell(t, values, state)
    finally:
        ex.shutdown()


def test_executor_reuse_after_cancellation_round():
    """Regression: cancelled submissions' completions stay in the done
    queue; a reused executor must not match them (by colliding trial
    number) against a later study's trials."""
    ex = ThreadExecutor()
    s1 = ParallelStudy(sampler=RandomSampler(seed=0), n_workers=2,
                       backend=ex, schedule="sliding_window",
                       tell_order="completion", window=6)
    with pytest.raises(ValueError, match="boom"):
        s1.optimize(_boom_then_slow, 12)
    assert any("cancelled" in t.user_attrs for t in s1.trials)
    # same executor instance, fresh study with overlapping trial numbers
    s2 = ParallelStudy(sampler=RandomSampler(seed=4), n_workers=2,
                       backend=ex, schedule="sliding_window",
                       tell_order="completion", window=6)
    s2.optimize(_quadratic, 8)
    assert all(t.state == TrialState.COMPLETE for t in s2.trials)
    ref = Study(sampler=RandomSampler(seed=4))
    ref.optimize(_quadratic, 8)
    assert _fingerprint(s2) == _fingerprint(ref)
