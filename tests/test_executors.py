"""Executor backends: serial/thread/process parity, process merge-back,
error semantics.  Objectives are module-level so they pickle across the
process boundary (spawn workers re-import this module)."""
import threading

import pytest

from repro.search import (
    GridSampler,
    NSGA2Sampler,
    ParallelStudy,
    ProcessExecutor,
    RandomSampler,
    RegularizedEvolutionSampler,
    SerialExecutor,
    Study,
    ThreadExecutor,
    TPESampler,
    TrialPruned,
    TrialState,
    make_executor,
)
from repro.search.study import HardConstraintViolated

BACKENDS = ("serial", "thread", "process")


def _quadratic(trial):
    x = trial.suggest_float("x", -4.0, 4.0)
    y = trial.suggest_float("y", -4.0, 4.0)
    return (x - 1.0) ** 2 + (y + 0.5) ** 2


def _fingerprint(study):
    return [(t.number, t.params["x"], t.params["y"], t.values[0]) for t in study.trials]


# ---------------------------------------------------------------------------
# parity: identical trials and best value at fixed seed, any backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_parity_with_serial_study(backend):
    ref = Study(sampler=RandomSampler(seed=7))
    ref.optimize(_quadratic, 12)
    s = ParallelStudy(sampler=RandomSampler(seed=7), n_workers=3, backend=backend)
    s.optimize(_quadratic, 12)
    assert _fingerprint(s) == _fingerprint(ref)
    assert s.best_trial.number == ref.best_trial.number
    assert s.best_trial.values == ref.best_trial.values


def test_process_backend_worker_count_independent():
    runs = {}
    for w in (1, 3):
        s = ParallelStudy(sampler=RandomSampler(seed=11), n_workers=w, backend="process")
        s.optimize(_quadratic, 9)
        runs[w] = _fingerprint(s)
    assert runs[1] == runs[3]


def _grid_obj(trial):
    # suggest in NON-sorted name order to exercise the radix bookkeeping
    b = trial.suggest_categorical("b", ["p", "q", "r"])
    a = trial.suggest_int("a", 0, 1)
    return float(a) + (0.0 if b == "p" else 1.0)


@pytest.mark.parametrize("backend", ("thread", "process"))
def test_grid_parity_across_backends(backend):
    ref = Study(sampler=GridSampler())
    ref.optimize(_grid_obj, 6)
    cover = lambda s: sorted((t.params["a"], t.params["b"]) for t in s.trials)
    s = ParallelStudy(sampler=GridSampler(), n_workers=3, backend=backend)
    s.optimize(_grid_obj, 6)
    assert len(set(cover(s))) == 6  # full 2x3 product, no repeats
    assert cover(s) == cover(ref)


@pytest.mark.parametrize("make_sampler", [
    lambda: TPESampler(seed=5, n_startup=4),
    lambda: RegularizedEvolutionSampler(seed=5, population=6),
    lambda: NSGA2Sampler(seed=5, population=6),
], ids=["tpe", "evolution", "nsga2"])
def test_population_samplers_thread_process_parity(make_sampler):
    """Population snapshots are taken at ask time under the study lock, so
    for a fixed n_workers the process backend replays exactly the
    threaded trajectory."""
    a = ParallelStudy(sampler=make_sampler(), n_workers=2, backend="thread")
    a.optimize(_quadratic, 14)
    b = ParallelStudy(sampler=make_sampler(), n_workers=2, backend="process")
    b.optimize(_quadratic, 14)
    assert _fingerprint(a) == _fingerprint(b)


# ---------------------------------------------------------------------------
# process backend: state + attribute merge-back, storage
# ---------------------------------------------------------------------------

def _special_states_obj(trial):
    x = trial.suggest_int("i", 0, 100)
    if trial.number % 3 == 0:
        raise TrialPruned()
    if trial.number % 3 == 1:
        raise HardConstraintViolated("n_params", 10.0, 1.0)
    trial.report(1, float(x))
    trial.set_user_attr("echo", trial.number)
    return float(x)


def test_process_backend_records_special_states(tmp_path):
    path = str(tmp_path / "s.jsonl")
    s = ParallelStudy(sampler=RandomSampler(seed=0), n_workers=3,
                      backend="process", storage=path)
    s.optimize(_special_states_obj, 12)
    states = [t.state for t in s.trials]
    assert states.count(TrialState.PRUNED) == 4
    assert states.count(TrialState.INFEASIBLE) == 4
    assert states.count(TrialState.COMPLETE) == 4
    for t in s.trials:
        assert "i" in t.params and "i" in t.distributions  # merged back
        if t.state == TrialState.INFEASIBLE:
            assert t.user_attrs["violated"]["name"] == "n_params"
        if t.state == TrialState.COMPLETE and t.number > 0:
            assert t.user_attrs["echo"] == t.number
            assert t.intermediate == {1: t.values[0]}
    # storage got every trial exactly once, in trial order
    s2 = Study(storage=path)
    assert [t.number for t in s2.trials] == list(range(12))


def _boom_obj(trial):
    x = trial.suggest_int("i", 0, 100)
    if trial.number == 3:
        raise ValueError("boom")
    return float(x)


def test_process_backend_drains_batch_on_uncaught_error(tmp_path):
    path = str(tmp_path / "s.jsonl")
    s = ParallelStudy(sampler=RandomSampler(seed=0), n_workers=4,
                      backend="process", storage=path)
    with pytest.raises(ValueError, match="boom"):
        s.optimize(_boom_obj, 12)
    assert all(t.state != TrialState.RUNNING for t in s.trials)
    assert s.trials[3].state == TrialState.FAIL
    assert "boom" in s.trials[3].user_attrs["error"]
    completed = [t for t in s.trials if t.state == TrialState.COMPLETE]
    assert completed  # siblings of the failing trial were preserved
    s2 = Study(storage=path)
    assert len(s2.trials) == len(s.trials)  # every told trial persisted


def _unpicklable_boom_obj(trial):
    trial.suggest_int("i", 0, 3)
    if trial.number == 2:
        e = ValueError("nope")
        e.bad = threading.Lock()  # cannot cross the process boundary
        raise e
    return 1.0


def test_process_backend_wraps_unpicklable_exception():
    s = ParallelStudy(sampler=RandomSampler(seed=0), n_workers=2, backend="process")
    with pytest.raises(RuntimeError, match="nope"):
        s.optimize(_unpicklable_boom_obj, 4)
    assert s.trials[2].state == TrialState.FAIL


def _catchable_obj(trial):
    trial.suggest_int("i", 0, 3)
    if trial.number % 2 == 1:
        raise KeyError("missing")
    return 0.0


def test_process_backend_catch_maps_to_fail():
    s = ParallelStudy(sampler=RandomSampler(seed=0), n_workers=2, backend="process")
    s.optimize(_catchable_obj, 6, catch=(KeyError,))
    fails = [t for t in s.trials if t.state == TrialState.FAIL]
    assert len(fails) == 3
    assert all("missing" in t.user_attrs["error"] for t in fails)


# ---------------------------------------------------------------------------
# executor surface
# ---------------------------------------------------------------------------

def test_make_executor_resolves_names_and_instances():
    assert isinstance(make_executor("serial"), SerialExecutor)
    assert isinstance(make_executor("thread"), ThreadExecutor)
    assert isinstance(make_executor("process"), ProcessExecutor)
    ex = ThreadExecutor()
    assert make_executor(ex) is ex
    # resolution now goes through the explorer registry: the error lists
    # every registered backend, including plugins
    with pytest.raises(ValueError, match="unknown executor.*serial"):
        make_executor("gpu-cluster")


def test_executor_instance_reusable_across_optimize_calls():
    ex = ThreadExecutor()
    s = ParallelStudy(sampler=RandomSampler(seed=1), n_workers=2, backend=ex)
    s.optimize(_quadratic, 4)
    s.optimize(_quadratic, 4)  # restarted pool, same instance
    assert len(s.trials) == 8
    ref = Study(sampler=RandomSampler(seed=1))
    ref.optimize(_quadratic, 8)
    assert _fingerprint(s) == _fingerprint(ref)
