"""Parallel evaluation engine + shared evaluation cache + resume fixes."""
import os
import threading
import time

import pytest

from repro.core.builder import ModelBuilder
from repro.core.space import parse_search_space
from repro.core.translate import ArchitectureIR, LayerIR, sample_architecture
from repro.evaluation import (
    CompiledLatencyEstimator,
    CompiledMemoryEstimator,
    EvaluationCache,
)
from repro.search import GridSampler, ParallelStudy, RandomSampler, Study, TrialState

SPACE = parse_search_space("""
input: [2, 64]
output: 3
sequence:
  - block: "c"
    op_candidates: "conv1d"
  - block: "h"
    op_candidates: "linear"
default_op_params:
  conv1d:
    kernel_size: [3]
    out_channels: [4]
""")


# ---------------------------------------------------------------------------
# signature regression: preprocessing is part of the cache identity
# ---------------------------------------------------------------------------

def test_signature_includes_preprocessing():
    layers = [LayerIR(op="conv1d", params={"kernel_size": 3}, path="c")]
    bare = ArchitectureIR(layers=list(layers))
    zscore = ArchitectureIR(layers=list(layers),
                            preprocessing=[{"stage": "normalize", "kind": "zscore"}])
    minmax = ArchitectureIR(layers=list(layers),
                            preprocessing=[{"stage": "normalize", "kind": "minmax"}])
    sigs = {bare.signature(), zscore.signature(), minmax.signature()}
    assert len(sigs) == 3  # all distinct — no cache collisions
    assert bare.signature() in zscore.signature()  # layer part unchanged


def test_compiled_estimators_distinguish_preprocessing():
    """Two candidates differing only in pre-processing never share a
    cached value (the pre-zscore/minmax programs are different)."""
    builder = ModelBuilder(SPACE.input_shape, SPACE.output_dim)
    study = Study(sampler=RandomSampler(seed=0))
    arch = sample_architecture(SPACE, study.ask())
    a = ModelBuilder(SPACE.input_shape, SPACE.output_dim).build(
        ArchitectureIR(layers=arch.layers,
                       preprocessing=[{"stage": "normalize", "kind": "zscore"}]))
    b = builder.build(
        ArchitectureIR(layers=arch.layers,
                       preprocessing=[{"stage": "normalize", "kind": "minmax"}]))
    cache = EvaluationCache()
    est = CompiledLatencyEstimator("host_cpu", batch=1, cache=cache)
    est.estimate(a)
    est.estimate(b)
    # two distinct candidates -> two artifacts + two values, zero hits
    assert cache.stats.misses == 4 and cache.stats.hits == 0


# ---------------------------------------------------------------------------
# cache accounting + artifact sharing + single-flight
# ---------------------------------------------------------------------------

def test_cache_hit_miss_accounting_and_artifact_sharing():
    builder = ModelBuilder(SPACE.input_shape, SPACE.output_dim)
    study = Study(sampler=RandomSampler(seed=0))
    m = builder.build(sample_architecture(SPACE, study.ask()))

    cache = EvaluationCache()
    lat = CompiledLatencyEstimator("host_cpu", batch=2, cache=cache)
    mem = CompiledMemoryEstimator("host_cpu", batch=2, cache=cache)

    v1 = lat.estimate(m)
    assert cache.stats.misses == 2 and cache.stats.hits == 0  # artifact + value
    mem.estimate(m)  # reuses the generated artifact: one hit, one new value
    assert cache.stats.hits == 1 and cache.stats.misses == 3
    assert lat.estimate(m) == v1  # pure value hit
    assert cache.stats.hits == 2 and cache.stats.misses == 3
    assert 0 < cache.stats.hit_rate < 1


def test_cache_single_flight_under_contention():
    cache = EvaluationCache()
    calls = []

    def compute():
        calls.append(1)
        time.sleep(0.05)
        return 42

    results = []
    threads = [threading.Thread(target=lambda: results.append(
        cache.get_or_compute("k", compute))) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert results == [42] * 8
    assert len(calls) == 1  # exactly one compute despite 8 racing callers
    assert cache.stats.misses == 1 and cache.stats.hits == 7


def test_cache_failed_compute_retried():
    cache = EvaluationCache()
    state = {"n": 0}

    def flaky():
        state["n"] += 1
        if state["n"] == 1:
            raise RuntimeError("boom")
        return "ok"

    with pytest.raises(RuntimeError):
        cache.get_or_compute("k", flaky)
    assert cache.get_or_compute("k", flaky) == "ok"


# ---------------------------------------------------------------------------
# ParallelStudy: determinism, state handling, storage
# ---------------------------------------------------------------------------

def _quadratic(trial):
    x = trial.suggest_float("x", -4.0, 4.0)
    y = trial.suggest_float("y", -4.0, 4.0)
    return (x - 1.0) ** 2 + (y + 0.5) ** 2


def test_parallel_study_deterministic_across_worker_counts():
    runs = {}
    for w in (1, 4):
        s = ParallelStudy(sampler=RandomSampler(seed=11), n_workers=w)
        s.optimize(_quadratic, 20)
        runs[w] = [(t.number, t.params["x"], t.params["y"], t.values[0]) for t in s.trials]
    assert runs[1] == runs[4]  # identical params AND values per trial


def test_parallel_study_matches_serial_study():
    serial = Study(sampler=RandomSampler(seed=3))
    serial.optimize(_quadratic, 16)
    par = ParallelStudy(sampler=RandomSampler(seed=3), n_workers=4)
    par.optimize(_quadratic, 16)
    assert serial.best_trial.number == par.best_trial.number
    assert serial.best_trial.values == par.best_trial.values


def test_parallel_study_records_special_states(tmp_path):
    from repro.search import TrialPruned
    from repro.search.study import HardConstraintViolated

    def obj(trial):
        x = trial.suggest_int("i", 0, 100)
        if trial.number % 3 == 0:
            raise TrialPruned()
        if trial.number % 3 == 1:
            raise HardConstraintViolated("n_params", 10.0, 1.0)
        return float(x)

    path = os.path.join(tmp_path, "s.jsonl")
    s = ParallelStudy(sampler=RandomSampler(seed=0), n_workers=4, storage=path)
    s.optimize(obj, 12)
    states = [t.state for t in s.trials]
    assert states.count(TrialState.PRUNED) == 4
    assert states.count(TrialState.INFEASIBLE) == 4
    assert states.count(TrialState.COMPLETE) == 4
    # storage got every trial exactly once, in trial order
    s2 = Study(storage=path)
    assert [t.number for t in s2.trials] == list(range(12))


def test_parallel_study_drains_batch_on_uncaught_error(tmp_path):
    """An uncaught objective exception must not strand sibling trials as
    RUNNING — their finished evaluations are told (and persisted) first."""
    path = os.path.join(tmp_path, "s.jsonl")

    def obj(trial):
        x = trial.suggest_int("i", 0, 100)
        if trial.number == 3:
            raise ValueError("boom")
        return float(x)

    s = ParallelStudy(sampler=RandomSampler(seed=0), n_workers=4, storage=path)
    with pytest.raises(ValueError, match="boom"):
        s.optimize(obj, 12)
    assert all(t.state != TrialState.RUNNING for t in s.trials)
    assert s.trials[3].state == TrialState.FAIL
    completed = [t for t in s.trials if t.state == TrialState.COMPLETE]
    assert completed  # siblings of the failing trial were preserved
    s2 = Study(storage=path)
    assert len(s2.trials) == len(s.trials)  # every told trial persisted


def test_parallel_grid_matches_serial_grid():
    """Grid sweep order is worker-count independent (first trial runs
    serially, completing the distribution registry before fan-out) —
    including when suggestion order differs from sorted name order."""
    def obj(seen):
        def _obj(trial):
            b = trial.suggest_categorical("b", ["p", "q", "r"])
            a = trial.suggest_int("a", 0, 1)
            seen.append((a, b))
            return 0.0
        return _obj

    serial_seen, par_seen = [], []
    s = Study(sampler=GridSampler())
    s.optimize(obj(serial_seen), 6)
    p = ParallelStudy(sampler=GridSampler(), n_workers=4)
    p.optimize(obj(par_seen), 6)
    assert len(set(serial_seen)) == 6
    assert sorted(par_seen) == sorted(serial_seen)


def test_archless_candidate_not_cached():
    """Candidates without an arch must bypass the cache — an object-id
    key could alias a freed model's address."""
    builder = ModelBuilder(SPACE.input_shape, SPACE.output_dim)
    study = Study(sampler=RandomSampler(seed=0))
    m = builder.build(sample_architecture(SPACE, study.ask()))
    m.arch = None
    cache = EvaluationCache()
    est = CompiledLatencyEstimator("host_cpu", batch=1, cache=cache)
    est.estimate(m)
    assert len(cache) == 0 and cache.stats.hits == 0 and cache.stats.misses == 0


# ---------------------------------------------------------------------------
# resume: distribution registry + grid sweep continuation
# ---------------------------------------------------------------------------

def _grid_obj(seen):
    def obj(trial):
        # suggest in NON-sorted name order: pre-fix, the resumed study's
        # empty registry gave "b" the wrong radix on the first trial
        b = trial.suggest_categorical("b", ["p", "q", "r"])
        a = trial.suggest_int("a", 0, 1)
        seen.append((a, b))
        return 0.0
    return obj


def test_grid_resume_continues_sweep(tmp_path):
    path = os.path.join(tmp_path, "grid.jsonl")
    seen = []
    s1 = Study(sampler=GridSampler(), storage=path)
    s1.optimize(_grid_obj(seen), 3)
    assert len(set(seen)) == 3

    s2 = Study(sampler=GridSampler(), storage=path)
    assert s2.distribution_registry.keys() == {"a", "b"}
    s2.optimize(_grid_obj(seen), 3)
    # the resumed study covers the REMAINING half of the 2x3 product —
    # no repeats, no holes
    assert len(seen) == 6
    assert len(set(seen)) == 6


def test_distribution_survives_roundtrip(tmp_path):
    path = os.path.join(tmp_path, "s.jsonl")
    s1 = Study(sampler=RandomSampler(seed=0), storage=path)

    def obj(trial):
        trial.suggest_int("n", 4, 64, step=4, log=True)
        trial.suggest_categorical("c", ["u", "v"])
        return 0.0

    s1.optimize(obj, 2)
    s2 = Study(storage=path)
    d = s2.distribution_registry["n"]
    assert (d.kind, d.low, d.high, d.step, d.log) == ("int", 4, 64, 4, True)
    assert s2.distribution_registry["c"].choices == ("u", "v")


# ---------------------------------------------------------------------------
# disk-persistent cache tier
# ---------------------------------------------------------------------------

def test_disk_cache_roundtrip_between_instances(tmp_path):
    from repro.evaluation import DiskEvaluationCache

    d = str(tmp_path / "store")
    a = DiskEvaluationCache(d)
    key = ("latency_s", "host_cpu", 2, "sig[conv1d]")
    assert a.store(key, 0.125)
    # a second instance simulates a sibling/restarted process
    b = DiskEvaluationCache(d)
    found, value = b.lookup(key)
    assert found and value == 0.125
    # entries appended AFTER construction are found via tail re-scan
    assert a.store(("x",), 1.0)
    found, value = b.lookup(("x",))
    assert found and value == 1.0
    assert len(b) == 2


def test_disk_cache_detects_sibling_truncation(tmp_path):
    """A sibling's clear() truncates the store; instances holding an old
    byte offset must drop their stale view instead of serving it (or
    parsing the regrown file mid-record)."""
    from repro.evaluation import DiskEvaluationCache

    d = str(tmp_path / "store")
    a = DiskEvaluationCache(d)
    a.store(("k1",), 1.0)
    a.store(("k2",), 2.0)
    b = DiskEvaluationCache(d)  # warm-loaded: offset at end of both records
    assert b.lookup(("k1",)) == (True, 1.0)
    a.clear()
    a.store(("k3",), 3.0)  # store is now shorter than b's offset
    assert b.lookup(("k1",)) == (False, None)  # stale view dropped
    assert b.lookup(("k3",)) == (True, 3.0)    # rebuilt view served


def test_disk_cache_skips_unserializable_values(tmp_path):
    from repro.evaluation import DiskEvaluationCache

    d = DiskEvaluationCache(str(tmp_path / "store"))
    assert not d.store(("artifact", "k"), object())  # e.g. a compiled executable
    assert not d.store((object(),), 1.0)             # non-JSON key part
    assert len(d) == 0


def test_cache_disk_tier_read_through_and_write_through(tmp_path):
    d = str(tmp_path / "store")
    calls = []
    c1 = EvaluationCache(disk=d)
    assert c1.get_or_compute(("k", 1), lambda: calls.append(1) or 7.5) == 7.5
    assert calls == [1] and c1.stats.misses == 1
    # a fresh cache over the same store serves the value without compute
    c2 = EvaluationCache(disk=d)
    assert c2.get_or_compute(("k", 1), lambda: calls.append(2) or -1.0) == 7.5
    assert calls == [1]
    assert c2.stats.misses == 0 and c2.stats.disk_hits == 1
    assert c2.stats.hit_rate == 1.0
    # second lookup is a pure memory hit
    assert c2.get_or_compute(("k", 1), lambda: -1.0) == 7.5
    assert c2.stats.hits == 1


def test_disk_cache_warm_restart_zero_compiles(tmp_path):
    """A restarted study over the same store re-uses every compiled value:
    zero XLA compiles, hit rate 1.0, identical values."""
    from repro.hwgen.generator import generate_call_count

    builder = ModelBuilder(SPACE.input_shape, SPACE.output_dim)
    study = Study(sampler=RandomSampler(seed=0))
    m = builder.build(sample_architecture(SPACE, study.ask()))
    d = str(tmp_path / "store")

    lat1 = CompiledLatencyEstimator("host_cpu", batch=1, cache=d, metric="modelled")
    v1 = lat1.estimate(m)
    assert lat1.cache.stats.misses == 2  # artifact + value, both computed
    compiles_after_cold = generate_call_count()

    # "restart": fresh cache + estimator, same store directory
    lat2 = CompiledLatencyEstimator("host_cpu", batch=1, cache=d, metric="modelled")
    assert lat2.estimate(m) == v1
    assert generate_call_count() == compiles_after_cold  # zero new compiles
    assert lat2.cache.stats.misses == 0
    assert lat2.cache.stats.disk_hits == 1  # the scalar; no artifact needed
    assert lat2.cache.stats.hit_rate == 1.0


def test_cache_disk_false_means_memory_only():
    c = EvaluationCache(disk=False)
    assert c.disk is None
    assert c.get_or_compute(("k",), lambda: 1.0) == 1.0


def test_cache_keeps_empty_disk_tier(tmp_path):
    """An EMPTY store instance is falsy via __len__ but must stay wired
    in — dropping it would silently disable persistence on cold hosts."""
    from repro.evaluation import DiskEvaluationCache

    store = DiskEvaluationCache(str(tmp_path / "store"))
    c = EvaluationCache(disk=store)
    assert c.disk is store
    assert c.get_or_compute(("k",), lambda: 2.0) == 2.0
    assert store.lookup(("k",)) == (True, 2.0)  # write-through happened


def test_disk_error_releases_single_flight(tmp_path):
    """A disk-tier I/O failure (store dir deleted mid-run, ENOSPC) must
    release single-flight ownership — not strand waiters forever."""
    cache = EvaluationCache(disk=str(tmp_path / "store"))

    def bad_lookup(key):
        raise OSError("store vanished")

    cache.disk.lookup = bad_lookup
    with pytest.raises(OSError, match="store vanished"):
        cache.get_or_compute(("k",), lambda: 1.0)
    # ownership was released: the next caller owns the key cleanly
    cache.disk.lookup = lambda key: (False, None)
    cache.disk.store = lambda key, value: True
    assert cache.get_or_compute(("k",), lambda: 1.0) == 1.0


# ---------------------------------------------------------------------------
# clear() vs in-flight computes
# ---------------------------------------------------------------------------

def test_clear_drops_inflight_ownership():
    """A compute that finishes after clear() must not resurrect its (now
    stale) entry, and stats stay consistently reset."""
    cache = EvaluationCache()
    started, release, done = threading.Event(), threading.Event(), []

    def compute():
        started.set()
        release.wait(5)
        return "stale"

    t = threading.Thread(target=lambda: done.append(cache.get_or_compute("k", compute)))
    t.start()
    started.wait(5)
    cache.clear()
    release.set()
    t.join(5)
    assert done == ["stale"]  # the in-flight caller still gets its value
    assert len(cache) == 0 and cache.get("k") is None  # ...but nothing cached
    assert cache.stats.as_dict() == {"hits": 0, "disk_hits": 0, "misses": 0,
                                     "hit_rate": 0.0}
    # the key is fully released: a new compute owns it cleanly
    assert cache.get_or_compute("k", lambda: "fresh") == "fresh"
    assert cache.get("k") == "fresh"


# ---------------------------------------------------------------------------
# search-layer persistence bugfixes
# ---------------------------------------------------------------------------

def test_double_tell_raises_and_persists_once(tmp_path):
    path = os.path.join(tmp_path, "s.jsonl")
    s = Study(sampler=RandomSampler(seed=0), storage=path)
    t = s.ask()
    t.suggest_float("x", 0.0, 1.0)
    s.tell(t, 1.0)
    with pytest.raises(RuntimeError, match="already told"):
        s.tell(t, 2.0)
    assert t.values == (1.0,)  # first result stands
    with open(path) as f:
        assert len([l for l in f if l.strip()]) == 1  # no duplicate record


def test_system_attrs_survive_resume(tmp_path):
    path = os.path.join(tmp_path, "s.jsonl")
    s1 = Study(sampler=RandomSampler(seed=0), storage=path)

    def obj(trial):
        trial.suggest_float("x", 0.0, 1.0)
        trial.system_attrs["retries"] = 2
        trial.system_attrs["scheduler"] = {"host": "worker-3"}
        return 0.0

    s1.optimize(obj, 1)
    s2 = Study(storage=path)
    assert s2.trials[0].system_attrs == {"retries": 2, "scheduler": {"host": "worker-3"}}


def test_compile_limit_env_validation(monkeypatch):
    import warnings as _warnings

    from repro.hwgen import generator

    default = max(1, (os.cpu_count() or 2) // 2)
    monkeypatch.setenv("REPRO_COMPILE_CONCURRENCY", "two")
    with pytest.warns(RuntimeWarning, match="REPRO_COMPILE_CONCURRENCY"):
        assert generator._compile_limit() == default
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")  # the valid forms must not warn
        monkeypatch.setenv("REPRO_COMPILE_CONCURRENCY", "")
        assert generator._compile_limit() == default  # unset-equivalent
        monkeypatch.setenv("REPRO_COMPILE_CONCURRENCY", "0")
        assert generator._compile_limit() == 1  # valid int, clamped
        monkeypatch.setenv("REPRO_COMPILE_CONCURRENCY", "3")
        assert generator._compile_limit() == 3
        monkeypatch.delenv("REPRO_COMPILE_CONCURRENCY")
        assert generator._compile_limit() == default


# ---------------------------------------------------------------------------
# suggest_int(log=True) respects step
# ---------------------------------------------------------------------------

def test_int_log_suggestion_respects_step():
    study = Study(sampler=RandomSampler(seed=0))
    values = set()
    for _ in range(60):
        t = study.ask()
        v = t.suggest_int("n", 4, 64, step=4, log=True)
        values.add(v)
        study.tell(t, 0.0)
    assert all(4 <= v <= 64 and (v - 4) % 4 == 0 for v in values)
    assert len(values) > 3  # still exploring the range, not collapsed


# ---------------------------------------------------------------------------
# disk-cache compaction: size-capped LRU, superseded-salt records first
# ---------------------------------------------------------------------------

def _fake_salted_record(key_tuple, value, toolchain):
    """A record whose key carries an arbitrary toolchain salt — what a
    run under a different jax/jaxlib would have appended."""
    import json

    key = json.dumps({"key": list(key_tuple), "toolchain": toolchain},
                     sort_keys=True, separators=(",", ":"))
    return json.dumps({"key": key, "value": value}) + "\n"


def test_disk_cache_compaction_drops_superseded_salt_first(tmp_path):
    from repro.evaluation import DiskEvaluationCache
    from repro.ioutils import locked_append

    d = str(tmp_path / "store")
    cache = DiskEvaluationCache(d, max_entries=4)
    # plant records from a superseded toolchain directly in the file
    import os

    path = os.path.join(d, cache.FILENAME)
    for i in range(3):
        locked_append(path, _fake_salted_record(
            ("old", i), float(i), {"jax": "0.0.1", "jaxlib": "0.0.1"}))
    # current-salt stores push the file over the cap -> compaction
    for i in range(5):
        assert cache.store(("cur", i), float(i))
    assert cache.compactions >= 1
    assert cache.dropped_superseded >= 3  # every old-salt record gone
    # the file holds at most max_entries records, all current-salt
    with open(path) as f:
        lines = [line for line in f if line.strip()]
    assert len(lines) <= 4
    assert all('"old"' not in line for line in lines)
    # surviving values still served
    found, value = cache.lookup(("cur", 4))
    assert found and value == 4.0


def test_disk_cache_compaction_lru_keeps_recently_used(tmp_path):
    from repro.evaluation import DiskEvaluationCache

    cache = DiskEvaluationCache(str(tmp_path / "store"), max_entries=3)
    for i in range(3):
        cache.store(("k", i), float(i))
    # touch k0 so it ranks most-recent before the cap-tripping store
    assert cache.lookup(("k", 0)) == (True, 0.0)
    cache.store(("k", 3), 3.0)  # 4 > 3 -> compacts, evicting LRU k1
    assert cache.dropped_lru >= 1
    assert cache.lookup(("k", 0)) == (True, 0.0)   # recently used: kept
    assert cache.lookup(("k", 3)) == (True, 3.0)   # newest: kept
    assert cache.lookup(("k", 1)) == (False, None)  # LRU: evicted
    stats = cache.stats()
    assert stats["compactions"] == cache.compactions
    assert stats["disk_entries"] == 3


def test_disk_cache_sibling_survives_compaction(tmp_path):
    """A sibling holding an offset past the rewritten file's end must
    drop its stale view (same truncation-detection path as clear())."""
    from repro.evaluation import DiskEvaluationCache

    d = str(tmp_path / "store")
    a = DiskEvaluationCache(d, max_entries=3)
    for i in range(3):
        a.store(("k", i), float(i))
    b = DiskEvaluationCache(d)  # warm-loaded at full length
    a.store(("k", 3), 3.0)  # compacts: file shrinks below b's offset
    found, value = b.lookup(("k", 3))
    assert found and value == 3.0


def test_disk_cache_max_entries_env(tmp_path, monkeypatch):
    from repro.evaluation import DiskEvaluationCache

    monkeypatch.setenv("REPRO_CACHE_MAX_ENTRIES", "2")
    cache = DiskEvaluationCache(str(tmp_path / "store"))
    assert cache.max_entries == 2
    for i in range(4):
        cache.store(("k", i), float(i))
    assert cache.compactions >= 1
    assert len(cache) <= 2

    monkeypatch.setenv("REPRO_CACHE_MAX_ENTRIES", "not-a-number")
    with pytest.warns(RuntimeWarning, match="REPRO_CACHE_MAX_ENTRIES"):
        unbounded = DiskEvaluationCache(str(tmp_path / "store2"))
    assert unbounded.max_entries is None


def test_disk_cache_no_spurious_compaction_below_cap(tmp_path):
    """Regression: the on-disk record count must not double-count this
    process's own appends (store used to bump a counter the next tail
    re-scan counted again, firing full-file rewrites at ~half the cap)."""
    from repro.evaluation import DiskEvaluationCache

    cache = DiskEvaluationCache(str(tmp_path / "store"), max_entries=10)
    for i in range(10):
        cache.store(("k", i), float(i))
        cache.lookup(("k", i))  # interleave reads like the miss->store path
    assert cache.compactions == 0
    assert len(cache) == 10
