"""Fault-tolerance utilities + data pipeline + generator reflection."""
import time

import jax
import numpy as np
import pytest

from repro.data.pipeline import Prefetcher, SyntheticClassificationData, SyntheticLMData
from repro.distributed.fault import StragglerMonitor, elastic_remesh, with_retries
from repro.hwgen.generator import XLAGenerator
from repro.hwgen.targets import get_target


def test_synthetic_lm_determinism_by_step():
    """Any host can regenerate any step's batch — the property elastic
    re-assignment and restarts rely on."""
    a = SyntheticLMData(vocab=128, seq=16, global_batch=4, seed=7)
    b = SyntheticLMData(vocab=128, seq=16, global_batch=4, seed=7)
    for step in (0, 3, 11):
        np.testing.assert_array_equal(a.batch_at(step)["tokens"], b.batch_at(step)["tokens"])
    assert not np.array_equal(a.batch_at(0)["tokens"], a.batch_at(1)["tokens"])


def test_synthetic_lm_host_sharding_disjoint():
    h0 = SyntheticLMData(vocab=128, seq=16, global_batch=8, n_hosts=2, host_id=0)
    h1 = SyntheticLMData(vocab=128, seq=16, global_batch=8, n_hosts=2, host_id=1)
    b0, b1 = h0.batch_at(5), h1.batch_at(5)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_prefetcher_orders_steps_and_resumes():
    data = SyntheticLMData(vocab=64, seq=8, global_batch=2)
    pf = Prefetcher(data, start_step=10)
    steps = [pf.next()[0] for _ in range(4)]
    pf.close()
    assert steps == [10, 11, 12, 13]
    np.testing.assert_array_equal(
        data.batch_at(10)["tokens"],
        SyntheticLMData(vocab=64, seq=8, global_batch=2).batch_at(10)["tokens"],
    )


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(window=16, threshold=2.0)
    for _ in range(8):
        assert not mon.record(0.1)
    assert mon.record(1.0)  # 10x the median
    assert mon.flags == 1


def test_with_retries_recovers():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    assert with_retries(flaky, retries=3, backoff=0.0)() == "ok"
    assert calls["n"] == 3


def test_with_retries_exhausts():
    def dead():
        raise RuntimeError("permanent")

    with pytest.raises(RuntimeError):
        with_retries(dead, retries=1, backoff=0.0)()


def test_elastic_remesh_fits_device_pool():
    mesh = elastic_remesh((16, 16), ("data", "model"))
    n = len(jax.devices())
    assert int(np.prod(mesh.devices.shape)) <= n
    assert mesh.axis_names == ("data", "model")


def test_generator_reflection_capabilities():
    gen = XLAGenerator("host_cpu")
    caps = gen.capabilities()
    assert caps["pallas"] is False  # host backend reports no Pallas
    assert "linear" in caps["ops"] and "conv1d" in caps["ops"]
    tpu = get_target("tpu_v5e_pod")
    assert tpu.supports_pallas and tpu.n_chips == 256
    assert tpu.chip.hbm_bytes == 16 * 1024 ** 3


def test_classification_data_learnable_structure():
    """Class-dependent amplitude must be visible to a trivial statistic."""
    data = SyntheticClassificationData(n=200, length=64, channels=2, classes=4, seed=1)
    power = (data.x ** 2).mean(axis=(1, 2))
    lo = power[data.y == 0].mean()
    hi = power[data.y == 3].mean()
    assert hi > lo * 1.5
