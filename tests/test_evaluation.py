"""Evaluation API: staged criteria, scalarization, HIL estimators."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.builder import ModelBuilder
from repro.core.space import parse_search_space
from repro.core.translate import sample_architecture
from repro.evaluation import (
    CompiledLatencyEstimator,
    CriteriaRunner,
    Estimator,
    FlopsEstimator,
    OptimizationCriteria,
    ParamCountEstimator,
)
from repro.search import HardConstraintViolated, RandomSampler, Study

SPACE = parse_search_space("""
input: [2, 64]
output: 3
sequence:
  - block: "c"
    op_candidates: "conv1d"
  - block: "h"
    op_candidates: "linear"
default_op_params:
  conv1d:
    kernel_size: [3]
    out_channels: [4]
""")


def _model(seed=0):
    study = Study(sampler=RandomSampler(seed=seed))
    arch = sample_architecture(SPACE, study.ask())
    return ModelBuilder(SPACE.input_shape, SPACE.output_dim).build(arch)


class CountingEstimator(Estimator):
    def __init__(self, name, value):
        self.name = name
        self.value = value
        self.calls = 0

    def estimate(self, candidate, context=None):
        self.calls += 1
        return self.value


def test_hard_constraint_stops_staged_evaluation():
    hard = CountingEstimator("hard_cost", 100.0)
    obj = CountingEstimator("obj_cost", 1.0)
    runner = CriteriaRunner([
        OptimizationCriteria(obj, kind="objective"),
        OptimizationCriteria(hard, kind="hard_constraint", limit=10.0),
    ])
    with pytest.raises(HardConstraintViolated):
        runner.evaluate(_model())
    assert hard.calls == 1
    assert obj.calls == 0  # never evaluated — early termination


def test_weighted_sum_and_soft_constraint():
    obj = CountingEstimator("o", 2.0)
    soft = CountingEstimator("s", 15.0)  # limit 10 -> violation 0.5
    runner = CriteriaRunner([
        OptimizationCriteria(obj, kind="objective", weight=1.0),
        OptimizationCriteria(soft, kind="soft_constraint", limit=10.0, weight=2.0),
    ])
    score = runner.evaluate(_model())
    assert score == pytest.approx(2.0 + 2.0 * 0.5)


def test_soft_constraint_no_penalty_below_limit():
    soft = CountingEstimator("s", 5.0)
    runner = CriteriaRunner([OptimizationCriteria(soft, kind="soft_constraint", limit=10.0)])
    assert runner.evaluate(_model()) == 0.0


def test_custom_aggregator_injection():
    a = CountingEstimator("a", 3.0)
    b = CountingEstimator("b", 4.0)
    runner = CriteriaRunner(
        [OptimizationCriteria(a), OptimizationCriteria(b)],
        aggregator=lambda values, crit: max(values.values()),
    )
    assert runner.evaluate(_model()) == 4.0


def test_maximize_objective_sign():
    acc = CountingEstimator("acc", 0.9)
    runner = CriteriaRunner([OptimizationCriteria(acc, direction="maximize")])
    assert runner.evaluate(_model()) == pytest.approx(-0.9)


def test_analytical_estimators_match_model():
    m = _model()
    assert ParamCountEstimator().estimate(m) == float(m.n_params)
    assert FlopsEstimator().estimate(m) == float(m.flops)
    assert m.n_params > 0 and m.flops > 0


def test_hardware_in_the_loop_latency_on_host():
    est = CompiledLatencyEstimator("host_cpu", batch=2)
    m = _model()
    latency = est.estimate(m)
    assert 0 < latency < 5.0
    # cached by signature: second call is instant and identical
    assert est.estimate(m) == latency


def test_multiobjective_evaluation():
    a = CountingEstimator("a", 1.0)
    b = CountingEstimator("b", 2.0)
    runner = CriteriaRunner([
        OptimizationCriteria(a, kind="objective"),
        OptimizationCriteria(b, kind="objective"),
    ])
    assert runner.evaluate_multi(_model()) == (1.0, 2.0)
