"""Checkpointing: roundtrip, atomicity, retention, async, resharding."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros((4,))},
        "opt": {"step": jnp.asarray(3, jnp.int32), "mu": {"w": jnp.ones((8, 4))}},
    }


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(10, tree)
    step, restored = ck.restore(like=tree)
    assert step == 10
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        tree, restored)


def test_retention_keeps_newest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    assert ck.all_steps() == [3, 4]


def test_async_save_then_wait(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save_async(5, tree)
    ck.save_async(6, tree)
    ck.wait()
    assert ck.latest_step() == 6


def test_atomicity_tmp_dirs_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(1, tree)
    # simulate a writer dying mid-checkpoint
    os.makedirs(os.path.join(tmp_path, "step_0000000002.tmp"))
    with open(os.path.join(tmp_path, "step_0000000002.tmp", "junk"), "w") as f:
        f.write("partial")
    assert ck.latest_step() == 1
    step, _ = ck.restore(like=tree)
    assert step == 1


def test_restore_missing_leaf_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"a": jnp.zeros((2,))})
    with pytest.raises(KeyError, match="missing leaf"):
        ck.restore(like={"a": jnp.zeros((2,)), "b": jnp.zeros((3,))})


def test_restore_with_resharding(tmp_path):
    """Elastic restore: host arrays re-placed under a new sharding."""
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.launch.mesh import make_host_mesh

    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(7, tree)
    mesh = make_host_mesh()
    sh = {"w": NamedSharding(mesh, PartitionSpec("data", None))}
    step, restored = ck.restore(like=tree, shardings=sh)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert restored["w"].sharding == sh["w"]


def test_resume_after_simulated_crash(tmp_path):
    """kill -9 between saves: latest complete checkpoint restores."""
    ck = Checkpointer(str(tmp_path), keep=5)
    tree = _tree()
    ck.save(10, tree)
    ck.save(20, tree)
    # a half-written (crashed) newer step
    tmp = os.path.join(tmp_path, "step_0000000030.tmp")
    os.makedirs(tmp)
    ck2 = Checkpointer(str(tmp_path), keep=5)
    assert ck2.latest_step() == 20
