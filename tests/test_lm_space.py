"""LM search spaces (DSL -> ModelSpec -> executable LM)."""
import os

import jax
import jax.numpy as jnp
import pytest

from repro.core.lm_space import LMSpaceBuilder
from repro.core.space import parse_search_space_file
from repro.core.translate import sample_architecture
from repro.models.lm import LM
from repro.nn.types import split
from repro.search import RandomSampler, Study

SPACES_DIR = os.path.join(os.path.dirname(__file__), "..", "src", "repro", "configs", "spaces")


@pytest.mark.parametrize("space_file", ["qwen3_like.yaml", "hybrid_like.yaml", "moe_like.yaml"])
def test_lm_space_samples_and_builds(space_file):
    space = parse_search_space_file(os.path.join(SPACES_DIR, space_file))
    study = Study(sampler=RandomSampler(seed=0))
    builder = LMSpaceBuilder(d_model=64, vocab=256)  # reduced width for CPU
    for _ in range(3):
        arch = sample_architecture(space, study.ask())
        spec = builder.build(arch)
        assert spec.n_layers == len(arch.layers)
        model = LM(spec)
        params, _ = split(model.init(jax.random.PRNGKey(0), dtype=jnp.float32))
        toks = jnp.zeros((1, 8), jnp.int32)
        logits = model.apply(params, toks)
        assert logits.shape == (1, 8, 256)
        assert jnp.isfinite(logits).all()


def test_identity_sample_matches_qwen3_family():
    """The space's identity point reproduces the qwen3-1.7b layer config."""
    from repro.configs import get_arch

    space = parse_search_space_file(os.path.join(SPACES_DIR, "qwen3_like.yaml"))
    study = Study(sampler=RandomSampler(seed=0))
    # force the identity choices
    trial = study.ask()
    trial.params.update({
        "backbone.depth": 28,
        "backbone.transformer_layer.kv_heads": 8,
        "backbone.transformer_layer.d_ff": 6144,
    })
    arch = sample_architecture(space, trial)
    spec = LMSpaceBuilder(d_model=2048, vocab=151936).build(arch)
    ref = get_arch("qwen3-1.7b").spec()
    assert spec.n_layers == ref.n_layers == 28
    got_attn = spec.layers[0].subs[0].cfg
    want_attn = ref.layers[0].subs[0].cfg
    assert got_attn.n_heads == want_attn.n_heads
    assert got_attn.n_kv_heads == want_attn.n_kv_heads
    assert got_attn.qk_norm == want_attn.qk_norm
    assert spec.layers[0].subs[1].cfg.d_ff == ref.layers[0].subs[1].cfg.d_ff
