"""§Perf optimization paths must be EXACT reformulations (same math)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.lm import LM
from repro.models.specs import ModelSpec, transformer_layer
from repro.nn.attention import chunked_attention, grouped_attention, make_mask
from repro.nn.types import split
from repro.train.step import make_loss_fn, make_prefill_step

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("kv_chunk,unroll", [(32, False), (64, True), (128, False)])
def test_chunked_attention_matches_full(kv_chunk, unroll):
    ks = jax.random.split(KEY, 3)
    b, s, h, k_, d = 2, 128, 4, 2, 32
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, k_, d))
    v = jax.random.normal(ks[2], (b, s, k_, d))
    full = grouped_attention(q, k, v, make_mask(s, s, True, None), d ** -0.5)
    chunk = chunked_attention(q, k, v, d ** -0.5, causal=True,
                              kv_chunk=kv_chunk, unroll=unroll)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunk), atol=2e-5, rtol=2e-5)


def test_chunked_attention_window():
    ks = jax.random.split(KEY, 3)
    b, s, h, d = 1, 128, 2, 16
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    full = grouped_attention(q, k, v, make_mask(s, s, True, 24), d ** -0.5)
    chunk = chunked_attention(q, k, v, d ** -0.5, causal=True, window=24, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunk), atol=2e-5, rtol=2e-5)


def _tiny_model(tie=True):
    spec = ModelSpec(name="t", d_model=32, vocab=64,
                     layers=(transformer_layer(32, 2, 2, 64),) * 2,
                     tie_embeddings=tie, remat=False)
    model = LM(spec)
    params, _ = split(model.init(KEY, jnp.float32))
    return model, params


@pytest.mark.parametrize("tie", [True, False])
def test_chunked_loss_matches_full(tie):
    model, params = _tiny_model(tie)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 64),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, 64),
    }
    full = make_loss_fn(model)(params, batch)
    for chunk in (8, 16):
        got = make_loss_fn(model, loss_chunk=chunk)(params, batch)
        np.testing.assert_allclose(float(full), float(got), rtol=1e-5)
    # unrolled variant identical too
    got_u = make_loss_fn(model, loss_chunk=8, loss_unroll=True)(params, batch)
    np.testing.assert_allclose(float(full), float(got_u), rtol=1e-5)


def test_chunked_loss_gradients_match():
    model, params = _tiny_model()
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, 64),
    }
    g_full = jax.grad(make_loss_fn(model))(params, batch)
    g_chunk = jax.grad(make_loss_fn(model, loss_chunk=4))(params, batch)
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g_full, g_chunk)
    assert max(jax.tree_util.tree_leaves(diffs)) < 1e-5


def test_last_logit_prefill_matches_full_last_position():
    model, params = _tiny_model()
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)}
    full = make_prefill_step(model, last_only=False)(params, batch)
    last = make_prefill_step(model, last_only=True)(params, batch)
    np.testing.assert_allclose(np.asarray(full[:, -1:]), np.asarray(last),
                               atol=2e-5, rtol=2e-5)


def test_remat_dots_same_loss():
    import dataclasses

    spec = ModelSpec(name="t", d_model=32, vocab=64,
                     layers=(transformer_layer(32, 2, 2, 64),) * 3,
                     remat=True)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, 64),
    }
    losses = {}
    for policy in (None, "dots"):
        m = LM(dataclasses.replace(spec, remat_policy=policy))
        params, _ = split(m.init(KEY, jnp.float32))
        losses[policy] = float(make_loss_fn(m)(params, batch))
    np.testing.assert_allclose(losses[None], losses["dots"], rtol=1e-6)


def test_moe_2d_sharding_axes():
    """shard_ff flips expert-weight logical axes (2D expert sharding)."""
    from repro.nn.moe import MoEConfig, moe_init
    from repro.nn.types import split as split_tree

    base = moe_init(MoEConfig(16, 32, 4, 2), KEY)
    twod = moe_init(MoEConfig(16, 32, 4, 2, shard_ff=True), KEY)
    _, ax_base = split_tree(base)
    _, ax_2d = split_tree(twod)
    assert ax_base["w_up"] == ("experts", "embed", "mlp")
    assert ax_2d["w_up"] == ("experts", None, "expert_mlp")
    # numerics identical
    vb, _ = split_tree(base)
    v2, _ = split_tree(twod)
    np.testing.assert_array_equal(np.asarray(vb["w_up"]), np.asarray(v2["w_up"]))
