"""Repeat-mode semantics (paper Table I), adapters, dynamic construction."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.builder import ModelBuilder
from repro.core.registry import get_transition
from repro.core.space import parse_search_space
from repro.core.translate import sample_architecture
from repro.search import RandomSampler, Study


def _sample(yaml_text, seed=0):
    space = parse_search_space(yaml_text)
    study = Study(sampler=RandomSampler(seed=seed))
    trial = study.ask()
    return space, trial, sample_architecture(space, trial)


BASE = """
input: [2, 64]
output: 3
sequence:
  - block: "body"
    op_candidates: "conv1d"
    type_repeat:
      type: "{mode}"
      depth: 4
default_op_params:
  conv1d:
    kernel_size: [3, 5, 7]
    out_channels: [4, 8, 16]
"""


def test_repeat_params_shares_everything():
    space, trial, arch = _sample(BASE.format(mode="repeat_params"), seed=3)
    assert len(arch.layers) == 4
    assert len({(l.params["kernel_size"], l.params["out_channels"]) for l in arch.layers}) == 1


def test_repeat_op_same_op_params_may_vary():
    found_varied = False
    for seed in range(8):
        space, trial, arch = _sample(BASE.format(mode="repeat_op"), seed=seed)
        assert len(arch.layers) == 4
        assert len({l.op for l in arch.layers}) == 1
        if len({str(l.params) for l in arch.layers}) > 1:
            found_varied = True
    assert found_varied, "repeat_op should resample params per layer"


def test_vary_all_can_vary_ops():
    y = """
input: [2, 64]
output: 3
sequence:
  - block: "body"
    op_candidates: ["conv1d", "maxpool"]
    type_repeat:
      type: "vary_all"
      depth: 6
default_op_params:
  conv1d:
    kernel_size: [3]
    out_channels: [4]
  maxpool:
    window: [2]
"""
    ops_seen = set()
    for seed in range(6):
        _, _, arch = _sample(y, seed=seed)
        assert len(arch.layers) == 6
        ops_seen |= {l.op for l in arch.layers}
    assert ops_seen == {"conv1d", "maxpool"}


def test_repeat_block_copies_sampled_config():
    y = """
input: [2, 64]
output: 3
sequence:
  - block: "a"
    op_candidates: "conv1d"
  - block: "b"
    op_candidates: "conv1d"
    type_repeat:
      type: "repeat_block"
      ref_block: "a"
      depth: 3
default_op_params:
  conv1d:
    kernel_size: [3, 5, 7]
    out_channels: [4, 8, 16]
"""
    _, _, arch = _sample(y, seed=1)
    assert len(arch.layers) == 4  # 1 (a) + 3 (repeats)
    first = arch.layers[0]
    for l in arch.layers[1:]:
        assert l.op == first.op and l.params == first.params


def test_depth_choices_sampled():
    y = BASE.format(mode="repeat_op").replace("depth: 4", "depth: [2, 5]")
    depths = set()
    for seed in range(12):
        _, _, arch = _sample(y, seed=seed)
        depths.add(len(arch.layers))
    assert depths <= {2, 5} and len(depths) == 2


def test_adapter_inserted_between_formats():
    y = """
input: [2, 64]
output: 3
sequence:
  - block: "c"
    op_candidates: "conv1d"
  - block: "h"
    op_candidates: "linear"
    linear:
      width: [8]
default_op_params:
  conv1d:
    kernel_size: [3]
    out_channels: [4]
"""
    space, trial, arch = _sample(y)
    model = ModelBuilder(space.input_shape, space.output_dim).build(arch)
    names = [l.name for l in model.layers]
    assert any(n.startswith("adapter/flatten") for n in names)
    x = jnp.zeros((2, 64, 2))
    params = model.init(jax.random.PRNGKey(0))
    assert model.apply(params, x).shape == (2, 3)


def test_unregistered_transition_raises():
    with pytest.raises(KeyError):
        get_transition("BF", "nonexistent")


def test_reflection_masks_unsupported_ops():
    y = """
input: [2, 64]
output: 3
sequence:
  - block: "body"
    op_candidates: ["conv1d", "attention"]
default_op_params:
  conv1d:
    kernel_size: [3]
    out_channels: [4]
  attention:
    heads: [2]
"""
    space = parse_search_space(y)
    study = Study(sampler=RandomSampler(seed=0))
    for _ in range(6):
        arch = sample_architecture(space, study.ask(), allowed_ops={"conv1d"})
        assert all(l.op == "conv1d" for l in arch.layers)


def test_shape_inference_through_strided_stack():
    y = """
input: [2, 64]
output: 5
sequence:
  - block: "body"
    op_candidates: "conv1d"
    type_repeat:
      type: "repeat_params"
      depth: 3
    conv1d:
      kernel_size: [3]
      out_channels: [6]
      stride: [2]
"""
    space, trial, arch = _sample(y)
    model = ModelBuilder(space.input_shape, space.output_dim).build(arch)
    # 64 -> 32 -> 16 -> 8 under stride 2 SAME
    conv_shapes = [l.out_shape for l in model.layers if l.name.startswith("conv1d")]
    assert conv_shapes == [(32, 6), (16, 6), (8, 6)]
    x = jnp.zeros((1, 64, 2))
    params = model.init(jax.random.PRNGKey(0))
    assert model.apply(params, x).shape == (1, 5)
