"""Training substrate: optimizers, loss, grad accumulation, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import GradientCompressor
from repro.train.loss import chunked_cross_entropy, cross_entropy, shift_labels
from repro.train.optimizer import (
    Optimizer,
    OptimizerConfig,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
)


@pytest.mark.parametrize("name", ["adamw", "sgd", "adafactor"])
def test_optimizer_decreases_quadratic(name):
    opt = Optimizer(OptimizerConfig(name=name, learning_rate=0.1, weight_decay=0.0,
                                    grad_clip_norm=None))
    params = {"w": jnp.asarray([3.0, -2.0]), "m": jnp.ones((2, 2))}
    state = opt.init(params)

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum((p["m"] - 0.5) ** 2)

    loss0 = float(loss_fn(params))
    for _ in range(30):
        grads = jax.grad(loss_fn)(params)
        params, state, _ = opt.update(grads, state, params)
    assert float(loss_fn(params)) < loss0 * 0.2, name


def test_grad_clip_norm():
    grads = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_warmup_and_decay():
    fn = cosine_schedule(1.0, warmup=10, total=100, min_ratio=0.1)
    assert float(fn(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(fn(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(fn(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-3)


def test_chunked_cross_entropy_matches_full():
    k = jax.random.PRNGKey(0)
    b, s, d, v = 2, 32, 16, 64
    h = jax.random.normal(k, (b, s, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (d, v)) * 0.1
    labels = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, v)
    full = cross_entropy(h @ w, labels)
    chunked = chunked_cross_entropy(h, w, labels, chunk=8)
    np.testing.assert_allclose(float(full), float(chunked), rtol=1e-5)


def test_shift_labels_masks_last():
    tokens = jnp.arange(10).reshape(1, 10)
    labels, mask = shift_labels(tokens)
    np.testing.assert_array_equal(np.asarray(labels[0, :-1]), np.arange(1, 10))
    assert float(mask[0, -1]) == 0.0


def test_grad_accumulation_equivalence():
    """microbatches=4 must produce (numerically close) identical updates."""
    from repro.models.lm import LM
    from repro.models.specs import ModelSpec, transformer_layer
    from repro.nn.types import split
    from repro.train.step import make_train_step

    spec = ModelSpec(name="t", d_model=32, vocab=64,
                     layers=(transformer_layer(32, 2, 2, 64),) * 2, remat=False)
    model = LM(spec)
    params, _ = split(model.init(jax.random.PRNGKey(0), dtype=jnp.float32))
    opt = Optimizer(OptimizerConfig(name="sgd", learning_rate=0.1, grad_clip_norm=None,
                                    weight_decay=0.0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 64),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, 64),
    }
    p1, _, m1 = jax.jit(make_train_step(model, opt))(params, opt.init(params), batch)
    p4, _, m4 = jax.jit(make_train_step(model, opt, microbatches=4))(params, opt.init(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
    diffs = jax.tree_util.tree_map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4)
    assert max(jax.tree_util.tree_leaves(diffs)) < 1e-4


def test_compression_error_feedback_bounded():
    comp = GradientCompressor()
    k = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(k, (1000,))}
    err = comp.init_state(grads)
    out, err = comp.compress_decompress(grads, err)
    # int8 block quantization: elementwise error bounded by scale/2
    scale = float(jnp.max(jnp.abs(grads["w"]))) / 127
    assert float(jnp.max(jnp.abs(out["w"] - grads["w"]))) <= scale * 1.01
    # error feedback: residual carried, not lost
    assert float(jnp.max(jnp.abs(err["w"]))) > 0


def test_compression_error_feedback_unbiased_over_steps():
    """Accumulated (quantized) updates converge to accumulated true grads."""
    comp = GradientCompressor()
    g = {"w": jnp.asarray([0.001, -0.003, 0.5, 1.0])}  # tiny + large entries
    err = comp.init_state(g)
    total = jnp.zeros((4,))
    for _ in range(50):
        out, err = comp.compress_decompress(g, err)
        total = total + out["w"]
    avg = total / 50
    np.testing.assert_allclose(np.asarray(avg), np.asarray(g["w"]), atol=2e-3)


def test_train_loss_decreases_end_to_end():
    """~100-step training on structured synthetic data reduces loss."""
    from repro.data.pipeline import SyntheticLMData
    from repro.models.lm import LM
    from repro.models.specs import ModelSpec, transformer_layer
    from repro.nn.types import split
    from repro.train.step import make_train_step

    spec = ModelSpec(name="t", d_model=64, vocab=128,
                     layers=(transformer_layer(64, 4, 2, 128),) * 2, remat=False)
    model = LM(spec)
    params, _ = split(model.init(jax.random.PRNGKey(0), dtype=jnp.float32))
    opt = Optimizer(OptimizerConfig(name="adamw", learning_rate=3e-3))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    data = SyntheticLMData(vocab=128, seq=32, global_batch=8)
    losses = []
    for i in range(60):
        _, batch = (i, data.batch_at(i))
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) * 0.9
