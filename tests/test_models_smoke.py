"""Per-arch smoke tests: reduced same-family config, one forward + one
train step on CPU, asserting output shapes and finiteness (assignment
requirement f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models.lm import LM
from repro.nn.types import split
from repro.train.optimizer import Optimizer, OptimizerConfig
from repro.train.step import make_train_step

ARCH_NAMES = sorted(ARCHS)


def _batch_for(arch, spec, b=2, s=16):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": rng.integers(0, spec.vocab, (b, s)).astype(np.int32),
        "labels": rng.integers(0, spec.vocab, (b, s)).astype(np.int32),
    }
    if arch.batch_kind == "encdec":
        batch["frames"] = rng.standard_normal((b, s, spec.d_model)).astype(np.float32)
    if arch.batch_kind == "vlm":
        npfx = min(spec.num_prefix_tokens, s // 2)
        batch["patch_embeds"] = rng.standard_normal((b, npfx, spec.d_model)).astype(np.float32)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward(name):
    arch = get_arch(name)
    spec = arch.smoke_spec_fn()
    model = LM(spec)
    params, _ = split(model.init(jax.random.PRNGKey(0), dtype=jnp.float32))
    batch = _batch_for(arch, spec)
    if arch.batch_kind == "encdec":
        enc = model.encode(params, jnp.asarray(batch["frames"]))
        logits = model.apply(params, batch["tokens"], enc_out=enc)
    elif arch.batch_kind == "vlm":
        logits = model.apply(params, batch["tokens"], prefix_embeds=jnp.asarray(batch["patch_embeds"]))
    else:
        logits = model.apply(params, batch["tokens"])
    assert logits.shape == (2, 16, spec.vocab)
    assert jnp.isfinite(logits).all(), f"{name}: non-finite logits"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_train_step(name):
    arch = get_arch(name)
    spec = arch.smoke_spec_fn()
    model = LM(spec)
    params, _ = split(model.init(jax.random.PRNGKey(0), dtype=jnp.float32))
    opt = Optimizer(OptimizerConfig(name="adamw", learning_rate=1e-3))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    batch = _batch_for(arch, spec)
    new_params, new_opt, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually changed
    moved = jax.tree_util.tree_reduce(
        lambda acc, pair: acc or bool(jnp.any(pair)),
        jax.tree_util.tree_map(lambda a, b: jnp.any(a != b), params, new_params),
        False,
    )
    assert moved, f"{name}: train step did not update params"


@pytest.mark.parametrize("name", ["qwen3-1.7b", "zamba2-2.7b", "xlstm-1.3b", "whisper-medium"])
def test_smoke_decode_step(name):
    arch = get_arch(name)
    spec = arch.smoke_spec_fn()
    model = LM(spec)
    params, _ = split(model.init(jax.random.PRNGKey(0), dtype=jnp.float32))
    enc_out = None
    if arch.batch_kind == "encdec":
        enc_out = model.encode(params, jnp.zeros((2, 8, spec.d_model)))
    cache = model.init_cache(params, 2, 16, enc_out=enc_out, dtype=jnp.float32)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache = model.decode(params, cache, tok, 0)
    assert logits.shape == (2, 1, spec.vocab)
    assert jnp.isfinite(logits).all()


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_bf16_dtype_discipline(name):
    """bf16 params must not leak f32 into the residual stream (scan
    carries reject dtype drift — this guards the dry-run configs)."""
    arch = get_arch(name)
    spec = arch.smoke_spec_fn()
    model = LM(spec)
    params, _ = split(model.init(jax.random.PRNGKey(0), dtype=jnp.bfloat16))
    toks = jnp.zeros((2, 16), jnp.int32)
    if arch.batch_kind == "encdec":
        enc = model.encode(params, jnp.zeros((2, 16, spec.d_model), jnp.bfloat16))
        logits = model.apply(params, toks, enc_out=enc)
    elif arch.batch_kind == "vlm":
        logits = model.apply(params, toks,
                             prefix_embeds=jnp.zeros((2, 4, spec.d_model), jnp.bfloat16))
    else:
        logits = model.apply(params, toks)
    assert jnp.isfinite(logits.astype(jnp.float32)).all()


def test_full_specs_construct_without_allocation():
    """Full (non-smoke) configs must build ShapeDtypeStructs quickly."""
    import functools

    for name in ARCH_NAMES:
        arch = get_arch(name)
        spec = arch.spec()
        model = LM(spec)
        sds = jax.eval_shape(functools.partial(model.init, dtype=jnp.bfloat16),
                             jax.random.PRNGKey(0))
        params, _ = split(sds)
        n = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
        assert n > 1e8, f"{name}: full config suspiciously small ({n:,})"


def test_param_counts_near_published():
    """Sanity: derived param counts are in the right ballpark."""
    import functools

    expect = {
        "qwen3-1.7b": (1.4e9, 2.3e9),
        "phi4-mini-3.8b": (3.3e9, 4.2e9),
        "nemotron-4-340b": (3.0e11, 4.0e11),
        "qwen1.5-4b": (3.0e9, 5.0e9),
        "dbrx-132b": (1.1e11, 1.5e11),
        "arctic-480b": (4.0e11, 5.5e11),
        "paligemma-3b": (2.0e9, 3.5e9),
        # per-head block-diagonal qkv (official BlockLinear); the remaining
        # delta vs 1.3B is the assignment's unverified-config headroom
        "xlstm-1.3b": (1.2e9, 2.3e9),
        "zamba2-2.7b": (2.0e9, 3.4e9),
        # + learned 64k-position tables for the 32k decode cells
        "whisper-medium": (5.5e8, 1.0e9),
    }
    for name, (lo, hi) in expect.items():
        arch = get_arch(name)
        model = LM(arch.spec())
        sds = jax.eval_shape(functools.partial(model.init, dtype=jnp.bfloat16),
                             jax.random.PRNGKey(0))
        params, _ = split(sds)
        n = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
        assert lo <= n <= hi, f"{name}: {n:,} outside [{lo:,.0f}, {hi:,.0f}]"
