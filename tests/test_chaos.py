"""Deterministic chaos: the fault-injection plan language, the seeded
schedules it produces, and the crash-safety invariants the storage,
cache, and remote layers promise under that schedule — torn-tail study
recovery, CRC-checked cache records surviving bit rot and compaction
races, poison-trial quarantine on both local-process and remote pools,
graceful daemon shutdown, worker rejoin, and fixed-seed best-trial
parity between chaos runs and fault-free references.

Objectives are module-level so they pickle by reference into spawned
process workers and loopback daemons (the same discipline as
test_remote.py)."""
import json
import os
import threading
import time
import warnings

import pytest

from repro import faults
from repro.evaluation.disk_cache import DiskEvaluationCache, canonical_key
from repro.faults import DROP, FaultPlan, FaultRule, InjectedFault
from repro.search import ParallelStudy, RandomSampler, Study, TrialState
from repro.search.remote import transport
from repro.search.remote.client import PoisonTrialError, RemoteClient
from repro.search.remote.executor import RemoteExecutor
from repro.search.remote.worker import DropConnection, WorkerServer


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    """A plan installed by one test must never leak into the next."""
    yield
    faults.uninstall()


def _quadratic(trial):
    x = trial.suggest_float("x", -4.0, 4.0)
    y = trial.suggest_float("y", -4.0, 4.0)
    return (x - 1.0) ** 2 + (y + 0.5) ** 2


def _fingerprint(study):
    return [(t.number, dict(t.params), t.values) for t in study.trials]


def _start_servers(n, **kwargs):
    servers = [WorkerServer(**kwargs) for _ in range(n)]
    addrs = []
    for s in servers:
        host, port = s.start()
        addrs.append(f"{host}:{port}")
    return servers, addrs


# ---------------------------------------------------------------------------
# the plan language
# ---------------------------------------------------------------------------

def test_rule_string_roundtrip():
    r = FaultRule.from_string("disk_cache.write:corrupt@p=0.25,times=2,key=3")
    assert (r.site, r.action, r.p, r.times, r.key) == \
        ("disk_cache.write", "corrupt", 0.25, 2, "3")
    assert FaultRule.from_string(r.to_string()).to_string() == r.to_string()
    assert FaultRule.from_dict(r.to_dict()).to_string() == r.to_string()


def test_plan_string_roundtrip_carries_seed():
    spec = "seed=7;worker.trial:kill@key=3;study.persist:corrupt@p=0.5"
    plan = FaultPlan.from_string(spec)
    assert plan.seed == 7 and len(plan.rules) == 2
    again = FaultPlan.from_string(plan.to_string())
    assert again.seed == 7
    assert [r.to_string() for r in again.rules] == \
        [r.to_string() for r in plan.rules]
    # dict form (the faults: spec section) accepts strings and mappings
    assert FaultPlan.from_spec(plan.to_dict()).to_string() == plan.to_string()
    mixed = FaultPlan.from_spec(
        {"seed": 7, "rules": ["worker.trial:kill@key=3",
                              {"site": "study.persist", "action": "corrupt",
                               "p": 0.5}]})
    assert mixed.to_string() == plan.to_string()


def test_plan_rejects_unknown_site_action_and_params():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultRule("nope.where", "raise")
    with pytest.raises(ValueError, match="unknown fault action"):
        FaultRule("compile", "explode")
    with pytest.raises(ValueError, match="param"):
        FaultRule.from_string("compile:raise@frequency=2")
    with pytest.raises(ValueError, match="mapping"):
        FaultPlan.from_spec(["compile:raise"])


def test_probabilistic_rule_is_seed_deterministic():
    def fire_pattern(seed):
        plan = FaultPlan([FaultRule("compile", "raise", p=0.5)], seed=seed)
        out = []
        for _ in range(40):
            try:
                plan.apply("compile", None, None)
                out.append(0)
            except InjectedFault:
                out.append(1)
        return out

    a, b = fire_pattern(3), fire_pattern(3)
    assert a == b                       # the whole point of seeded chaos
    assert 0 < sum(a) < 40              # and it is actually probabilistic
    assert fire_pattern(4) != a


def test_after_times_and_key_gating():
    plan = faults.install(FaultPlan([
        FaultRule("worker.trial", "raise", after=1, times=2, key="5"),
    ]))
    # wrong key: never eligible
    assert faults.fault_point("worker.trial", key=4) is None
    # first keyed hit swallowed by after=1, next two fire, then capped
    assert faults.fault_point("worker.trial", key=5) is None
    for _ in range(2):
        with pytest.raises(InjectedFault):
            faults.fault_point("worker.trial", key=5)
    assert faults.fault_point("worker.trial", key=5) is None
    (c,) = plan.counters()
    assert (c["hits"], c["fired"]) == (4, 2)


def test_corrupt_truncates_str_and_flips_bytes_deterministically():
    plan = FaultPlan([FaultRule("study.persist", "corrupt"),
                      FaultRule("transport.send", "corrupt")], seed=9)
    line = json.dumps({"kind": "trial", "number": 12}) + "\n"
    torn = plan.apply("study.persist", line, None)
    assert torn != line and line.startswith(torn)  # a prefix: a torn write
    frame = b"\x80\x05pickled-payload"
    bent = plan.apply("transport.send", frame, None)
    assert bent != frame and len(bent) == len(frame)
    diff = [i for i, (x, y) in enumerate(zip(frame, bent)) if x != y]
    assert len(diff) == 1                           # exactly one bit-rot byte
    # same seed -> same damage
    plan2 = FaultPlan([FaultRule("study.persist", "corrupt"),
                       FaultRule("transport.send", "corrupt")], seed=9)
    assert plan2.apply("study.persist", line, None) == torn
    assert plan2.apply("transport.send", frame, None) == bent


def test_drop_delay_and_disabled_hot_path():
    assert faults.active_plan() is None
    payload = "payload"
    assert faults.fault_point("disk_cache.write", payload) is payload
    faults.install(FaultPlan([FaultRule("transport.send", "drop"),
                              FaultRule("compile", "delay", delay_s=0.05)]))
    assert faults.fault_point("transport.send", b"x") is DROP
    t0 = time.perf_counter()
    faults.fault_point("compile")
    assert time.perf_counter() - t0 >= 0.04
    faults.uninstall()
    assert faults.fault_point("transport.send", b"x") == b"x"


def test_env_knob_installs_a_plan(monkeypatch):
    monkeypatch.setenv("REPRO_FAULTS", "seed=2;disk_cache.read:raise@times=1")
    faults._install_from_env()
    plan = faults.active_plan()
    assert plan is not None and plan.seed == 2
    with pytest.raises(InjectedFault):
        faults.fault_point("disk_cache.read", "line")


# ---------------------------------------------------------------------------
# the faults: experiment-spec section
# ---------------------------------------------------------------------------

TINY_SPACE = {
    "input": [2, 64],
    "output": 3,
    "sequence": [
        {"block": "features", "op_candidates": "conv1d",
         "conv1d": {"kernel_size": [3, 5], "out_channels": [4, 8]}},
        {"block": "head", "op_candidates": "linear",
         "linear": {"width": [8, 16]}},
    ],
}


def _experiment(tmp_path, **overrides):
    raw = {
        "name": "chaos",
        "search_space": TINY_SPACE,
        "sampler": {"name": "tpe", "seed": 0},
        "executor": {"backend": "serial"},
        "criteria": [{"estimator": "flops", "kind": "objective"}],
        "budget": {"n_trials": 4},
        "report_dir": str(tmp_path / "results"),
    }
    raw.update(overrides)
    return raw


def test_faults_spec_validates_and_roundtrips(tmp_path):
    from repro.explorer.experiment import ExperimentError, ExperimentSpec

    raw = _experiment(tmp_path, faults={
        "seed": 7, "rules": ["study.persist:corrupt@p=0.5",
                             {"site": "compile", "action": "delay"}]})
    spec = ExperimentSpec.from_dict(raw)
    assert spec.faults.seed == 7 and len(spec.faults.rules) == 2
    plan = spec.faults.plan()
    assert plan.seed == 7 and plan.rules[0].site == "study.persist"
    again = ExperimentSpec.from_dict(spec.to_dict())
    assert again.faults == spec.faults
    # the bare-string shorthand is the REPRO_FAULTS encoding
    spec2 = ExperimentSpec.from_dict(
        _experiment(tmp_path, faults="seed=7;study.persist:corrupt@p=0.5"))
    assert spec2.faults.seed == 7

    with pytest.raises(ExperimentError, match="unknown fault site"):
        ExperimentSpec.from_dict(
            _experiment(tmp_path, faults={"rules": ["nowhere:raise"]}))
    with pytest.raises(ExperimentError, match="at least one rule"):
        ExperimentSpec.from_dict(_experiment(tmp_path, faults={"seed": 3}))


def test_explorer_run_arms_and_disarms_the_plan(tmp_path, monkeypatch):
    from repro import Explorer, ExperimentSpec

    monkeypatch.delenv("REPRO_FAULTS", raising=False)
    storage = str(tmp_path / "study.jsonl")
    spec = ExperimentSpec.from_dict(_experiment(
        tmp_path, persistence=storage,
        faults={"seed": 1, "rules": ["study.persist:corrupt@p=0.5"]}))
    report = Explorer(spec).run(save_report=False)
    assert report.n_trials == 4
    # disarmed after the run: no plan in-process, no env leak
    assert faults.active_plan() is None
    assert "REPRO_FAULTS" not in os.environ
    # chaos hit the store, yet it stays loadable
    with pytest.warns(RuntimeWarning):
        resumed = Study(storage=storage)
    assert len(resumed.trials) < 4  # the p=0.5 schedule tore some records


# ---------------------------------------------------------------------------
# study storage: torn-tail recovery + repair
# ---------------------------------------------------------------------------

def test_torn_tail_is_skipped_then_repaired(tmp_path):
    path = str(tmp_path / "study.jsonl")
    s = Study(sampler=RandomSampler(seed=5), storage=path)
    s.optimize(_quadratic, 4)
    intact = _fingerprint(s)

    # a crash mid-append: half a record, no newline
    with open(path, "ab") as f:
        f.write(b'{"kind": "trial", "trial": {"number": 99, "sta')
    with pytest.warns(RuntimeWarning, match="torn"):
        resumed = Study(sampler=RandomSampler(seed=5), storage=path)
    assert _fingerprint(resumed) == intact

    # the next persist truncates the torn tail instead of appending onto
    # it (which would corrupt the next record too)
    resumed.optimize(_quadratic, 1)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        clean = Study(sampler=RandomSampler(seed=5), storage=path)
    assert len(clean.trials) == 5
    with open(path, "rb") as f:
        for line in f.read().splitlines(keepends=True):
            assert line.endswith(b"\n")
            json.loads(line)


def test_mid_file_corruption_skips_only_the_bad_record(tmp_path):
    path = str(tmp_path / "study.jsonl")
    s = Study(sampler=RandomSampler(seed=5), storage=path)
    s.optimize(_quadratic, 3)
    lines = open(path, "rb").read().splitlines(keepends=True)
    lines[1] = b'{"kind": "trial", "trial": {"num\n'  # bit rot mid-file
    with open(path, "wb") as f:
        f.writelines(lines)
    with pytest.warns(RuntimeWarning, match="skipped"):
        resumed = Study(sampler=RandomSampler(seed=5), storage=path)
    assert [t.number for t in resumed.trials] == [0, 2]


def test_injected_torn_persist_roundtrips(tmp_path):
    """Chaos-injected torn writes on every persist: the reload parses
    what is intact and never raises — the crash-safety contract."""
    path = str(tmp_path / "study.jsonl")
    faults.install(FaultPlan.from_string("seed=1;study.persist:corrupt@p=0.5"))
    s = Study(sampler=RandomSampler(seed=6), storage=path)
    s.optimize(_quadratic, 8)
    faults.uninstall()
    with pytest.warns(RuntimeWarning):
        resumed = Study(storage=path)
    good = {t.number: t.values for t in resumed.trials}
    live = {t.number: t.values for t in s.trials}
    assert good  # some records survive a p=0.5 schedule at seed 1
    for n, v in good.items():
        assert live[n] == v  # survivors are byte-faithful


# ---------------------------------------------------------------------------
# disk cache: CRC records, corruption, compaction under concurrency
# ---------------------------------------------------------------------------

def test_bit_rot_reads_as_miss_and_compaction_drops_it(tmp_path):
    c = DiskEvaluationCache(path=str(tmp_path))
    c.store(("k", 1), {"latency": 0.25})
    c.store(("k", 2), {"latency": 0.5})
    f = os.path.join(str(tmp_path), DiskEvaluationCache.FILENAME)
    text = open(f).read().replace("0.25", "0.26")  # flip the stored value
    with open(f, "w") as fh:
        fh.write(text)

    sibling = DiskEvaluationCache(path=str(tmp_path))
    found, _ = sibling.lookup(("k", 1))
    assert not found and sibling.corrupt_records == 1
    found, v = sibling.lookup(("k", 2))
    assert found and v == {"latency": 0.5}

    # compaction physically removes the damaged record
    sibling.max_entries = 1
    for i in range(3):
        sibling.store(("fill", i), i)
    assert sibling.compactions >= 1 and sibling.dropped_corrupt >= 1
    assert "0.26" not in open(f).read()


def test_legacy_record_without_crc_still_loads(tmp_path):
    c = DiskEvaluationCache(path=str(tmp_path))
    ck = canonical_key(("legacy", 1))
    f = os.path.join(str(tmp_path), DiskEvaluationCache.FILENAME)
    with open(f, "a") as fh:
        fh.write(json.dumps({"key": ck, "value": 42}) + "\n")
    found, v = c.lookup(("legacy", 1))
    assert found and v == 42


def test_injected_write_corruption_degrades_to_sibling_miss(tmp_path):
    faults.install(FaultPlan.from_string("disk_cache.write:corrupt@times=1"))
    writer = DiskEvaluationCache(path=str(tmp_path))
    writer.store(("a",), 1)   # torn on disk, intact in writer memory
    writer.store(("b",), 2)   # times=1: this one lands whole
    faults.uninstall()
    assert writer.lookup(("a",)) == (True, 1)  # writer keeps its own value
    sibling = DiskEvaluationCache(path=str(tmp_path))
    found, _ = sibling.lookup(("a",))
    assert not found                            # a miss, never a wrong value
    assert sibling.lookup(("b",)) == (True, 2)


def test_compaction_racing_concurrent_writer_loses_nothing(tmp_path):
    """One process compacts (rewrite-in-place under flock) while a
    sibling appends: every surviving key must read back with the right
    value — the epoch protocol plus keep-last merge makes the race safe.
    A delay rule widens the window so the interleaving actually occurs."""
    a = DiskEvaluationCache(path=str(tmp_path), max_entries=8)
    b = DiskEvaluationCache(path=str(tmp_path), max_entries=None)
    stop = threading.Event()
    written = []

    def writer():
        i = 0
        while not stop.is_set():
            b.store(("race", i), i)
            written.append(i)
            i += 1

    t = threading.Thread(target=writer)
    t.start()
    try:
        faults.install(FaultPlan.from_string(
            "disk_cache.write:delay@p=0.3,delay_s=0.005"))
        for i in range(40):
            a.store(("compactor", i), i)
    finally:
        stop.set()
        t.join(10.0)
        faults.uninstall()
    assert a.compactions >= 1
    fresh = DiskEvaluationCache(path=str(tmp_path))
    hits = 0
    for i in written:
        found, v = fresh.lookup(("race", i))
        if found:
            assert v == i  # never a torn/mixed record
            hits += 1
    assert hits > 0
    assert fresh.corrupt_records == 0  # the race never manufactures rot


# ---------------------------------------------------------------------------
# transport: CRC frames end-to-end
# ---------------------------------------------------------------------------

def test_corrupted_frame_payload_fails_the_checksum():
    import socket

    a, b = socket.socketpair()
    left, right = transport.Connection(a), transport.Connection(b)
    try:
        faults.install(FaultPlan.from_string(
            "seed=4;transport.send:corrupt@times=1"))
        left.send("submit", {"task": "t1"}, b"A" * 64)
        with pytest.raises(transport.TransportError, match="checksum"):
            right.recv(timeout=2.0)
    finally:
        faults.uninstall()
        left.close()
        right.close()


def test_dropped_frame_is_skipped_not_delivered():
    import socket

    a, b = socket.socketpair()
    left, right = transport.Connection(a), transport.Connection(b)
    try:
        faults.install(FaultPlan.from_string("transport.recv:drop@times=1"))
        left.send("result", {"n": 1}, b"first")
        left.send("result", {"n": 2}, b"second")
        msg = right.recv(timeout=2.0)
        assert (msg.meta["n"], msg.payload) == (2, b"second")
    finally:
        faults.uninstall()
        left.close()
        right.close()


# ---------------------------------------------------------------------------
# poison-trial quarantine: process pool
# ---------------------------------------------------------------------------

def test_process_pool_quarantines_poison_trial(monkeypatch):
    """Trial 2 SIGKILLs every worker it lands on (the plan rides
    REPRO_FAULTS into the spawned interpreters).  The pool restarts,
    innocent in-flight trials resubmit strike-free, and after the second
    death trial 2 is quarantined as FAIL while its siblings complete
    with values identical to a fault-free serial run."""
    monkeypatch.setenv("REPRO_FAULTS", "worker.trial:kill@key=2")
    with pytest.warns(RuntimeWarning, match="quarantin"):
        s = ParallelStudy(sampler=RandomSampler(seed=0), n_workers=2,
                          backend="process")
        s.optimize(_quadratic, 6)
    monkeypatch.delenv("REPRO_FAULTS")

    poison = [t for t in s.trials if "quarantined" in t.user_attrs]
    assert [t.number for t in poison] == [2]
    assert poison[0].state == TrialState.FAIL
    assert poison[0].user_attrs["quarantined"]["deaths"] >= 2
    done = [t for t in s.trials if t.state == TrialState.COMPLETE]
    assert len(done) == 5

    ref = Study(sampler=RandomSampler(seed=0))
    ref.optimize(_quadratic, 6)
    for t in done:
        assert t.values == ref.trials[t.number].values


# ---------------------------------------------------------------------------
# remote pool: quarantine, graceful shutdown, rejoin, chaos parity
# ---------------------------------------------------------------------------

class _PoisonHook:
    """Sever the connection whenever the poison trial number arrives —
    a daemon-side stand-in for a trial that SIGKILLs its host."""

    def __init__(self, number):
        self.number = number
        self.kills = 0

    def __call__(self, task_id, task):
        if isinstance(task, dict) and task.get("number") == self.number:
            self.kills += 1
            raise DropConnection()


def test_remote_pool_quarantines_poison_trial():
    hook = _PoisonHook(1)
    servers, addrs = _start_servers(2, task_hook=hook)
    try:
        s = ParallelStudy(
            sampler=RandomSampler(seed=3), n_workers=2,
            backend=RemoteExecutor(workers=addrs, retries=5,
                                   quarantine_after=2),
            schedule="sliding_window", tell_order="completion")
        with pytest.warns(RuntimeWarning, match="quarantin"):
            s.optimize(_quadratic, 6)
    finally:
        for srv in servers:
            srv.stop()
    assert hook.kills == 2  # quarantined on the second death, not later
    poison = [t for t in s.trials if "quarantined" in t.user_attrs]
    assert [t.number for t in poison] == [1]
    assert poison[0].state == TrialState.FAIL
    done = [t for t in s.trials if t.state == TrialState.COMPLETE]
    assert len(done) == 5
    ref = Study(sampler=RandomSampler(seed=3))
    ref.optimize(_quadratic, 6)
    for t in done:
        assert t.values == ref.trials[t.number].values


def test_shutdown_frame_resubmits_without_heartbeat_wait():
    """A daemon announcing shutdown mid-task must trigger immediate
    resubmission — the client must not wait out the heartbeat timeout
    (set absurdly high here so the slow path cannot be the explanation)."""
    flaky, flaky_addrs = _start_servers(1)
    steady, steady_addrs = _start_servers(1)

    def announce_and_wedge(task_id, task):
        flaky[0].announce_shutdown()
        time.sleep(30.0)  # never returns a result

    flaky[0]._task_hook = announce_and_wedge
    import operator
    import pickle as pkl

    payload = pkl.dumps(("call", (operator.mul, (6, 7), {})),
                        protocol=pkl.HIGHEST_PROTOCOL)
    client = RemoteClient(flaky_addrs + steady_addrs, retries=2,
                          heartbeat_timeout_s=300.0)
    done = threading.Event()
    result = {}

    def on_done(key, value, error, worker_addr):
        result.update(value=value, error=error, worker=worker_addr)
        done.set()

    try:
        client.connect()
        t0 = time.perf_counter()
        with pytest.warns(RuntimeWarning, match="shutdown"):
            # dispatch order follows connect order: the flaky daemon
            # takes the task, announces shutdown, and wedges
            client.submit("k", lambda: payload, on_done)
            assert done.wait(20.0)
        assert time.perf_counter() - t0 < 15.0
        assert result["error"] is None and result["value"] == 42
        assert result["worker"] == steady_addrs[0]
    finally:
        client.close()
        for srv in flaky + steady:
            srv.stop()


def test_lost_worker_rejoins_the_pool():
    """Kill the only daemon, then bring a new one up on the same port:
    a rejoin-enabled client redials with backoff and the pool heals."""
    servers, addrs = _start_servers(1)
    host, port = addrs[0].split(":")
    client = RemoteClient(addrs, retries=0, heartbeat_timeout_s=1.0,
                          rejoin=True)
    try:
        assert client.connect() == addrs
        with pytest.warns(RuntimeWarning, match="lost|rejoin"):
            servers[0].stop()
            deadline = time.monotonic() + 10.0
            while client.live_workers() and time.monotonic() < deadline:
                time.sleep(0.05)
            assert client.live_workers() == []

            replacement = WorkerServer(host=host, port=int(port))
            replacement.start()
            servers.append(replacement)
            while not client.live_workers() and time.monotonic() < deadline:
                time.sleep(0.05)
        assert client.live_workers() == addrs

        import operator
        import pickle as pkl

        payload = pkl.dumps(("call", (operator.add, (20, 22), {})),
                            protocol=pkl.HIGHEST_PROTOCOL)
        done = threading.Event()
        result = {}

        def on_done(key, value, error, worker_addr):
            result.update(value=value, error=error)
            done.set()

        client.submit("k", lambda: payload, on_done)
        assert done.wait(10.0)
        assert result["error"] is None and result["value"] == 42
    finally:
        client.close()
        for srv in servers:
            srv.stop()


# ---------------------------------------------------------------------------
# the chaos matrix: fixed-seed parity across backends under injection
# ---------------------------------------------------------------------------

def test_chaos_matrix_fixed_seed_parity(tmp_path, monkeypatch):
    """The capstone: one fault-free serial reference, then chaos runs on
    every backend — serial under torn persists, process under a worker
    SIGKILL, remote under a severed connection — all producing the same
    trials and the same best trial at the same seed."""
    seed, n = 21, 6
    ref = Study(sampler=RandomSampler(seed=seed))
    ref.optimize(_quadratic, n)

    # serial + torn persists: the in-memory study is untouched by
    # storage damage, and the store stays loadable
    faults.install(FaultPlan.from_string("seed=2;study.persist:corrupt@p=0.4"))
    serial = Study(sampler=RandomSampler(seed=seed),
                   storage=str(tmp_path / "chaos.jsonl"))
    serial.optimize(_quadratic, n)
    faults.uninstall()
    assert _fingerprint(serial) == _fingerprint(ref)
    Study(storage=str(tmp_path / "chaos.jsonl"))  # must not raise

    # process + timing chaos: seeded delays shuffle completion order
    # inside the workers; fixed-seed determinism must hold regardless
    # (kill -> quarantine is pinned by its dedicated test above)
    monkeypatch.setenv("REPRO_FAULTS", "seed=5;worker.trial:delay@p=0.5,delay_s=0.02")
    proc = ParallelStudy(sampler=RandomSampler(seed=seed), n_workers=2,
                         backend="process")
    proc.optimize(_quadratic, n)
    monkeypatch.delenv("REPRO_FAULTS")
    assert _fingerprint(proc) == _fingerprint(ref)

    # remote + a daemon severing its connection once
    class DieOnce:
        def __init__(self):
            self.dropped = False

        def __call__(self, task_id, task):
            if not self.dropped:
                self.dropped = True
                raise DropConnection()

    hook = DieOnce()
    flaky, flaky_addrs = _start_servers(1, task_hook=hook)
    steady, steady_addrs = _start_servers(1)
    try:
        rem = ParallelStudy(
            sampler=RandomSampler(seed=seed), n_workers=2,
            backend=RemoteExecutor(workers=flaky_addrs + steady_addrs),
            schedule="sliding_window", tell_order="completion")
        with pytest.warns(RuntimeWarning, match="lost"):
            rem.optimize(_quadratic, n)
    finally:
        for srv in flaky + steady:
            srv.stop()
    assert hook.dropped
    assert _fingerprint(rem) == _fingerprint(ref)
    assert rem.best_trial.number == ref.best_trial.number
    assert rem.best_trial.values == ref.best_trial.values
