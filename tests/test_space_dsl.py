"""Unit tests for the YAML search-space DSL (paper §IV, Listings 1-3)."""
import pytest

from repro.core.space import SpaceError, parse_search_space

LISTING3 = """
input: [4, 1250]
output: 6
sequence:
  - block: "features"
    op_candidates: "conv-block"
    type_repeat:
      type: "vary_all"
      depth: [1, 2, 3, 4, 5, 6]
  - block: "head"
    op_candidates: "linear"
    linear:
      width: [32, 64, 128]
default_op_params:
  conv1d:
    kernel_size: [3, 5]
    out_channels: [8, 16]
composites:
  conv-block:
    sequence:
      - block: "conv"
        op_candidates: "conv1d"
      - block: "pool"
        op_candidates: ["maxpool", "identity"]
"""


def test_parse_listing3():
    space = parse_search_space(LISTING3)
    assert space.input_shape == (4, 1250)
    assert space.output_dim == 6
    assert [b.name for b in space.blocks] == ["features", "head"]
    assert space.blocks[0].op_candidates == ["conv-block"]
    assert space.blocks[0].repeat.mode == "vary_all"
    assert space.blocks[0].repeat.depth == [1, 2, 3, 4, 5, 6]
    assert "conv-block" in space.composites
    assert [b.name for b in space.composites["conv-block"]] == ["conv", "pool"]


def test_default_op_params_fallback_and_override():
    space = parse_search_space(LISTING3)
    conv_block = space.composites["conv-block"][0]
    # global fallback
    assert space.op_params(conv_block, "conv1d")["kernel_size"] == [3, 5]
    # local override
    head = space.blocks[1]
    assert space.op_params(head, "linear")["width"] == [32, 64, 128]


def test_local_overrides_global():
    y = """
input: [1, 8]
output: 2
sequence:
  - block: "b"
    op_candidates: "linear"
    linear:
      width: [7]
default_op_params:
  linear:
    width: [9]
    activation: ["relu"]
"""
    space = parse_search_space(y)
    merged = space.op_params(space.blocks[0], "linear")
    assert merged["width"] == [7]  # local wins
    assert merged["activation"] == ["relu"]  # global fallback survives


def test_missing_op_candidates_rejected():
    with pytest.raises(SpaceError, match="op_candidates"):
        parse_search_space("input: [1,8]\noutput: 2\nsequence:\n  - block: b\n")


def test_duplicate_block_names_rejected():
    y = """
input: [1, 8]
output: 2
sequence:
  - block: "b"
    op_candidates: "linear"
  - block: "b"
    op_candidates: "linear"
"""
    with pytest.raises(SpaceError, match="duplicate"):
        parse_search_space(y)


def test_unknown_repeat_mode_rejected():
    y = """
input: [1, 8]
output: 2
sequence:
  - block: "b"
    op_candidates: "linear"
    type_repeat:
      type: "sometimes"
"""
    with pytest.raises(SpaceError, match="unknown repeat mode"):
        parse_search_space(y)


def test_repeat_block_requires_existing_ref():
    y = """
input: [1, 8]
output: 2
sequence:
  - block: "b"
    op_candidates: "linear"
    type_repeat:
      type: "repeat_block"
      ref_block: "nope"
      depth: 2
"""
    with pytest.raises(SpaceError, match="not a defined block"):
        parse_search_space(y)


def test_composite_cycle_rejected():
    y = """
input: [1, 8]
output: 2
sequence:
  - block: "b"
    op_candidates: "c1"
composites:
  c1:
    sequence:
      - block: "x"
        op_candidates: "c2"
  c2:
    sequence:
      - block: "y"
        op_candidates: "c1"
"""
    with pytest.raises(SpaceError, match="cycle"):
        parse_search_space(y)


def test_preprocessing_section_parsed():
    y = LISTING3 + """
preprocessing:
  normalize:
    kind: ["zscore", "minmax"]
  downsample:
    factor: [1, 2, 4]
"""
    space = parse_search_space(y)
    assert set(space.preprocessing) == {"normalize", "downsample"}
