"""Kernels wired through the model blocks: impl="pallas" (interpret on
CPU) must match impl="xla" block-for-block."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.attention import AttentionConfig, attention_apply, attention_init
from repro.nn.ssm import Mamba2Config, mamba2_apply, mamba2_init
from repro.nn.xlstm import MLSTMConfig, mlstm_block_apply, mlstm_init
from repro.nn.types import split

KEY = jax.random.PRNGKey(7)


def test_attention_block_pallas_matches_xla():
    cfg = AttentionConfig(d_model=64, n_heads=4, n_kv_heads=2, causal=True)
    params, _ = split(attention_init(cfg, KEY))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 64))
    y_xla = attention_apply(params, cfg, x)
    y_pl = attention_apply(params, dataclasses.replace(cfg, impl="pallas"), x)
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_pl), atol=3e-5, rtol=3e-5)


def test_attention_block_pallas_sliding_window():
    cfg = AttentionConfig(d_model=32, n_heads=2, n_kv_heads=2, causal=True, window=32)
    params, _ = split(attention_init(cfg, KEY))
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 128, 32))
    y_xla = attention_apply(params, cfg, x)
    y_pl = attention_apply(params, dataclasses.replace(cfg, impl="pallas"), x)
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_pl), atol=3e-5, rtol=3e-5)


def test_mamba2_block_pallas_matches_xla():
    cfg = Mamba2Config(d_model=32, d_state=16, d_head=16, chunk=16)
    params, _ = split(mamba2_init(cfg, KEY))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, 32))
    y_xla = mamba2_apply(params, cfg, x)
    y_pl = mamba2_apply(params, dataclasses.replace(cfg, impl="pallas"), x)
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_pl), atol=2e-4, rtol=2e-3)


def test_mlstm_block_pallas_matches_xla():
    cfg = MLSTMConfig(d_model=32, n_heads=2, chunk=16)
    params, _ = split(mlstm_init(cfg, KEY))
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 64, 32))
    y_xla = mlstm_block_apply(params, cfg, x)
    y_pl = mlstm_block_apply(params, dataclasses.replace(cfg, impl="pallas"), x)
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_pl), atol=2e-4, rtol=2e-3)


def test_full_lm_with_pallas_blocks():
    """A whole model running with Pallas kernels in every layer."""
    from repro.models.lm import LM
    from repro.models.specs import LayerSpec, ModelSpec, SubBlock
    from repro.nn.mlp import MLPConfig

    layer = LayerSpec(subs=(
        SubBlock("attention", AttentionConfig(32, 2, 2, causal=True, impl="pallas")),
        SubBlock("mlp", MLPConfig(32, 64)),
    ))
    mamba = LayerSpec(subs=(SubBlock("mamba2", Mamba2Config(32, d_state=8, d_head=16, chunk=16, impl="pallas")),))
    spec = ModelSpec(name="pallas-lm", d_model=32, vocab=64,
                     layers=(layer, mamba, layer), remat=False)
    model = LM(spec)
    params, _ = split(model.init(KEY, jnp.float32))
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 64), 0, 64)
    logits = model.apply(params, toks)
    assert logits.shape == (2, 64, 64)
    assert jnp.isfinite(logits).all()
