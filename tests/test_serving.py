"""Serving path: seeded traffic replay, the continuous-batching engine,
batched prefill vs the token-by-token loop, the content-addressed
artifact store's zero-compile warm boot, and determinism of the
traffic-shaped estimators across backends."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import Explorer
from repro.configs import get_arch
from repro.evaluation.serving import _ServingEstimator, resolve_serving
from repro.explorer.experiment import ExperimentError, ServingSpec
from repro.hwgen.generator import generate_call_count
from repro.launch.serve import RequestQueue, ServingEngine, rebuild_best
from repro.launch.traffic import (
    Request,
    ServingCosts,
    ServingSim,
    TrafficError,
    TrafficSpec,
)
from repro.models.lm import LM
from repro.nn.types import split
from test_parity_matrix import CANONICAL_SERVING, canonical_experiment


# ---------------------------------------------------------------------------
# TrafficSpec: seeded replay + validation
# ---------------------------------------------------------------------------

def test_traffic_fixed_seed_replays_bit_identically():
    spec = TrafficSpec.from_raw({
        "seed": 11, "n_requests": 40, "arrival": "poisson", "rate_rps": 20.0,
        "prompt_lens": {8: 3, 16: 1}, "gen_lens": [4, 8]})
    a, b = spec.requests(), TrafficSpec.from_raw(spec.to_dict()).requests()
    assert a == b  # dataclass equality: arrivals, lengths, token seeds
    # prompt tokens replay bit-identically too
    for ra, rb in zip(a, b):
        assert np.array_equal(ra.prompt_tokens(512), rb.prompt_tokens(512))
    # a different seed is a different stream
    other = TrafficSpec.from_raw({**spec.to_dict(), "seed": 12})
    assert other.requests() != a


def test_traffic_length_mix_shorthands_normalize():
    spec = TrafficSpec.from_raw({"prompt_lens": 8, "gen_lens": [2, 6]})
    assert spec.prompt_lens == {8: 1.0}
    assert spec.gen_lens == {2: 0.5, 6: 0.5}
    assert spec.max_context == 8 + 6
    weighted = TrafficSpec.from_raw({"prompt_lens": {4: 3, 8: 1}})
    assert weighted.prompt_lens == {4: 0.75, 8: 0.25}


def test_traffic_arrival_shapes():
    burst = TrafficSpec.from_raw({"arrival": "burst", "n_requests": 5})
    assert [r.arrival_s for r in burst.requests()] == [0.0] * 5
    uniform = TrafficSpec.from_raw(
        {"arrival": "uniform", "n_requests": 4, "rate_rps": 2.0})
    assert [r.arrival_s for r in uniform.requests()] == [0.0, 0.5, 1.0, 1.5]
    poisson = TrafficSpec.from_raw({"arrival": "poisson", "n_requests": 8})
    arrivals = [r.arrival_s for r in poisson.requests()]
    assert arrivals == sorted(arrivals) and arrivals[0] > 0.0


@pytest.mark.parametrize("raw, message", [
    ({"n_requests": 0}, "n_requests"),
    ({"rate_rps": 0.0}, "rate_rps"),
    ({"arrival": "flood"}, "flood"),
    ({"prompt_lens": {0: 1.0}}, ">= 1"),
    ({"gen_lens": {4: -1.0}}, "> 0"),
    ({"cadence": 3}, "cadence"),
])
def test_traffic_validation_names_the_problem(raw, message):
    with pytest.raises(TrafficError, match=message):
        TrafficSpec.from_raw(raw)


def test_serving_spec_validation():
    spec = ServingSpec.from_raw(dict(CANONICAL_SERVING))
    assert spec.max_batch == 2 and spec.queue_limit == 4
    assert spec.traffic.seed == 5
    assert ServingSpec.from_raw(None) is None
    with pytest.raises(ExperimentError, match="max_batch"):
        ServingSpec.from_raw({"max_batch": 0})
    with pytest.raises(ExperimentError, match="dtype_bytes"):
        ServingSpec.from_raw({"dtype_bytes": 3})
    with pytest.raises(ExperimentError, match="flood"):
        ServingSpec.from_raw({"traffic": {"arrival": "flood"}})


# ---------------------------------------------------------------------------
# ServingSim: shedding, concurrency limit, determinism
# ---------------------------------------------------------------------------

def _req(i, arrival, prompt=4, gen=2):
    return Request(id=i, arrival_s=arrival, prompt_len=prompt, gen_len=gen,
                   token_seed=i)


COSTS = ServingCosts(prefill_s_per_token=0.001, decode_step_s=0.01)


def test_sim_sheds_arrivals_beyond_queue_limit():
    # 6 requests burst into a queue of 3: the whole burst is admitted
    # (or shed) on arrival, before any slot frees up
    requests = [_req(i, 0.0) for i in range(6)]
    out = ServingSim(max_batch=1, queue_limit=3).run(requests, COSTS)
    assert out["served"] == 3 and out["shed"] == 3
    assert out["shed_ids"] == [3, 4, 5]  # later arrivals shed first-come
    assert out["peak_concurrency"] == 1


def test_sim_respects_concurrency_limit():
    requests = [_req(i, 0.0) for i in range(4)]
    out = ServingSim(max_batch=2, queue_limit=8).run(requests, COSTS)
    assert out["served"] == 4 and out["shed"] == 0
    assert out["peak_concurrency"] == 2
    # kv peak: 2 concurrent sequences at prompt+generated depth
    assert out["kv_peak_tokens"] <= 2 * (4 + 2)


def test_sim_is_a_pure_function_of_requests_and_costs():
    spec = TrafficSpec.from_raw({"seed": 3, "n_requests": 24,
                                 "arrival": "poisson", "rate_rps": 64.0,
                                 "prompt_lens": [4, 8], "gen_lens": [2, 4]})
    sim = ServingSim(max_batch=2, queue_limit=4)
    a = sim.run(spec.requests(), COSTS)
    b = ServingSim(max_batch=2, queue_limit=4).run(spec.requests(), COSTS)
    assert a == b
    assert a["total_tokens"] > 0 and a["throughput_tok_s"] > 0
    assert a["p99_latency_s"] >= a["p50_latency_s"] > 0


def test_request_queue_sheds_when_full():
    q = RequestQueue(2)
    assert q.offer("a") and q.offer("b")
    assert not q.offer("c")  # full -> shed
    assert q.shed == ["c"] and len(q) == 2
    assert q.take() == "a" and q.take() == "b" and q.take() is None


# ---------------------------------------------------------------------------
# batched prefill vs the token-by-token decode loop
# ---------------------------------------------------------------------------

PREFILL_ARCHS = ("qwen3-1.7b", "zamba2-2.7b", "xlstm-1.3b")


def _smoke_model(name):
    spec = get_arch(name).smoke_spec_fn()
    model = LM(spec)
    params, _ = split(model.init(jax.random.PRNGKey(0), dtype=jnp.float32))
    return spec, model, params


@pytest.mark.parametrize("name", PREFILL_ARCHS)
def test_prefill_matches_token_loop(name):
    """One full-sequence prefill must produce the same logits and the
    same decode cache as feeding the prompt token-by-token."""
    spec, model, params = _smoke_model(name)
    S, max_ctx = 8, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0, spec.vocab)

    loop_cache = model.init_cache(params, 2, max_ctx, dtype=jnp.float32)
    loop_logits = []
    for t in range(S):
        lg, loop_cache = model.decode(params, loop_cache,
                                      tokens[:, t:t + 1], t)
        loop_logits.append(lg)
    loop_logits = jnp.concatenate(loop_logits, axis=1)

    cache = model.init_cache(params, 2, max_ctx, dtype=jnp.float32)
    logits, cache = model.prefill(params, cache, tokens)

    assert logits.shape == loop_logits.shape
    assert jnp.max(jnp.abs(logits - loop_logits)) < 1e-4
    for a, b in zip(jax.tree_util.tree_leaves(cache),
                    jax.tree_util.tree_leaves(loop_cache)):
        assert jnp.max(jnp.abs(a.astype(jnp.float32)
                               - b.astype(jnp.float32))) < 1e-4
    # and decoding continues identically from both caches
    nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    lg_a, _ = model.decode(params, cache, nxt, S)
    lg_b, _ = model.decode(params, loop_cache, nxt, S)
    assert jnp.max(jnp.abs(lg_a - lg_b)) < 1e-4


def test_decode_accepts_per_slot_position_vector():
    spec, model, params = _smoke_model("qwen3-1.7b")
    cache = model.init_cache(params, 2, 16, dtype=jnp.float32)
    tok = jnp.zeros((2, 1), jnp.int32)
    scalar, _ = model.decode(params, cache, tok, 3)
    vector, _ = model.decode(params, cache, tok, jnp.array([3, 3]))
    assert jnp.max(jnp.abs(scalar - vector)) < 1e-5


# ---------------------------------------------------------------------------
# ServingEngine: continuous batching, mid-flight joins, shedding
# ---------------------------------------------------------------------------

def test_engine_matches_isolated_generation():
    """Requests joining a shared batch mid-flight must emit the same
    tokens as each request generated alone: slots are independent."""
    spec, model, params = _smoke_model("qwen3-1.7b")
    traffic = TrafficSpec.from_raw({
        "seed": 2, "n_requests": 3, "arrival": "burst",
        "prompt_lens": [4, 6], "gen_lens": 3})
    requests = traffic.requests()
    max_ctx = min(traffic.max_context + 1, spec.max_position)

    engine = ServingEngine(model, params, max_batch=2, queue_limit=4,
                           max_context=max_ctx)
    summary = engine.run(requests)
    assert summary["served"] == 3 and summary["shed"] == 0
    assert summary["prefills"] == 3

    by_id = {r["id"]: r for r in engine.completed}
    for req in requests:
        cache = model.init_cache(params, 1, max_ctx, dtype=jnp.float32)
        prompt = jnp.asarray(req.prompt_tokens(spec.vocab)[None])
        logits, cache = model.prefill(params, cache, prompt)
        tok = int(jnp.argmax(logits[0, -1]))
        alone = [tok]
        pos = req.prompt_len
        while len(alone) < req.gen_len:
            lg, cache = model.decode(params, cache,
                                     jnp.array([[tok]], jnp.int32),
                                     jnp.array([pos]))
            tok = int(jnp.argmax(lg[0, 0]))
            alone.append(tok)
            pos += 1
        assert by_id[req.id]["tokens"] == alone


def test_engine_sheds_and_replays_deterministically():
    spec, model, params = _smoke_model("qwen3-1.7b")
    traffic = TrafficSpec.from_raw({
        "seed": 0, "n_requests": 6, "arrival": "burst",
        "prompt_lens": 4, "gen_lens": 2})
    max_ctx = min(traffic.max_context + 1, spec.max_position)

    def run():
        engine = ServingEngine(model, params, max_batch=2, queue_limit=3,
                               max_context=max_ctx)
        summary = engine.run(traffic.requests())
        return summary, [r["tokens"] for r in engine.completed]

    (a, toks_a), (b, toks_b) = run(), run()
    # burst of 6 into queue_limit 3: the overflow is shed gracefully
    assert a["shed"] == 3 and a["shed_ids"] == [3, 4, 5]
    assert a["served"] == 3
    # fixed seed -> bit-identical replay, admissions and outputs alike
    assert a == b and toks_a == toks_b


# ---------------------------------------------------------------------------
# artifact store: cold explore -> warm boot with zero XLA compiles
# ---------------------------------------------------------------------------

@pytest.fixture
def serving_report(tmp_path):
    raw = canonical_experiment(
        tmp_path, cache_dir=str(tmp_path / "cache"),
        budget={"n_trials": 6})
    os.environ.setdefault("REPRO_ARTIFACTS", "1")
    explorer = Explorer.from_dict(raw)
    report = explorer.run()
    assert report.artifacts and report.artifacts["entries"] > 0
    return report


def test_warm_boot_serves_same_logits_with_zero_compiles(serving_report):
    with open(serving_report.artifact) as f:
        persisted = json.load(f)
    candidate, spec = rebuild_best(persisted)
    assert candidate.arch.signature() == persisted["best"]["signature"]

    # cold path: a fresh estimator with no cache dir must compile
    cold = _ServingEstimator(target=spec.target, serving=spec.serving)
    plan = cold._schedule_plan(candidate)
    before = generate_call_count()
    cold_artifact, (params, x0) = cold._artifact(candidate, plan)
    assert generate_call_count() - before == 1
    cold_logits = np.asarray(cold_artifact.compiled(params, x0))

    # warm path: same cache dir the exploration populated -> store hit,
    # zero generate() calls, and the loaded executable agrees exactly
    warm = _ServingEstimator(target=spec.target, serving=spec.serving,
                             cache=spec.cache.dir)
    before = generate_call_count()
    warm_artifact, (params_w, x0_w) = warm._artifact(candidate, plan)
    assert generate_call_count() - before == 0
    assert warm.artifacts is not None and warm.artifacts.hits >= 1
    warm_logits = np.asarray(warm_artifact.compiled(params_w, x0_w))
    assert np.array_equal(cold_logits, warm_logits)


def test_serve_cli_boots_report_with_zero_compiles(serving_report):
    """The CI smoke in-process: `serve --from-report --expect-compiles 0`
    must serve every request of the declared traffic without compiling."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.setdefault("REPRO_ARTIFACTS", "1")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve",
         "--from-report", serving_report.artifact, "--expect-compiles", "0"],
        capture_output=True, text=True, env=env, timeout=300,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["compiles"] == 0
    assert out["served"] == out["traffic"]["n_requests"]
    assert out["shed"] == 0
    assert out["signature"] == serving_report.best["signature"]


def test_rebuild_best_rejects_signature_drift(serving_report):
    with open(serving_report.artifact) as f:
        persisted = json.load(f)
    persisted["best"]["signature"] = "linear(width=9999)"
    with pytest.raises(SystemExit, match="does not\n?.*match"):
        rebuild_best(persisted)


# ---------------------------------------------------------------------------
# estimator determinism: serial vs process backends
# ---------------------------------------------------------------------------

def test_serving_criteria_deterministic_across_backends(tmp_path):
    def run(backend, sub):
        raw = canonical_experiment(
            tmp_path / sub, backend=backend,
            cache_dir=str(tmp_path / sub / "cache"),
            budget={"n_trials": 6})
        report = Explorer.from_dict(raw).run(save_report=False)
        return (report.best["number"], report.best["params"],
                report.best["values"], report.criteria_values)

    serial = run("serial", "serial")
    assert run("process", "process") == serial
    assert run("serial", "again") == serial  # and across repeat runs


def test_estimator_values_are_pure_functions_of_spec():
    """Same candidate + same serving spec -> same values, no cache."""
    from repro.core.builder import ModelBuilder
    from repro.core.space import parse_search_space
    from repro.core.translate import sample_architecture
    from repro.search.samplers import RandomSampler
    from repro.search.study import Study
    from test_parity_matrix import CANONICAL_SPACE

    space = parse_search_space(dict(CANONICAL_SPACE))
    builder = ModelBuilder(space.input_shape, space.output_dim)
    study = Study(sampler=RandomSampler(seed=0))
    candidate = builder.build(sample_architecture(space, study.ask()))

    serving = resolve_serving(dict(CANONICAL_SERVING))
    values = {}
    for _ in range(2):
        est = _ServingEstimator(target="host_cpu", serving=serving)
        summary = est._simulate(candidate)
        for k in ("p99_latency_s", "throughput_tok_s", "kv_peak_tokens"):
            values.setdefault(k, []).append(summary[k])
    for k, (a, b) in values.items():
        assert a == b, k
