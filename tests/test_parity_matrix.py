"""The canonical cross-backend parity matrix.

One experiment spec — the canonical tiny conv1d/linear space ranked by
the traffic-shaped ``p99_latency_s`` criterion — runs through every
execution mode the framework offers:

    {serial, process, remote-loopback} x {flat, cascade} x
    {kernel_tuning: off, cached}

and every cell must find the *identical* best trial (params and values)
as the serial reference for its (mode, kernel_tuning) pair.  This file
is also the single home of the shared tiny search space: the scattered
parity checks in ``test_explorer.py`` / ``test_cascade.py`` /
``test_remote.py`` import :data:`CANONICAL_SPACE` and
:func:`canonical_experiment` from here instead of re-declaring their
own copies.

All cells share one disk cache (and its content-addressed artifact
store), so the matrix also exercises the warm path: the first cell to
evaluate a program compiles it, every later cell warm-loads it — and
must still report the same numbers.
"""
import copy

import pytest

from repro import Explorer

CANONICAL_SPACE = {
    "input": [2, 64],
    "output": 3,
    "sequence": [
        {"block": "features", "op_candidates": "conv1d",
         "conv1d": {"kernel_size": [3, 5], "out_channels": [4, 8]}},
        {"block": "head", "op_candidates": "linear",
         "linear": {"width": [8, 16]}},
    ],
}

# the serving section every cell ranks under: small seeded poisson mix
CANONICAL_SERVING = {
    "max_batch": 2,
    "queue_limit": 4,
    "traffic": {"seed": 5, "n_requests": 12, "arrival": "poisson",
                "rate_rps": 100.0, "prompt_lens": [4, 8],
                "gen_lens": [2, 4]},
}


def canonical_experiment(tmp_path, *, mode="flat", backend="serial",
                         kernel_tuning="off", workers=None,
                         cache_dir=None, seed=7, **overrides):
    """The one tiny experiment the whole parity suite agrees on."""
    raw = {
        "name": f"parity-{mode}-{backend}-{kernel_tuning}",
        "search_space": copy.deepcopy(CANONICAL_SPACE),
        "sampler": {"name": "random", "seed": seed},
        "executor": {"backend": backend,
                     "n_workers": 1 if backend == "serial" else 2},
        "criteria": [
            {"estimator": "p99_latency_s", "kind": "objective",
             "weight": 1.0},
            {"estimator": "n_params", "kind": "objective", "weight": 1e-9},
        ],
        "serving": copy.deepcopy(CANONICAL_SERVING),
        "budget": {"n_trials": 8},
        "report_dir": str(tmp_path / "results"),
    }
    if backend == "remote":
        raw["executor"]["workers"] = list(workers)
        raw["schedule"] = {"mode": "sliding_window"}
    if kernel_tuning != "off":
        raw["kernel_tuning"] = {"mode": kernel_tuning}
    if cache_dir is not None:
        raw["cache"] = {"dir": str(cache_dir)}
    if mode == "cascade":
        raw["fidelity"] = {
            "generation": 4,
            "stages": [
                {"name": "zero_cost",
                 "criteria": [{"estimator": "synflow", "kind": "objective",
                               "direction": "minimize"}],
                 "keep": {"top_frac": 0.5}},
            ],
        }
    raw.update(overrides)
    return raw


def run_cell(tmp_path, cache_dir, backend, mode, kernel_tuning,
             workers=None):
    raw = canonical_experiment(
        tmp_path, mode=mode, backend=backend, kernel_tuning=kernel_tuning,
        workers=workers, cache_dir=cache_dir)
    report = Explorer.from_dict(raw).run(save_report=False)
    return {
        "best_number": report.best["number"],
        "best_params": report.best["params"],
        "best_values": report.best["values"],
        "best_signature": report.best["signature"],
        "states": report.states,
    }


@pytest.fixture(scope="module")
def cache_dir(tmp_path_factory):
    # one disk cache for the whole matrix: later cells warm-load the
    # compiled programs (and artifact-store blobs) earlier cells produced
    return str(tmp_path_factory.mktemp("parity-cache"))


@pytest.fixture(scope="module")
def pool():
    from repro.search.remote.worker import WorkerServer

    servers = [WorkerServer() for _ in range(2)]
    addrs = []
    for s in servers:
        host, port = s.start()
        addrs.append(f"{host}:{port}")
    yield addrs
    for s in servers:
        s.stop()


@pytest.fixture(scope="module")
def refs(tmp_path_factory, cache_dir):
    """Lazily-computed serial reference per (mode, kernel_tuning)."""
    store = {}

    def get(mode, kernel_tuning):
        key = (mode, kernel_tuning)
        if key not in store:
            store[key] = run_cell(
                tmp_path_factory.mktemp(f"ref-{mode}-{kernel_tuning}"),
                cache_dir, "serial", mode, kernel_tuning)
        return store[key]

    return get


@pytest.mark.parametrize("kernel_tuning", ("off", "cached"))
@pytest.mark.parametrize("mode", ("flat", "cascade"))
@pytest.mark.parametrize("backend", ("serial", "process", "remote"))
def test_parity_cell(tmp_path, cache_dir, refs, pool, backend, mode,
                     kernel_tuning):
    workers = pool if backend == "remote" else None
    cell = run_cell(tmp_path, cache_dir, backend, mode, kernel_tuning,
                    workers=workers)
    assert cell == refs(mode, kernel_tuning)


def test_reference_cells_rank_by_p99(refs):
    """The serial references really did rank on the serving criterion:
    the winning scalarized value is dominated by p99_latency_s."""
    for mode in ("flat", "cascade"):
        ref = refs(mode, "off")
        assert ref["best_values"][0] > 0.0
        assert ref["best_signature"].startswith("conv1d(")
