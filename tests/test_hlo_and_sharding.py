"""HLO collective parser + logical-axis sharding resolver (pure logic)."""
import types

import pytest
from jax.sharding import PartitionSpec

from repro.distributed.sharding import default_rules, partition_spec
from repro.hwgen.hlo_analysis import analyze_collectives, total_collective_bytes
from repro.hwgen.roofline import roofline_terms
from repro.hwgen.targets import TPU_V5E

SAMPLE_HLO = """
HloModule jit_f, is_scheduled=true

%region_0.body (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = f32[8,16]{1,0} parameter(0)
  %all-gather.1 = f32[8,64]{1,0} all-gather(%p), channel_id=1, replica_groups=[2,4]<=[8]
  %c9 = s32[] constant(7)
}

%region_1.cond (arg: (s32[], f32[8,16])) -> pred[] {
  %gte = s32[] get-tuple-element(%arg), index=0
  %trip = s32[] constant(12)
  ROOT %cmp = pred[] compare(%gte, %trip), direction=LT
}

ENTRY %main (a: f32[8,16], b: f32[16,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %b = f32[16,16]{1,0} parameter(1)
  %all-reduce = f32[8,16]{1,0} all-reduce(%a), channel_id=2, replica_groups=[1,8]<=[8], to_apply=%add
  %t = (s32[], f32[8,16]) tuple(%c0, %all-reduce)
  %w = (s32[], f32[8,16]) while(%t), condition=%region_1.cond, body=%region_0.body
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w), index=1
}
"""


def test_collective_parser_counts_and_bytes():
    st = analyze_collectives(SAMPLE_HLO)
    # all-reduce in ENTRY: 8*16*4 = 512 bytes, once
    assert st["all-reduce"]["count"] == 1
    assert st["all-reduce"]["bytes"] == 512
    # all-gather inside while body: operand f32[8,16] = 512 bytes x trip 12
    assert st["all-gather"]["count"] == 12
    assert st["all-gather"]["bytes"] == 512 * 12
    assert total_collective_bytes(st) == 512 + 512 * 12


def test_parser_ignores_done_ops():
    txt = """
ENTRY %main (a: f32[4]) -> f32[4] {
  %a = f32[4]{0} parameter(0)
  %ag-start = (f32[4], f32[16]) all-gather-start(%a), channel_id=1
  %ag-done = f32[16]{0} all-gather-done(%ag-start)
}
"""
    st = analyze_collectives(txt)
    assert st["all-gather"]["count"] == 1  # start only
    assert st["all-gather"]["bytes"] == 16


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_partition_spec_basic():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = {"embed": ("data",), "mlp": ("model",)}
    ps = partition_spec(("embed", "mlp"), (1024, 4096), mesh, rules)
    assert ps == PartitionSpec("data", "model")


def test_partition_spec_divisibility_fallback():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = {"kv_heads": ("model",), "embed": ("data",)}
    # 8 kv heads cannot shard over 16 -> replicated
    ps = partition_spec(("embed", "kv_heads"), (2048, 8), mesh, rules)
    assert ps == PartitionSpec("data", None)


def test_partition_spec_no_axis_reuse():
    mesh = FakeMesh({"data": 4, "model": 4})
    rules = {"a": ("model",), "b": ("model",)}
    ps = partition_spec(("a", "b"), (64, 64), mesh, rules)
    assert ps == PartitionSpec("model", None)  # second use dropped


def test_partition_spec_multi_axis_batch():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    rules = {"batch": ("pod", "data")}
    ps = partition_spec(("batch", None), (256, 4096), mesh, rules)
    assert ps == PartitionSpec(("pod", "data"), None)
    # batch=24 not divisible by 32 -> replicated
    ps2 = partition_spec(("batch", None), (24, 4096), mesh, rules)
    assert ps2 == PartitionSpec(None, None)


def test_default_rules_cover_expected_axes():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    rules = default_rules(mesh)
    for name in ("batch", "embed", "mlp", "heads", "kv_heads", "vocab", "experts", "kv_seq"):
        assert name in rules


def test_roofline_dominant_term():
    r = roofline_terms(hlo_flops=1e15, hlo_bytes=1e12, collective_bytes=1e11,
                       n_chips=1, chip=TPU_V5E, cell="x")
    assert r.compute_s == pytest.approx(1e15 / 197e12)
    assert r.memory_s == pytest.approx(1e12 / 819e9)
    assert r.collective_s == pytest.approx(1e11 / 50e9)
    assert r.dominant == "compute"
    assert r.roofline_fraction == 1.0

    r2 = roofline_terms(hlo_flops=1e12, hlo_bytes=1e13, collective_bytes=0,
                        n_chips=1, chip=TPU_V5E)
    assert r2.dominant == "memory"
    assert r2.roofline_fraction < 1.0
