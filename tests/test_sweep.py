"""Sweep engine: expansion (cross product, dotted-key overrides, axis
validation), per-cell resume, merge determinism, standalone-cell parity,
target-constants round-trip, and the docs generator."""
import copy
import json
import os

import pytest
import yaml

from repro import Explorer, SweepError, SweepSpec, run_sweep
from repro.explorer.sweep import _axis_label, _set_dotted, merge_reports

TINY_SPACE = {
    "input": [2, 64],
    "output": 3,
    "sequence": [
        {"block": "features", "op_candidates": "conv1d",
         "conv1d": {"kernel_size": [3, 5], "out_channels": [4, 8]}},
        {"block": "head", "op_candidates": "linear",
         "linear": {"width": [8, 16]}},
    ],
}

BASE = {
    "name": "tiny",
    "search_space": TINY_SPACE,
    "sampler": {"name": "random", "seed": 0},
    "executor": {"backend": "serial"},
    "criteria": [
        {"estimator": "flops", "kind": "objective", "weight": 1.0},
        {"estimator": "n_params", "kind": "objective", "weight": 0.1},
    ],
    "budget": {"n_trials": 6},
}


def make_sweep(tmp_path, **overrides):
    raw = {
        "name": "tiny-sweep",
        "base": copy.deepcopy(BASE),
        "axes": {
            "targets": ["host_cpu", "edge_npu"],
            "samplers": [{"name": "random", "seed": 0},
                         {"name": "grid", "seed": 0}],
        },
        "report_dir": str(tmp_path / "results"),
    }
    raw.update(overrides)
    return raw


# ---------------------------------------------------------------------------
# expansion
# ---------------------------------------------------------------------------

def test_expand_cross_product_order_and_overrides(tmp_path):
    spec = SweepSpec.from_dict(make_sweep(tmp_path))
    cells = spec.expand()
    assert len(cells) == 4
    # axes expand in declaration order: target-major, sampler-minor
    assert [c.axes for c in cells] == [
        {"target": "host_cpu", "sampler": "random-seed0"},
        {"target": "host_cpu", "sampler": "grid-seed0"},
        {"target": "edge_npu", "sampler": "random-seed0"},
        {"target": "edge_npu", "sampler": "grid-seed0"},
    ]
    assert cells[0].spec.target == "host_cpu" and cells[0].spec.sampler.name == "random"
    assert cells[3].spec.target == "edge_npu" and cells[3].spec.sampler.name == "grid"
    # cell names are unique, deterministic, and filesystem-safe
    names = [c.name for c in cells]
    assert len(set(names)) == 4
    assert all("/" not in n and " " not in n for n in names)
    # every cell reports into the sweep's cell directory
    assert all(c.spec.report_dir == spec.cells_dir for c in cells)


def test_expand_dotted_key_axis(tmp_path):
    raw = make_sweep(tmp_path)
    raw["axes"]["budget.n_trials"] = [2, 4]
    cells = SweepSpec.from_dict(raw).expand()
    assert len(cells) == 8
    assert sorted({c.spec.budget.n_trials for c in cells}) == [2, 4]
    # the dotted override only touches its leaf
    assert all(c.spec.budget.timeout_s is None for c in cells)


def test_sweep_cache_forced_into_every_cell(tmp_path):
    raw = make_sweep(tmp_path, cache=str(tmp_path / "store"))
    cells = SweepSpec.from_dict(raw).expand()
    assert all(c.spec.cache.dir == str(tmp_path / "store") for c in cells)
    # booleans take the experiment-level shorthand, not str(True)/"False"
    from repro.evaluation.disk_cache import DEFAULT_DIR

    assert SweepSpec.from_dict(make_sweep(tmp_path, cache=True)).cache == DEFAULT_DIR
    assert SweepSpec.from_dict(make_sweep(tmp_path, cache=False)).cache is None


def test_expand_overrides_beat_whole_section_axes(tmp_path):
    """The CLI's shrink knobs apply after axis values, so even a
    whole-section `budget:`/`executor:` axis cannot defeat --trials."""
    raw = make_sweep(tmp_path)
    raw["axes"] = {"budget": [{"n_trials": 50}, {"n_trials": 60}],
                   "executor": [{"backend": "serial", "n_workers": 8}]}
    spec = SweepSpec.from_dict(raw)
    cells = spec.expand({"budget.n_trials": 2, "executor.n_workers": 1})
    assert [c.spec.budget.n_trials for c in cells] == [2, 2]
    assert [c.spec.executor.n_workers for c in cells] == [1, 1]
    # without overrides the axes stand
    assert [c.spec.budget.n_trials for c in spec.expand()] == [50, 60]


def test_axis_validation_names_the_bad_axis(tmp_path):
    # unknown experiment key as an axis head
    raw = make_sweep(tmp_path)
    raw["axes"]["samplerz"] = ["random"]
    with pytest.raises(SweepError, match="samplerz"):
        SweepSpec.from_dict(raw)
    # non-sweepable axis
    raw = make_sweep(tmp_path)
    raw["axes"]["name"] = ["a", "b"]
    with pytest.raises(SweepError, match="name.*not sweepable"):
        SweepSpec.from_dict(raw)
    # empty value list
    raw = make_sweep(tmp_path)
    raw["axes"]["target"] = []
    with pytest.raises(SweepError, match="target.*non-empty"):
        SweepSpec.from_dict(raw)
    # a bad VALUE surfaces at expand() naming the whole cell coordinates
    raw = make_sweep(tmp_path)
    raw["axes"]["targets"] = ["host_cpu", "warp_core"]
    with pytest.raises(SweepError) as e:
        SweepSpec.from_dict(raw).expand()
    msg = str(e.value)
    assert "target=warp_core" in msg and "host_cpu" in msg  # alternatives listed


def test_unknown_sweep_key_and_missing_base(tmp_path):
    raw = make_sweep(tmp_path)
    raw["bases"] = raw.pop("base")
    with pytest.raises(SweepError, match="bases"):
        SweepSpec.from_dict(raw)
    with pytest.raises(SweepError, match="base"):
        SweepSpec.from_dict({"name": "x", "axes": {"target": ["host_cpu"]}})


def test_base_file_ref_resolves_and_inlines(tmp_path):
    (tmp_path / "exp.yaml").write_text(yaml.safe_dump(copy.deepcopy(BASE)))
    raw = make_sweep(tmp_path, base={"file": "exp.yaml"})
    path = tmp_path / "sweep.yaml"
    path.write_text(yaml.safe_dump(raw))
    spec = SweepSpec.from_yaml(str(path))
    assert spec.base["search_space"]["input"] == [2, 64]
    assert spec.to_dict()["base"]["name"] == "tiny"


def test_set_dotted_and_axis_labels():
    doc = {"budget": {"n_trials": 5}}
    _set_dotted(doc, "budget.n_trials", 9)
    _set_dotted(doc, "schedule.mode", "batch")
    assert doc == {"budget": {"n_trials": 9}, "schedule": {"mode": "batch"}}
    with pytest.raises(SweepError, match="descends through"):
        _set_dotted({"budget": 5}, "budget.n_trials", 9)
    assert _axis_label("host_cpu") == "host_cpu"
    assert _axis_label({"name": "tpe", "seed": 3}) == "tpe-seed3"
    assert _axis_label({"mode": "sliding_window"}) == "sliding_window"
    # distinct option sets may never collide on one label
    assert (_axis_label({"name": "tpe", "seed": 1})
            != _axis_label({"name": "tpe", "seed": 2}))


# ---------------------------------------------------------------------------
# running: parity, resume, determinism
# ---------------------------------------------------------------------------

def test_cell_best_matches_standalone_explorer(tmp_path):
    """A sweep adds comparison, not a different engine: each cell's best
    trial must be identical to running the child spec standalone."""
    spec = SweepSpec.from_dict(make_sweep(tmp_path))
    report = run_sweep(spec, save_report=False)
    assert report.n_cells == 4 and report.n_resumed == 0
    for cell, summary in zip(spec.expand(), report.cells):
        standalone = Explorer.from_spec(cell.spec).run(save_report=False)
        assert summary["best"]["number"] == standalone.best["number"]
        assert summary["best"]["values"] == standalone.best["values"]
        assert summary["best"]["params"] == standalone.best["params"]


def test_sweep_resume_skips_completed_cells(tmp_path):
    spec = SweepSpec.from_dict(make_sweep(tmp_path))
    first = run_sweep(spec)
    assert first.n_resumed == 0
    assert os.path.exists(first.artifact)

    # a full re-run resumes everything and reproduces the merge
    second = run_sweep(spec)
    assert second.n_resumed == 4
    assert second.matrix == first.matrix
    assert [c["best"] for c in second.cells] == [c["best"] for c in first.cells]

    # killing one cell re-runs exactly that cell
    victim = spec.expand()[2]
    os.remove(victim.report_path)
    third = run_sweep(spec)
    assert third.n_resumed == 3
    resumed = {c["name"]: c["resumed"] for c in third.cells}
    assert resumed[victim.name] is False
    assert sum(not r for r in resumed.values()) == 1
    assert third.matrix == first.matrix

    # editing the base spec invalidates every cell (spec fingerprint)
    spec.base["budget"]["n_trials"] = 4
    fourth = run_sweep(spec)
    assert fourth.n_resumed == 0


def test_sweep_report_merge_deterministic(tmp_path):
    r1 = run_sweep(SweepSpec.from_dict(make_sweep(tmp_path / "a")),
                   save_report=False)
    r2 = run_sweep(SweepSpec.from_dict(make_sweep(tmp_path / "b")),
                   save_report=False)
    d1, d2 = r1.to_dict(), r2.to_dict()
    # everything but wall clock and file paths must be bit-identical
    for d in (d1, d2):
        d.pop("wall_clock_s")
        d["spec"].pop("report_dir")
        for cell in d["cells"]:
            cell.pop("wall_clock_s")
            cell.pop("artifact")
            cell.pop("cache")  # absent under serial in-memory runs anyway
    assert json.dumps(d1, sort_keys=True) == json.dumps(d2, sort_keys=True)


def test_sweep_report_views(tmp_path):
    spec = SweepSpec.from_dict(make_sweep(tmp_path))
    report = run_sweep(spec, save_report=False)
    # per-criterion matrix: target rows x sampler columns
    assert set(report.matrix) == {"flops", "n_params"}
    grid = report.matrix["flops"]
    assert set(grid) == {"host_cpu", "edge_npu"}
    assert set(grid["host_cpu"]) == {"random-seed0", "grid-seed0"}
    assert all(isinstance(v, float) for row in grid.values() for v in row.values())
    # pareto union: tagged, non-dominated across every cell
    assert report.pareto_union
    for entry in report.pareto_union:
        assert entry["target"] in ("host_cpu", "edge_npu")
        assert len(entry["objective_values"]) == 2
    # rankings cover each criterion plus the declared weighting
    assert set(report.target_rankings) == {"flops", "n_params", "declared_weights"}
    for ranked in report.target_rankings.values():
        assert [r["target"] for r in ranked]  # non-empty, ordered
        values = [r["value"] for r in ranked]
        assert values == sorted(values)  # minimize criteria -> ascending


def test_merge_reports_is_pure(tmp_path):
    """merge_reports over the same summaries is deterministic and does
    not mutate its inputs (resumed merges must equal live merges)."""
    spec = SweepSpec.from_dict(make_sweep(tmp_path))
    report = run_sweep(spec)
    summaries = copy.deepcopy(report.cells)
    merged_a = merge_reports(spec, copy.deepcopy(summaries), 0, 1.0)
    merged_b = merge_reports(spec, copy.deepcopy(summaries), 4, 2.0)
    assert merged_a.matrix == report.matrix == merged_b.matrix
    assert merged_a.pareto_union == report.pareto_union
    assert merged_a.target_rankings == report.target_rankings


def test_sweep_artifact_round_trips(tmp_path):
    spec = SweepSpec.from_dict(make_sweep(tmp_path))
    report = run_sweep(spec)
    with open(report.artifact) as f:
        persisted = json.load(f)
    assert persisted["sweep"] == "tiny-sweep"
    assert persisted["matrix"] == report.matrix
    assert persisted["spec"]["axes"]["target"] == ["host_cpu", "edge_npu"]
    assert persisted["artifact"] == report.artifact


# ---------------------------------------------------------------------------
# bugfix: reports persist the full target constants
# ---------------------------------------------------------------------------

def test_report_persists_full_target_constants(tmp_path):
    from repro.explorer.registry import TARGETS

    raw = copy.deepcopy(BASE)
    raw["target"] = "edge_npu"
    raw["report_dir"] = str(tmp_path / "results")
    report = Explorer.from_dict(raw).run()
    expected = TARGETS.get("edge_npu").to_dict()
    assert report.target == expected
    assert report.target["chip"]["peak_flops_bf16"] == 4e12
    assert report.target["chip"]["hbm_bandwidth"] == 34e9
    # round-trip through the JSON artifact
    with open(report.artifact) as f:
        persisted = json.load(f)
    assert persisted["target"] == expected
    assert persisted["spec"] == report.spec  # report self-describes


def test_sweep_cells_carry_their_targets(tmp_path):
    report = run_sweep(SweepSpec.from_dict(make_sweep(tmp_path)),
                       save_report=False)
    by_axis = {c["axes"]["target"]: c["target"] for c in report.cells}
    assert by_axis["host_cpu"]["chip"]["name"] == "host_cpu"
    assert by_axis["edge_npu"]["chip"]["name"] == "edge_npu"
    assert (by_axis["edge_npu"]["chip"]["peak_flops_bf16"]
            != by_axis["host_cpu"]["chip"]["peak_flops_bf16"])


# ---------------------------------------------------------------------------
# cache plumbing: compile-derived values are scoped by mesh topology
# ---------------------------------------------------------------------------

def test_cross_target_cache_reuse_zero_compiles(tmp_path):
    """Targets sharing a mesh topology share compiles: the second
    target's modelled latency comes from the cached roofline terms
    (chip constants applied after the fact) and peak bytes from the
    cached memory analysis — zero new XLA compiles, yet chip-dependent
    values still differ per target."""
    from repro.core.builder import ModelBuilder
    from repro.core.space import parse_search_space
    from repro.core.translate import sample_architecture
    from repro.evaluation import (
        CompiledLatencyEstimator,
        CompiledMemoryEstimator,
        EvaluationCache,
    )
    from repro.hwgen.generator import generate_call_count
    from repro.search import RandomSampler, Study

    space = parse_search_space(dict(TINY_SPACE))
    builder = ModelBuilder(space.input_shape, space.output_dim)
    model = builder.build(sample_architecture(space, Study(
        sampler=RandomSampler(seed=0)).ask()))

    cache = EvaluationCache(disk=str(tmp_path / "store"))
    c0 = generate_call_count()
    host_lat = CompiledLatencyEstimator("host_cpu", batch=2, cache=cache,
                                        metric="modelled").estimate(model)
    host_mem = CompiledMemoryEstimator("host_cpu", batch=2,
                                       cache=cache).estimate(model)
    compiled_once = generate_call_count()
    assert compiled_once == c0 + 1  # latency + memory share one artifact

    for other in ("edge_npu", "tpu_v5e"):
        lat = CompiledLatencyEstimator(other, batch=2, cache=cache,
                                       metric="modelled").estimate(model)
        mem = CompiledMemoryEstimator(other, batch=2, cache=cache).estimate(model)
        assert generate_call_count() == compiled_once  # ZERO new compiles
        assert lat != host_lat     # chip constants still apply per target
        assert mem == host_mem     # memory analysis is chip-independent

    # a *different* mesh topology must NOT alias (distinct program)
    from repro.evaluation.cache import EvaluationCache as EC
    host = CompiledLatencyEstimator("host_cpu", batch=2, cache=cache,
                                    metric="modelled")
    pod = CompiledLatencyEstimator("tpu_v5e_pod", batch=2, cache=cache,
                                   metric="modelled")
    assert (host._program_key("roofline_terms", model)
            != pod._program_key("roofline_terms", model))
    assert EC.candidate_key(model) in str(host._program_key("artifact", model))


def test_shared_artifact_rebinds_to_requesting_target(tmp_path):
    """A cached artifact compiled by a sibling same-topology target must
    be re-bound before use: measurement dispatch and roofline constants
    follow the REQUESTING estimator's target, not whoever compiled
    first."""
    import pytest as _pytest

    from repro.core.builder import ModelBuilder
    from repro.core.space import parse_search_space
    from repro.core.translate import sample_architecture
    from repro.evaluation import (
        CompiledLatencyEstimator,
        CompiledMemoryEstimator,
        EvaluationCache,
    )
    from repro.hwgen.generator import generate_call_count
    from repro.search import RandomSampler, Study

    space = parse_search_space(dict(TINY_SPACE))
    builder = ModelBuilder(space.input_shape, space.output_dim)
    model = builder.build(sample_architecture(space, Study(
        sampler=RandomSampler(seed=0)).ask()))
    cache = EvaluationCache()

    # host_cpu pays the compile; the artifact in the cache carries host_cpu
    CompiledMemoryEstimator("host_cpu", batch=2, cache=cache).estimate(model)
    c0 = generate_call_count()

    # tpu_v5e measurement="roofline": benchmark() must return the TPU
    # roofline bound, not wall-clock the host (host_cpu's measurement)
    measured = CompiledLatencyEstimator("tpu_v5e", batch=2, cache=cache,
                                        metric="measured").estimate(model)
    modelled = CompiledLatencyEstimator("tpu_v5e", batch=2, cache=cache,
                                        metric="modelled").estimate(model)
    assert generate_call_count() == c0  # still zero extra compiles
    assert measured == _pytest.approx(modelled)

    # and the rebound artifact reports the requesting target's chip
    est = CompiledLatencyEstimator("tpu_v5e", batch=2, cache=cache)
    artifact, _ = est._artifact(model)
    assert artifact.target.name == "tpu_v5e"
    assert artifact.roofline.bound_s == _pytest.approx(modelled)


# ---------------------------------------------------------------------------
# docs generator
# ---------------------------------------------------------------------------

def test_gen_docs_covers_every_registered_component():
    from repro.explorer.docgen import (
        components_markdown,
        list_components_text,
        walk_components,
    )
    from repro.explorer.registry import REGISTRIES

    rendered = components_markdown()
    listed = list_components_text()
    walked = walk_components()
    for kind, registry in REGISTRIES.items():
        names = registry.names()
        assert names, f"registry {kind} is empty"
        assert [e["name"] for e in walked[kind]] == names
        for name in names:
            assert f"`{name}`" in rendered
            assert name in listed
    # the new builtins specifically
    assert "`edge_npu`" in rendered and "`tpu_v5e`" in rendered


def test_gen_docs_spec_reference_covers_every_key():
    from repro.explorer.docgen import experiment_spec_markdown
    from repro.explorer.experiment import TOP_LEVEL_KEYS
    from repro.explorer.sweep import SWEEP_KEYS

    rendered = experiment_spec_markdown()
    for key in TOP_LEVEL_KEYS:
        assert f"`{key}`" in rendered
    for key in SWEEP_KEYS:
        assert f"`{key}`" in rendered
    for section in ("sampler", "executor", "schedule", "criteria[i]",
                    "cache", "budget", "pruner", "Sweep document"):
        assert section in rendered


def test_gen_docs_env_reference_covers_every_env_var():
    from repro.envvars import ENV_VARS
    from repro.explorer.docgen import env_markdown

    rendered = env_markdown()
    assert ENV_VARS  # the registry is populated at import
    for name, var in ENV_VARS.items():
        assert f"`{name}`" in rendered
        assert var.default in rendered
        assert var.malformed in rendered


def test_env_registry_rejects_unregistered_reads_and_falls_back():
    import warnings

    from repro.envvars import read_env

    with pytest.raises(KeyError, match="REPRO_NOT_A_KNOB"):
        read_env("REPRO_NOT_A_KNOB", 1)
    # malformed registered value: warn + default (never raise)
    os.environ["REPRO_CACHE_MAX_ENTRIES"] = "banana"
    try:
        with pytest.warns(RuntimeWarning, match="REPRO_CACHE_MAX_ENTRIES"):
            assert read_env("REPRO_CACHE_MAX_ENTRIES", None) is None
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            os.environ["REPRO_CACHE_MAX_ENTRIES"] = "12"
            assert read_env("REPRO_CACHE_MAX_ENTRIES", None) == 12
    finally:
        del os.environ["REPRO_CACHE_MAX_ENTRIES"]
