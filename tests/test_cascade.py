"""Fidelity cascade: keep rules, staged screening, zero-cost proxies,
the `fidelity:` spec section, and the end-to-end determinism contract
(identical survivors / funnel / best trial across every backend and
schedule at a fixed seed)."""
import math

import jax
import jax.numpy as jnp
import pytest

from repro import Explorer
from repro.core.builder import ModelBuilder
from repro.core.space import parse_search_space
from repro.core.translate import sample_architecture
from repro.evaluation import (
    CascadeRunner,
    CriteriaRunner,
    EvaluationCache,
    Estimator,
    FidelityStage,
    FlopsEstimator,
    GradNormEstimator,
    KeepRule,
    OptimizationCriteria,
    ParamCountEstimator,
    SynFlowEstimator,
    constraint_violation,
    weighted_sum,
)
from repro.explorer.experiment import ExperimentError, ExperimentSpec
from repro.explorer.registry import ESTIMATORS
from repro.hwgen.generator import generate_call_count
from repro.search.study import HardConstraintViolated

# the canonical tiny space shared with the cross-backend parity matrix
from test_parity_matrix import CANONICAL_SPACE as TINY_SPACE

CASCADE_EXPERIMENT = {
    "name": "cascade-tiny",
    "search_space": TINY_SPACE,
    "sampler": {"name": "random", "seed": 7},
    "executor": {"backend": "serial"},
    "criteria": [{"estimator": "flops", "kind": "objective"}],
    "fidelity": {
        "generation": 8,
        "stages": [
            {"name": "zero_cost",
             "criteria": [{"estimator": "synflow", "kind": "objective",
                           "direction": "minimize"}],
             "keep": {"top_frac": 0.5}},
        ],
    },
    "budget": {"n_trials": 16},
}


def build_tiny_models(n=4, seed=0):
    from repro.search.samplers import RandomSampler
    from repro.search.study import Study

    space = parse_search_space(dict(TINY_SPACE))
    builder = ModelBuilder(space.input_shape, space.output_dim)
    study = Study(sampler=RandomSampler(seed=seed))
    return [builder.build(sample_architecture(space, study.ask()))
            for _ in range(n)]


class FixedEstimator(Estimator):
    def __init__(self, name, values):
        self.name = name
        self.values = dict(values)  # id(candidate) -> value

    def estimate(self, candidate, context=None):
        return self.values[id(candidate)]


# ---------------------------------------------------------------------------
# keep rules
# ---------------------------------------------------------------------------

def test_keep_rule_requires_exactly_one_field():
    with pytest.raises(ValueError, match="exactly one"):
        KeepRule()
    with pytest.raises(ValueError, match="exactly one"):
        KeepRule(top_k=2, top_frac=0.5)
    with pytest.raises(ValueError, match="top_k"):
        KeepRule(top_k=0)
    with pytest.raises(ValueError, match="top_frac"):
        KeepRule(top_frac=1.5)


def test_keep_rule_survivor_semantics():
    scored = [(0, 3.0), (1, 1.0), (2, 2.0), (3, 1.0)]
    # top_k ranks by (score, index): the tie at 1.0 keeps ask order
    assert KeepRule(top_k=2).survivors(scored) == [1, 3]
    # top_frac keeps ceil(frac * n), at least one
    assert KeepRule(top_frac=0.5).survivors(scored) == [1, 3]
    assert KeepRule(top_frac=0.01).survivors(scored) == [1]
    # threshold is per-candidate, cohort-independent
    assert KeepRule(threshold=2.0).survivors(scored) == [1, 2, 3]
    assert KeepRule(threshold=0.5).survivors(scored) == []


# ---------------------------------------------------------------------------
# cascade runner construction + screening
# ---------------------------------------------------------------------------

def test_cascade_validates_stage_structure():
    crit = [OptimizationCriteria(FlopsEstimator())]
    with pytest.raises(ValueError, match="at least one stage"):
        CascadeRunner([])
    with pytest.raises(ValueError, match="keep rule"):
        CascadeRunner([FidelityStage("screen", crit),
                       FidelityStage("final",
                                     [OptimizationCriteria(ParamCountEstimator())])])
    with pytest.raises(ValueError, match="must not have a keep rule"):
        CascadeRunner([FidelityStage("final", crit, keep=KeepRule(top_k=1))])
    with pytest.raises(ValueError, match="duplicate fidelity stage"):
        CascadeRunner([
            FidelityStage("s", crit, keep=KeepRule(top_k=1)),
            FidelityStage("s", [OptimizationCriteria(ParamCountEstimator())]),
        ])
    # estimator names must be distinct across the WHOLE cascade
    with pytest.raises(ValueError, match="share estimator name"):
        CascadeRunner([
            FidelityStage("screen", crit, keep=KeepRule(top_k=1)),
            FidelityStage("final", [OptimizationCriteria(FlopsEstimator())]),
        ])


def test_single_stage_cascade_is_flat_runner():
    models = build_tiny_models(3)
    criteria = [OptimizationCriteria(FlopsEstimator()),
                OptimizationCriteria(ParamCountEstimator(), weight=0.1)]
    flat = CriteriaRunner(criteria)
    cascade = CascadeRunner([FidelityStage("final", criteria)])
    for m in models:
        assert cascade.evaluate(m) == flat.evaluate(m)
        assert cascade.evaluate_multi(m) == flat.evaluate_multi(m)
    result = cascade.screen_cohort(models)
    assert result.promoted == [0, 1, 2]
    assert result.screened == {} and result.infeasible == {}


def test_screen_cohort_promotes_screens_and_rejects():
    models = build_tiny_models(4)
    proxy = FixedEstimator("proxy", {id(m): float(i)
                                     for i, m in enumerate(models)})
    gate = FixedEstimator("gate", {id(m): float(i)
                                   for i, m in enumerate(models)})
    runner = CascadeRunner([
        FidelityStage("screen", [
            OptimizationCriteria(gate, kind="hard_constraint", limit=2.5),
            OptimizationCriteria(proxy),
        ], keep=KeepRule(top_k=2)),
        FidelityStage("final", [OptimizationCriteria(FlopsEstimator())]),
    ])
    result = runner.screen_cohort(models)
    # index 3 violates the hard gate (3.0 > 2.5) before ranking
    assert result.infeasible.keys() == {3}
    stage, exc = result.infeasible[3]
    assert stage == "screen" and isinstance(exc, HardConstraintViolated)
    # of the feasible 0..2, top_k=2 by proxy score keeps 0 and 1
    assert result.promoted == [0, 1]
    assert result.screened == {2: "screen"}
    assert result.counts == {"promoted": 2, "screened": 1, "infeasible": 1}


# ---------------------------------------------------------------------------
# satellite: direction-aware constraints ("val_accuracy >= 0.9")
# ---------------------------------------------------------------------------

def test_maximize_hard_constraint_violates_below_limit():
    models = build_tiny_models(1)
    acc = FixedEstimator("val_accuracy", {id(models[0]): 0.8})
    runner = CriteriaRunner([
        OptimizationCriteria(acc, kind="hard_constraint",
                             direction="maximize", limit=0.9),
        OptimizationCriteria(FlopsEstimator()),
    ])
    with pytest.raises(HardConstraintViolated):
        runner.evaluate(models[0])
    # the same value SATISFIES a minimize constraint with the same limit
    runner_min = CriteriaRunner([
        OptimizationCriteria(FixedEstimator("v", {id(models[0]): 0.8}),
                             kind="hard_constraint", limit=0.9),
        OptimizationCriteria(FlopsEstimator()),
    ])
    runner_min.evaluate(models[0])


def test_maximize_soft_constraint_hinge_direction():
    c = OptimizationCriteria(FixedEstimator("acc", {}),
                             kind="soft_constraint",
                             direction="maximize", limit=0.9)
    assert constraint_violation(c, 0.8) > 0.0   # below the floor: violated
    assert constraint_violation(c, 0.95) < 0.0  # above: satisfied
    # hinge enters weighted_sum only when violated
    assert weighted_sum({"acc": 0.95}, [c]) == 0.0
    assert weighted_sum({"acc": 0.8}, [c]) > 0.0


def test_staged_iteration_shared_between_paths():
    """Hard constraints run before objectives in BOTH evaluate paths —
    the expensive objective estimator must never run on a violator."""
    models = build_tiny_models(1)

    class Exploding(Estimator):
        name = "expensive"

        def estimate(self, candidate, context=None):
            raise AssertionError("objective ran despite hard violation")

    runner = CriteriaRunner([
        OptimizationCriteria(Exploding()),
        OptimizationCriteria(FixedEstimator("gate", {id(models[0]): 1.0}),
                             kind="hard_constraint", limit=0.5),
    ])
    with pytest.raises(HardConstraintViolated):
        runner.evaluate(models[0])
    with pytest.raises(HardConstraintViolated):
        runner.evaluate_multi(models[0])


# ---------------------------------------------------------------------------
# zero-cost proxies
# ---------------------------------------------------------------------------

def test_proxies_registered_as_estimators():
    assert isinstance(ESTIMATORS.get("synflow"), type)
    assert ESTIMATORS.get("synflow") is SynFlowEstimator
    assert ESTIMATORS.get("grad_norm") is GradNormEstimator


def test_proxies_deterministic_and_capacity_ordered():
    models = build_tiny_models(4, seed=3)
    syn, gn = SynFlowEstimator(), GradNormEstimator()
    for m in models:
        assert syn.estimate(m) == SynFlowEstimator().estimate(m)
        assert gn.estimate(m) == GradNormEstimator().estimate(m)
        assert math.isfinite(syn.estimate(m)) and syn.estimate(m) > 0.0


def test_proxies_never_touch_the_xla_generator():
    models = build_tiny_models(2)
    before = generate_call_count()
    for m in models:
        SynFlowEstimator().estimate(m)
        GradNormEstimator().estimate(m)
    assert generate_call_count() == before


def test_synflow_conservation_identity_matches_autodiff():
    """The one-forward fast path equals the classical |θ ⊙ ∂R/∂θ|
    backward-pass formulation on the same probe."""
    for m in build_tiny_models(3, seed=5):
        syn = SynFlowEstimator()
        probe, _ = SynFlowEstimator._probe_params(m)
        x = jnp.ones((syn.batch, m.input_shape[-1], m.input_shape[0]),
                     jnp.float32)

        def saliency(p):
            return jnp.sum(SynFlowEstimator._apply_net(m, p, x))

        grads = jax.grad(saliency)(probe)
        total = sum(float(jnp.sum(jnp.abs(g * p)))
                    for g, p in zip(jax.tree_util.tree_leaves(grads),
                                    jax.tree_util.tree_leaves(probe)))
        assert syn._score(m) == pytest.approx(math.log1p(total), rel=1e-5)


def test_proxy_scores_ride_the_disk_cache(tmp_path):
    model = build_tiny_models(1)[0]
    store = str(tmp_path / "cache")
    first = SynFlowEstimator(cache=EvaluationCache(disk=store))
    score = first.estimate(model)

    class Broken(SynFlowEstimator):
        def _score(self, candidate):
            raise AssertionError("disk tier missed: proxy recomputed")

    second = Broken(cache=EvaluationCache(disk=store))
    assert second.estimate(model) == score


def test_proxy_batch_env_knob(monkeypatch):
    monkeypatch.setenv("REPRO_PROXY_BATCH", "5")
    assert SynFlowEstimator().batch == 5
    monkeypatch.delenv("REPRO_PROXY_BATCH")
    assert SynFlowEstimator(batch=3).batch == 3


# ---------------------------------------------------------------------------
# fidelity spec validation
# ---------------------------------------------------------------------------

def make_cascade_experiment(tmp_path, **overrides):
    import copy

    raw = copy.deepcopy(CASCADE_EXPERIMENT)
    raw["report_dir"] = str(tmp_path / "results")
    raw.update(copy.deepcopy(overrides))
    return raw


def test_fidelity_spec_round_trips(tmp_path):
    spec = ExperimentSpec.from_dict(make_cascade_experiment(tmp_path))
    again = ExperimentSpec.from_dict(spec.to_dict())
    assert again.to_dict()["fidelity"] == spec.to_dict()["fidelity"]
    assert spec.fidelity.generation == 8
    assert spec.fidelity.stages[0].keep.top_frac == 0.5


@pytest.mark.parametrize("mutation, message", [
    ({"fidelity": {"generation": 8, "stages": []}}, "non-empty list"),
    ({"fidelity": {"stages": [{"name": "final", "criteria": [
        {"estimator": "synflow"}], "keep": {"top_k": 1}}]}}, "reserved"),
    ({"fidelity": {"stages": [{"name": "s", "criteria": [
        {"estimator": "synflow"}],
        "keep": {"top_k": 1, "top_frac": 0.5}}]}}, "exactly one"),
    ({"fidelity": {"stages": [{"name": "s", "criteria": [
        {"estimator": "synflow"}], "keep": {"bogus": 1}}]}}, "unknown"),
    ({"fidelity": {"stages": [{"name": "s", "criteria": [
        {"estimator": "flops"}], "keep": {"top_k": 1}}]}},
     "share estimator name|flops"),
])
def test_fidelity_spec_rejects_bad_configs(tmp_path, mutation, message):
    with pytest.raises((ExperimentError, ValueError), match=message):
        ExperimentSpec.from_dict(make_cascade_experiment(tmp_path, **mutation))


# ---------------------------------------------------------------------------
# satellite: fixed-seed determinism across backends and schedules
# ---------------------------------------------------------------------------

def run_cascade(tmp_path, backend, schedule, n_workers=2):
    raw = make_cascade_experiment(
        tmp_path,
        executor={"backend": backend,
                  "n_workers": 1 if backend == "serial" else n_workers},
        schedule={"mode": schedule},
    )
    explorer = Explorer.from_dict(raw)
    report = explorer.run(save_report=False)
    study = explorer.study
    screened = sorted(t.number for t in study.trials
                      if t.user_attrs.get("fidelity_stage") == "zero_cost")
    promoted = sorted(t.number for t in study.trials
                      if t.user_attrs.get("fidelity_stage") == "promoted")
    return {
        "funnel": report.fidelity["funnel"],
        "screened": screened,
        "promoted": promoted,
        "best_number": report.best["number"],
        "best_values": report.best["values"],
        "states": report.states,
    }


@pytest.mark.parametrize("backend", ("serial", "thread", "process"))
@pytest.mark.parametrize("schedule", ("batch", "sliding_window"))
def test_cascade_deterministic_across_backends(tmp_path, backend, schedule):
    reference = run_cascade(tmp_path / "ref", "serial", "batch")
    assert reference["funnel"]["asked"] == 16
    assert reference["funnel"]["screened"] == 8
    assert reference["funnel"]["promoted"] == 8
    assert run_cascade(tmp_path / "run", backend, schedule) == reference


def test_cascade_report_funnel_and_spearman(tmp_path):
    raw = make_cascade_experiment(tmp_path)
    explorer = Explorer.from_dict(raw)
    report = explorer.run(save_report=False)
    funnel = report.fidelity["funnel"]
    assert funnel["asked"] == 16
    assert funnel["screened"] + funnel["promoted"] + funnel["infeasible"] == 16
    # the final stage here is analytic — nothing may compile at all
    assert funnel["compiled"] == 0
    rho = report.fidelity["spearman"]["zero_cost"]
    assert rho is None or -1.0 <= rho <= 1.0
    # screened trials carry the stage score attr for the correlation
    scored = [t for t in explorer.study.trials
              if "fidelity_score:zero_cost" in t.user_attrs]
    assert len(scored) == 16
    assert report.to_dict()["fidelity"]["funnel"] == funnel
