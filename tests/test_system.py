"""End-to-end behaviour tests for the whole system.

Covers: the paper's full workflow (YAML space -> sampled trials -> dynamic
models -> staged criteria with HIL latency -> study results), the training
driver with kill/resume fault tolerance, the serving driver, and the
gradient-compression training path.
"""
import json
import os
import subprocess
import sys

import numpy as np

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ENV = {**os.environ, "PYTHONPATH": SRC}


def test_paper_workflow_end_to_end(tmp_path):
    """Listing-3-style NAS with staged criteria + pruning + storage."""
    from repro.core.builder import ModelBuilder
    from repro.core.space import parse_search_space
    from repro.core.translate import sample_architecture
    from repro.data.pipeline import SyntheticClassificationData
    from repro.evaluation import (
        CompiledLatencyEstimator,
        CriteriaRunner,
        OptimizationCriteria,
        ParamCountEstimator,
        TrainedAccuracyEstimator,
    )
    from repro.search import Study, TPESampler

    space = parse_search_space("""
input: [2, 128]
output: 4
sequence:
  - block: "features"
    op_candidates: "conv-block"
    type_repeat:
      type: "vary_all"
      depth: [1, 2]
  - block: "head"
    op_candidates: "linear"
    linear:
      width: [16, 32]
default_op_params:
  conv1d:
    kernel_size: [3]
    out_channels: [4, 8]
composites:
  conv-block:
    sequence:
      - block: "c"
        op_candidates: "conv1d"
      - block: "p"
        op_candidates: ["maxpool", "identity"]
preprocessing:
  normalize:
    kind: ["zscore"]
""")
    data = SyntheticClassificationData(n=160, length=128, channels=2, classes=4).split()
    builder = ModelBuilder(space.input_shape, space.output_dim)
    runner = CriteriaRunner([
        OptimizationCriteria(ParamCountEstimator(), kind="hard_constraint", limit=5e5),
        OptimizationCriteria(TrainedAccuracyEstimator(steps=25, batch=16),
                             kind="objective", direction="maximize"),
        OptimizationCriteria(CompiledLatencyEstimator("host_cpu", batch=4),
                             kind="soft_constraint", limit=0.05, weight=0.2),
    ])
    storage = os.path.join(tmp_path, "study.jsonl")
    study = Study(sampler=TPESampler(seed=0, n_startup=3), storage=storage)

    def objective(trial):
        arch = sample_architecture(space, trial)
        model = builder.build(arch)
        return runner.evaluate(model, context={"data": data, "trial": trial}, trial=trial)

    study.optimize(objective, 6)
    done = study.completed_trials
    assert done, "no trial completed"
    best = study.best_trial
    assert best.user_attrs["val_accuracy"] > 0.3  # learned something
    assert best.user_attrs["n_params"] <= 5e5
    # storage survives
    study2 = Study(storage=storage)
    assert len(study2.trials) == 6


def _run(args, timeout=600, **kw):
    return subprocess.run(args, env=ENV, timeout=timeout, capture_output=True,
                          text=True, **kw)


def test_train_driver_resume_after_kill(tmp_path):
    ckpt = os.path.join(tmp_path, "ck")
    base = [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-1.7b",
            "--smoke", "--seq", "32", "--global-batch", "2", "--ckpt-dir", ckpt,
            "--ckpt-every", "5", "--log-every", "100"]
    r1 = _run(base + ["--steps", "12"])
    assert r1.returncode == 0, r1.stderr[-2000:]
    r2 = _run(base + ["--steps", "20"])
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step" in r2.stdout
    final = json.loads(r2.stdout.strip().splitlines()[-1])
    assert np.isfinite(final["final_loss"])


def test_serve_driver(tmp_path):
    r = _run([sys.executable, "-m", "repro.launch.serve", "--arch", "xlstm-1.3b",
              "--smoke", "--requests", "2", "--max-batch", "2",
              "--prompt-lens", "8", "--gen-lens", "6"])
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["served"] == 2 and out["shed"] == 0
    assert out["tokens_generated"] == 2 * 6


def test_train_with_compression():
    r = _run([sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-1.7b",
              "--smoke", "--steps", "8", "--seq", "32", "--global-batch", "2",
              "--compression", "--log-every", "4"])
    assert r.returncode == 0, r.stderr[-2000:]
    final = json.loads(r.stdout.strip().splitlines()[-1])
    assert np.isfinite(final["final_loss"])


def test_dryrun_single_cell_small_mesh():
    """Integration: the dry-run machinery on an 8-device spoofed host."""
    code = (
        "import os; os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8';\n"
        "import jax, functools, jax.numpy as jnp\n"
        "from repro.launch import mesh as M\n"
        "M.make_production_mesh = lambda multi_pod=False: M.make_mesh((2,4), ('data','model'))\n"
        "from repro.launch.dryrun import build_cell\n"
        "step, args, in_sh, out_sh, mesh, meta = build_cell('qwen3-1.7b', 'train_4k', False, cost_variant=True, n_units=2, overrides={'remat': False})\n"
        "lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh).lower(*args)\n"
        "c = lowered.compile()\n"
        "from repro.compat import cost_analysis_dict\n"
        "print('flops', cost_analysis_dict(c).get('flops'))\n"
    )
    r = _run([sys.executable, "-c", code], timeout=1200)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "flops" in r.stdout
