"""Distributed execution: transport framing + handshake, the worker
daemon, RemoteClient fault tolerance (death -> resubmit, heartbeat
timeout, retry exhaustion), RemoteExecutor parity vs the serial
reference at a fixed seed, mid-trial pruner refresh, graceful
degradation, spec plumbing, the sweep-cell scheduler, and the
shared-filesystem lock fallback.  Workers are in-process loopback
``WorkerServer`` instances (ephemeral ports); objectives are
module-level so they pickle by reference across the wire."""
import errno
import operator
import os
import pickle
import subprocess
import sys
import threading
import time
import warnings

import pytest

from repro.search import (
    MedianPruner,
    ParallelStudy,
    RandomSampler,
    Study,
    TrialPruned,
    TrialState,
)
from repro.search.remote import transport
from repro.search.remote.client import RemoteClient
from repro.search.remote.executor import RemoteExecutor
from repro.search.remote.worker import DropConnection, WorkerServer


def _quadratic(trial):
    x = trial.suggest_float("x", -4.0, 4.0)
    y = trial.suggest_float("y", -4.0, 4.0)
    return (x - 1.0) ** 2 + (y + 0.5) ** 2


_PRUNE_BUDGET = 10


def _prunable(trial):
    bad = trial.number % 4 == 3
    base = 100.0 if bad else 1.0
    for step in range(_PRUNE_BUDGET):
        trial.report(step, base + 0.01 * step)
        if trial.should_prune():
            trial.set_user_attr("steps_run", step + 1)
            raise TrialPruned()
        time.sleep(0.01)
    trial.set_user_attr("steps_run", _PRUNE_BUDGET)
    return base


def _fingerprint(study):
    return [(t.number, dict(t.params), t.values) for t in study.trials]


def _start_servers(n, **kwargs):
    servers = [WorkerServer(**kwargs) for _ in range(n)]
    addrs = []
    for s in servers:
        host, port = s.start()
        addrs.append(f"{host}:{port}")
    return servers, addrs


@pytest.fixture
def pool():
    servers, addrs = _start_servers(2)
    yield addrs
    for s in servers:
        s.stop()


# ---------------------------------------------------------------------------
# transport: framing + handshake
# ---------------------------------------------------------------------------

def test_frame_roundtrip_over_socketpair():
    import socket

    a, b = socket.socketpair()
    left, right = transport.Connection(a), transport.Connection(b)
    try:
        left.send("submit", {"task": "t1"}, b"\x00payload\xff")
        msg = right.recv(timeout=2.0)
        assert (msg.kind, msg.meta, msg.payload) == \
            ("submit", {"task": "t1"}, b"\x00payload\xff")
        # empty-payload control frame
        right.send("heartbeat", {"n": 3})
        msg = left.recv(timeout=2.0)
        assert msg.kind == "heartbeat" and msg.meta == {"n": 3} and msg.payload == b""
        # no frame pending: timeout yields None, stream stays usable
        assert left.recv(timeout=0.05) is None
        right.send("bye")
        assert left.recv(timeout=2.0).kind == "bye"
    finally:
        left.close()
        right.close()


def test_recv_raises_closed_on_eof():
    import socket

    a, b = socket.socketpair()
    left, right = transport.Connection(a), transport.Connection(b)
    right.close()
    with pytest.raises(transport.ConnectionClosed):
        left.recv(timeout=2.0)
    left.close()


def test_parse_addr():
    assert transport.parse_addr("10.0.0.2:7471") == ("10.0.0.2", 7471)
    for bad in ("nope", ":7471", "host:", "host:port"):
        with pytest.raises(ValueError, match="host:port"):
            transport.parse_addr(bad)


def test_handshake_protocol_mismatch_rejected(pool):
    conn = transport.connect(pool[0])
    try:
        with pytest.raises(transport.HandshakeError, match="protocol mismatch"):
            transport.client_hello(conn, hello_meta={"protocol": 999})
    finally:
        conn.close()


def test_handshake_toolchain_mismatch_rejected():
    servers, addrs = _start_servers(1, toolchain={"jax": "not-what-you-have"})
    try:
        conn = transport.connect(addrs[0])
        try:
            with pytest.raises(transport.HandshakeError, match="toolchain mismatch"):
                transport.client_hello(conn)
        finally:
            conn.close()
        # the pool client treats a rejecting worker as absent, with a warning
        client = RemoteClient(addrs)
        with pytest.warns(RuntimeWarning, match="rejected the handshake"):
            live = client.connect()
        assert live == []
        client.close()
    finally:
        for s in servers:
            s.stop()


# ---------------------------------------------------------------------------
# RemoteClient: dispatch + fault tolerance (stubbed failure seams)
# ---------------------------------------------------------------------------

def _call_payload(fn, *args):
    blob = pickle.dumps(("call", (fn, args, {})), protocol=pickle.HIGHEST_PROTOCOL)
    return lambda: blob


class _Done:
    def __init__(self):
        self.event = threading.Event()
        self.value = self.error = self.worker = None

    def __call__(self, key, value, error, worker_addr):
        self.value, self.error, self.worker = value, error, worker_addr
        self.event.set()


def test_client_runs_generic_calls(pool):
    client = RemoteClient(pool)
    assert sorted(client.connect()) == sorted(pool)
    try:
        done = _Done()
        client.submit("k", _call_payload(operator.add, 2, 3), done)
        assert done.event.wait(10.0)
        assert done.error is None and done.value == 5
        assert done.worker in pool
    finally:
        client.close()


def test_worker_death_resubmits_to_sibling():
    class DieOnce:
        def __init__(self):
            self.dropped = False

        def __call__(self, task_id, task):
            if not self.dropped:
                self.dropped = True
                raise DropConnection()

    hook = DieOnce()
    flaky, flaky_addrs = _start_servers(1, task_hook=hook)
    steady, steady_addrs = _start_servers(1)
    client = RemoteClient(flaky_addrs + steady_addrs, retries=2)
    try:
        client.connect()
        done = _Done()
        with pytest.warns(RuntimeWarning, match="lost"):
            # dispatch order follows connect order: the first (flaky)
            # worker gets the task and severs the connection
            client.submit("k", _call_payload(operator.mul, 6, 7), done)
            assert done.event.wait(10.0)
        assert hook.dropped
        assert done.error is None and done.value == 42
        assert done.worker == steady_addrs[0]  # the sibling finished it
    finally:
        client.close()
        for s in flaky + steady:
            s.stop()


def test_retries_exhausted_surfaces_error():
    def die(task_id, task):
        raise DropConnection()

    servers, addrs = _start_servers(2, task_hook=die)
    client = RemoteClient(addrs, retries=0)
    try:
        client.connect()
        done = _Done()
        with pytest.warns(RuntimeWarning, match="lost"):
            client.submit("k", _call_payload(operator.add, 1, 1), done)
            assert done.event.wait(10.0)
        assert done.value is None
        assert "attempts" in str(done.error)
    finally:
        client.close()
        for s in servers:
            s.stop()


def test_heartbeat_timeout_declares_worker_lost():
    hang = threading.Event()
    # heartbeat_s=0: the daemon never heartbeats; the hook wedges the
    # task, so the client sees acks then total silence
    servers, addrs = _start_servers(
        1, heartbeat_s=0, task_hook=lambda tid, task: hang.wait(30.0))
    client = RemoteClient(addrs, retries=0, heartbeat_timeout_s=0.5)
    try:
        client.connect()
        done = _Done()
        with pytest.warns(RuntimeWarning, match="lost"):
            client.submit("k", _call_payload(operator.add, 1, 1), done)
            assert done.event.wait(10.0)
        assert done.value is None
        assert "silent" in str(done.error)
        assert client.live_workers() == []
    finally:
        hang.set()
        client.close()
        for s in servers:
            s.stop()


def test_submit_with_dead_pool_fails_inline():
    client = RemoteClient(["127.0.0.1:9"], connect_timeout_s=0.2)
    with pytest.warns(RuntimeWarning, match="unreachable"):
        assert client.connect() == []
    done = _Done()
    client.submit("k", _call_payload(operator.add, 1, 1), done)
    assert done.event.is_set()
    assert "no live remote workers" in str(done.error)
    client.close()


# ---------------------------------------------------------------------------
# RemoteExecutor: fixed-seed parity, pruning, degradation, env plumbing
# ---------------------------------------------------------------------------

def test_remote_parity_with_serial_reference(pool):
    ref = Study(sampler=RandomSampler(seed=7))
    ref.optimize(_quadratic, 10)
    s = ParallelStudy(sampler=RandomSampler(seed=7), n_workers=2,
                      backend=RemoteExecutor(workers=pool),
                      schedule="sliding_window", tell_order="completion")
    s.optimize(_quadratic, 10)
    assert _fingerprint(s) == _fingerprint(ref)
    assert s.best_trial.number == ref.best_trial.number
    assert s.best_trial.values == ref.best_trial.values


def test_remote_parity_survives_worker_death():
    """Kill one of two workers on its first task: bounded resubmission
    must finish the run with the exact serial-reference trials — the
    detached-plan determinism the fault story rests on."""
    class DieOnce:
        def __init__(self):
            self.dropped = False

        def __call__(self, task_id, task):
            if not self.dropped:
                self.dropped = True
                raise DropConnection()

    hook = DieOnce()
    flaky, flaky_addrs = _start_servers(1, task_hook=hook)
    steady, steady_addrs = _start_servers(1)
    try:
        ref = Study(sampler=RandomSampler(seed=11))
        ref.optimize(_quadratic, 8)
        s = ParallelStudy(sampler=RandomSampler(seed=11), n_workers=2,
                          backend=RemoteExecutor(workers=flaky_addrs + steady_addrs),
                          schedule="sliding_window", tell_order="completion")
        with pytest.warns(RuntimeWarning, match="lost"):
            s.optimize(_quadratic, 8)
        assert hook.dropped
        assert all(t.state == TrialState.COMPLETE for t in s.trials)
        assert _fingerprint(s) == _fingerprint(ref)
    finally:
        for srv in flaky + steady:
            srv.stop()


def test_remote_prunes_worker_side(pool):
    s = ParallelStudy(sampler=RandomSampler(seed=0), n_workers=2,
                      backend=RemoteExecutor(workers=pool),
                      schedule="sliding_window", tell_order="completion",
                      pruner=MedianPruner(n_startup_trials=2))
    s.optimize(_prunable, 12)
    pruned = [t for t in s.trials if t.state == TrialState.PRUNED]
    assert pruned, "expected doomed trials to be pruned inside remote workers"
    for t in pruned:
        assert t.user_attrs["steps_run"] < _PRUNE_BUDGET
        assert t.intermediate  # streamed report frames merged back
    complete = [t for t in s.trials if t.state == TrialState.COMPLETE]
    assert all(t.user_attrs["steps_run"] == _PRUNE_BUDGET for t in complete)


def test_no_reachable_workers_degrades_to_fallback():
    ex = RemoteExecutor(workers=["127.0.0.1:9"], connect_timeout_s=0.2,
                        fallback="serial")
    ref = Study(sampler=RandomSampler(seed=5))
    ref.optimize(_quadratic, 5)
    s = ParallelStudy(sampler=RandomSampler(seed=5), n_workers=2,
                      backend=ex, schedule="sliding_window")
    with pytest.warns(RuntimeWarning, match="degrading"):
        s.optimize(_quadratic, 5)
    assert all(t.state == TrialState.COMPLETE for t in s.trials)
    assert _fingerprint(s) == _fingerprint(ref)


def test_executor_requires_a_worker_pool(monkeypatch):
    monkeypatch.delenv("REPRO_REMOTE_WORKERS", raising=False)
    with pytest.raises(ValueError, match="REPRO_REMOTE_WORKERS"):
        RemoteExecutor().start(1)


def test_executor_reads_workers_from_env(pool, monkeypatch):
    monkeypatch.setenv("REPRO_REMOTE_WORKERS", ",".join(pool))
    ex = RemoteExecutor()
    ex.start(2)
    try:
        assert sorted(ex._client.live_workers()) == sorted(pool)
    finally:
        ex.shutdown()


# ---------------------------------------------------------------------------
# mid-trial pruner refresh: the delta fold is shared and in-place
# ---------------------------------------------------------------------------

def test_apply_pruner_deltas_refreshes_live_contexts():
    from repro.search.detached import (
        _DELTA_HISTORY,
        PrunerContext,
        apply_pruner_deltas,
    )

    cid = "ctx-refresh-test"
    try:
        ctx = PrunerContext(MedianPruner(n_startup_trials=0), ("minimize",),
                            deltas=[("report", 0, 0, 1.0)], base=0,
                            context_id=cid)
        ctx.apply()
        assert _DELTA_HISTORY[cid][0] == 1
        # a refresh arriving while ctx's trial runs: same records dict,
        # so the running trial's next should_prune sees trial 1
        assert apply_pruner_deltas(cid, 1, [("report", 1, 0, 5.0)]) == 2
        assert 1 in ctx._applied[1]
        assert ctx._applied[1][1].intermediate == {0: 5.0}
        # idempotent replay of an already-applied slice
        assert apply_pruner_deltas(
            cid, 0, [("report", 0, 0, 1.0), ("report", 1, 0, 5.0)]) == 2
        assert ctx._applied[1][0].intermediate == {0: 1.0}
        # a tail starting past what we hold is unusable: ack what we have
        assert apply_pruner_deltas(cid, 10, [("report", 9, 0, 1.0)]) == 2
        # terminal record supersedes streamed reports
        apply_pruner_deltas(
            cid, 2, [("final", 0, TrialState.COMPLETE, (1.5,), {0: 1.0})])
        assert ctx._applied[1][0].state == TrialState.COMPLETE
    finally:
        _DELTA_HISTORY.pop(cid, None)


# ---------------------------------------------------------------------------
# spec plumbing: executor.workers in the YAML surface
# ---------------------------------------------------------------------------

def test_executor_spec_workers_plumbing():
    from repro.explorer.experiment import ExecutorSpec, ExperimentError

    spec = ExecutorSpec.from_raw({"backend": "remote",
                                  "workers": ["h:7471", "g:7472"]})
    assert spec.n_workers == 2  # defaults to the pool size
    assert spec.to_dict() == {"backend": "remote", "n_workers": 2,
                              "workers": ["h:7471", "g:7472"]}
    # options bind against the constructor signature at parse time
    spec = ExecutorSpec.from_raw({"backend": "remote", "workers": ["h:1"],
                                  "options": {"retries": 5, "fallback": "serial"}})
    assert spec.options == {"retries": 5, "fallback": "serial"}
    with pytest.raises(ExperimentError):
        ExecutorSpec.from_raw({"backend": "remote", "workers": ["h:1"],
                               "options": {"bogus": 1}})
    # backends without a worker pool reject `workers` at parse time
    with pytest.raises(ExperimentError):
        ExecutorSpec.from_raw({"backend": "serial", "workers": ["h:1"]})
    with pytest.raises(ExperimentError, match="host:port"):
        ExecutorSpec.from_raw({"backend": "remote", "workers": ["nope"]})
    with pytest.raises(ExperimentError, match="non-empty"):
        ExecutorSpec.from_raw({"backend": "remote", "workers": []})
    # legacy round-trip shape untouched (persisted-report resume)
    assert ExecutorSpec.from_raw("serial").to_dict() == \
        {"backend": "serial", "n_workers": 1}


# ---------------------------------------------------------------------------
# sweep-cell scheduler: fan cells across the pool, resume still works
# ---------------------------------------------------------------------------

# the canonical tiny space shared with the cross-backend parity matrix
from test_parity_matrix import CANONICAL_SPACE as TINY_SPACE


def _tiny_sweep(tmp_path):
    return {
        "name": "remote-sweep",
        "base": {
            "name": "tiny",
            "search_space": TINY_SPACE,
            "sampler": {"name": "random", "seed": 0},
            "executor": {"backend": "serial"},
            "criteria": [{"estimator": "flops", "kind": "objective",
                          "weight": 1.0}],
            "budget": {"n_trials": 3},
        },
        "axes": {"targets": ["host_cpu", "edge_npu"]},
        "report_dir": str(tmp_path / "results"),
    }


def test_sweep_cells_fan_across_workers(tmp_path, pool):
    from repro.explorer.sweep import SweepSpec, run_sweep

    spec = SweepSpec.from_dict(_tiny_sweep(tmp_path))
    report = run_sweep(spec, workers=list(pool))
    assert report.n_cells == 2 and report.n_resumed == 0
    assert all(c["best"] is not None for c in report.cells)
    # the parent persisted each worker-computed report at the local cell
    # path, so a re-run resumes every cell instead of recomputing
    for cell in spec.expand():
        assert os.path.exists(cell.report_path)
    again = run_sweep(SweepSpec.from_dict(_tiny_sweep(tmp_path)))
    assert again.n_resumed == 2
    assert [c["best"]["values"] for c in again.cells] == \
        [c["best"]["values"] for c in report.cells]


def test_sweep_remote_matches_local_reports(tmp_path, pool):
    from repro.explorer.sweep import SweepSpec, run_sweep

    raw = _tiny_sweep(tmp_path)
    local = run_sweep(SweepSpec.from_dict(raw), save_report=False)
    raw["report_dir"] = str(tmp_path / "results2")
    remote = run_sweep(SweepSpec.from_dict(raw), save_report=False,
                       workers=list(pool))
    assert [c["best"]["values"] for c in remote.cells] == \
        [c["best"]["values"] for c in local.cells]
    assert [c["name"] for c in remote.cells] == [c["name"] for c in local.cells]


def test_sweep_unreachable_pool_falls_back_to_local(tmp_path):
    from repro.explorer.sweep import SweepSpec, run_sweep

    spec = SweepSpec.from_dict(_tiny_sweep(tmp_path))
    with pytest.warns(RuntimeWarning):
        report = run_sweep(spec, workers=["127.0.0.1:9"])
    assert report.n_cells == 2
    assert all(c["best"] is not None for c in report.cells)


# ---------------------------------------------------------------------------
# shared-filesystem robustness + worker cache plumbing
# ---------------------------------------------------------------------------

def test_flock_fallback_to_lockf(tmp_path, monkeypatch):
    from repro import ioutils

    def no_flock(fd, op):
        raise OSError(errno.ENOLCK, "No locks available")

    monkeypatch.setattr(ioutils.fcntl, "flock", no_flock)
    path = str(tmp_path / "store.jsonl")
    try:
        with pytest.warns(RuntimeWarning, match="flock unsupported"):
            ioutils.locked_append(path, "a\n")
        # the path is remembered: no re-probe, no second warning
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            ioutils.locked_append(path, "b\n")
        with open(path) as f:
            assert f.read() == "a\nb\n"
    finally:
        ioutils._FLOCK_UNSUPPORTED.discard(path)


def test_cache_dir_env_redirects_store(tmp_path, monkeypatch):
    from repro.evaluation.disk_cache import DiskEvaluationCache

    store = tmp_path / "shared-store"
    monkeypatch.setenv("REPRO_CACHE_DIR", str(store))
    cache = DiskEvaluationCache(path=str(tmp_path / "ignored"))
    assert cache.path == str(store)
    assert store.is_dir()
    assert not (tmp_path / "ignored").exists()


# ---------------------------------------------------------------------------
# the CLI daemon end-to-end (subprocess)
# ---------------------------------------------------------------------------

def test_worker_cli_subprocess_roundtrip(tmp_path):
    import repro

    src = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.worker", "--no-warmup", "--port", "0",
         "--cache-dir", str(tmp_path / "cache")],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True, env=env)
    try:
        addr = None
        deadline = time.monotonic() + 60.0
        while time.monotonic() < deadline:
            line = proc.stdout.readline()
            if not line:
                break
            if line.startswith("listening on "):
                addr = line.split()[-1].strip()
                break
        assert addr, "daemon never printed its bound address"
        conn = transport.connect(addr)
        try:
            hello = transport.client_hello(conn)
            assert hello.get("worker")
            conn.send("submit", {"task": "t1"},
                      pickle.dumps(("call", (operator.add, (2, 3), {})),
                                   protocol=pickle.HIGHEST_PROTOCOL))
            result = None
            deadline = time.monotonic() + 30.0
            while time.monotonic() < deadline:
                msg = conn.recv(timeout=1.0)
                if msg is None or msg.kind in ("ack", "heartbeat"):
                    continue
                result = msg
                break
            assert result is not None and result.kind == "result"
            assert pickle.loads(result.payload) == 5
            conn.send("bye")
        finally:
            conn.close()
    finally:
        proc.terminate()
        proc.wait(timeout=10.0)
