"""Kernel schedules: validation, parity, threading, and tuning.

The contract under test (see ``repro/kernels/schedule.py``):

  * every legal candidate schedule computes the same values as the
    pure-jnp oracles in ``repro/kernels/ref.py`` — blocking is a launch
    decision, never a numerics decision;
  * resolving the named ``default`` schedule is bit-identical to calling
    the kernels with their legacy constants;
  * validation errors name the offending field;
  * effective (shape-clamped) schedules mirror what the ops layer
    launches, and the recorder/sink sees exactly that;
  * the autotuner honors budget/overrides and memoizes sweeps.

No hypothesis dependency on purpose: this suite must run in the bare
container (``tests/test_kernels.py`` module-skips without hypothesis).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import schedule as ksched
from repro.kernels.schedule import (
    CANDIDATE_SCHEDULES,
    KERNEL_FIELDS,
    KernelSchedule,
    ScheduleError,
    as_schedule,
    default_schedule,
    effective_schedule,
    schedule_signature,
    use_schedules,
    validate_schedule,
)

L = 256  # divides every scan candidate chunk; spans the flash block grid


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# shared inputs + per-kernel call plumbing
# ---------------------------------------------------------------------------

def _flash_inputs():
    q = _rand(0, (1, L, 2, 8))
    k = _rand(1, (1, L, 2, 8))
    v = _rand(2, (1, L, 2, 8))
    return q, k, v


def _flash_ref(q, k, v):
    # ref takes (B, H, S, D); ops takes the model layout (B, S, H, D)
    out = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True)
    return out.transpose(0, 2, 1, 3)


def _ssm_inputs():
    x = _rand(3, (1, L, 2, 8))
    dt = jax.nn.softplus(_rand(4, (1, L, 2)))
    a = -jnp.exp(_rand(5, (2,)))
    b = _rand(6, (1, L, 1, 4))  # one group, expanded to 2 heads inside ops
    c = _rand(7, (1, L, 1, 4))
    return x, dt, a, b, c


def _mlstm_inputs():
    q = _rand(8, (1, L, 2, 8))
    k = _rand(9, (1, L, 2, 8))
    v = _rand(10, (1, L, 2, 8))
    i_log = _rand(11, (1, L, 2))
    f_log = _rand(12, (1, L, 2)) + 3.0
    return q, k, v, i_log, f_log


def _call(kernel, schedule=None, **kwargs):
    """Run one schedulable op on the shared inputs; returns the primary
    output array."""
    if kernel == "flash_attention":
        return ops.flash_attention(*_flash_inputs(), causal=True,
                                   schedule=schedule, **kwargs)
    if kernel == "ssm_scan":
        y, _ = ops.ssm_scan(*_ssm_inputs(), schedule=schedule, **kwargs)
        return y
    q, k, v, i_log, f_log = _mlstm_inputs()
    h, _ = ops.mlstm_scan(q, k, v, i_log, f_log, schedule=schedule, **kwargs)
    return h


def _oracle(kernel):
    if kernel == "flash_attention":
        return _flash_ref(*_flash_inputs())
    if kernel == "ssm_scan":
        x, dt, a, b, c = _ssm_inputs()
        b_mat = jnp.repeat(b, 2, axis=2)
        c_mat = jnp.repeat(c, 2, axis=2)
        y, _ = ref.ssm_scan_ref(x, dt, a, b_mat, c_mat)
        return y
    return ref.mlstm_scan_ref(*_mlstm_inputs())


_PARITY_CASES = [(kernel, cand)
                 for kernel, grid in sorted(CANDIDATE_SCHEDULES.items())
                 for cand in grid]


@pytest.mark.parametrize(
    "kernel,cand", _PARITY_CASES,
    ids=[f"{k}-{schedule_signature(k, c.merged_over(default_schedule(k)))}"
         for k, c in _PARITY_CASES])
def test_every_candidate_schedule_matches_reference(kernel, cand):
    out = _call(kernel, schedule=cand)
    want = _oracle(kernel)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("kernel", sorted(KERNEL_FIELDS))
def test_default_schedule_is_bit_identical_to_legacy_path(kernel):
    """Resolving the named default must reproduce the legacy constant
    path bit-for-bit — same blocks, same launch, same floats."""
    plain = _call(kernel)  # no schedule anywhere -> named default
    explicit = _call(kernel, schedule=default_schedule(kernel))
    if kernel == "flash_attention":
        legacy = _call(kernel, block_q=128, block_kv=128)
    else:
        legacy = _call(kernel, chunk=128)
    assert np.array_equal(np.asarray(plain), np.asarray(explicit))
    assert np.array_equal(np.asarray(plain), np.asarray(legacy))


# ---------------------------------------------------------------------------
# validation: errors name the offending field
# ---------------------------------------------------------------------------

def test_unknown_kernel_named_in_error():
    with pytest.raises(ScheduleError, match="warp_drive"):
        validate_schedule("warp_drive", KernelSchedule())


def test_inapplicable_field_named_in_error():
    with pytest.raises(ScheduleError, match="'chunk'"):
        validate_schedule("flash_attention", KernelSchedule(chunk=64))
    with pytest.raises(ScheduleError, match="'block_q'"):
        validate_schedule("ssm_scan", KernelSchedule(block_q=64))


def test_non_integer_field_named_in_error():
    with pytest.raises(ScheduleError, match="'chunk'"):
        validate_schedule("ssm_scan", KernelSchedule(chunk=64.0))
    with pytest.raises(ScheduleError, match="'chunk'"):
        validate_schedule("ssm_scan", KernelSchedule(chunk=True))


def test_out_of_range_field_named_in_error():
    with pytest.raises(ScheduleError, match=r"'block_q'=4"):
        validate_schedule("flash_attention", KernelSchedule(block_q=4))
    with pytest.raises(ScheduleError, match=r"'chunk'=2048"):
        validate_schedule("ssm_scan", KernelSchedule(chunk=2048))


def test_non_power_of_two_field_named_in_error():
    with pytest.raises(ScheduleError, match=r"'block_kv'=96"):
        validate_schedule("flash_attention", KernelSchedule(block_kv=96))


def test_unknown_schedule_dict_field_rejected():
    with pytest.raises(ScheduleError, match="block_z"):
        KernelSchedule.from_dict({"block_z": 64})


def test_as_schedule_fills_defaults():
    s = as_schedule("flash_attention", {"block_q": 64})
    assert (s.block_q, s.block_kv) == (64, 128)


# ---------------------------------------------------------------------------
# effective (shape-clamped) schedules mirror the ops layer
# ---------------------------------------------------------------------------

def test_effective_flash_clamps_to_sequence():
    eff = effective_schedule("flash_attention",
                             KernelSchedule(block_q=128, block_kv=256),
                             seq_len=40, kv_len=80)
    assert (eff.block_q, eff.block_kv) == (40, 80)
    # never below the 16-row floor
    eff = effective_schedule("flash_attention", None, seq_len=4)
    assert (eff.block_q, eff.block_kv) == (16, 16)


def test_effective_chunk_halves_until_it_divides():
    eff = effective_schedule("ssm_scan", KernelSchedule(chunk=32), seq_len=48)
    assert eff.chunk == 16  # 32 -> 16 divides 48
    eff = effective_schedule("mlstm_scan", KernelSchedule(chunk=512), seq_len=192)
    assert eff.chunk == 192  # min(512, 192) already divides
    eff = effective_schedule("ssm_scan", KernelSchedule(chunk=64), seq_len=96)
    assert eff.chunk == 32  # 64 -> 32 divides 96


def test_recorder_sees_effective_not_requested():
    sink = {}
    q, k, v = _rand(0, (1, 40, 2, 8)), _rand(1, (1, 40, 2, 8)), _rand(2, (1, 40, 2, 8))
    with ksched.record_kernel_calls(sink):
        jax.eval_shape(lambda q, k, v: ops.flash_attention(
            q, k, v, schedule=KernelSchedule(block_q=256, block_kv=256)),
            q, k, v)
    (entry,) = sink.values()
    assert entry["requested"].block_q == 256
    assert entry["effective"].block_q == 40  # clamped to the sequence
    sig = ksched.effective_signature(sink)
    assert "block_q=40" in sig and "flash_attention" in sig


# ---------------------------------------------------------------------------
# trace-time threading: use_schedules precedence
# ---------------------------------------------------------------------------

def test_context_overrides_legacy_kwargs():
    want = _call("ssm_scan", schedule=KernelSchedule(chunk=32))
    with use_schedules({"ssm_scan": {"chunk": 32}}):
        got = _call("ssm_scan", chunk=128)  # legacy kwarg loses
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_explicit_schedule_overrides_context():
    want = _call("ssm_scan", schedule=KernelSchedule(chunk=64))
    with use_schedules({"ssm_scan": {"chunk": 32}}):
        got = _call("ssm_scan", schedule=KernelSchedule(chunk=64))
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_use_schedules_validates_up_front():
    with pytest.raises(ScheduleError, match="'chunk'=7"):
        with use_schedules({"ssm_scan": {"chunk": 7}}):
            pass


# ---------------------------------------------------------------------------
# autotuner: discovery, budget, overrides, memoization
# ---------------------------------------------------------------------------

def _tuner(**kwargs):
    from repro.hwgen.autotune import ScheduleTuner
    from repro.hwgen.targets import get_target
    kwargs.setdefault("warmup", 0)
    kwargs.setdefault("iters", 1)
    return ScheduleTuner(get_target("host_cpu"), **kwargs)


def _discovered_ssm():
    from repro.hwgen.autotune import discover_kernel_calls
    x, dt, a, b, c = _ssm_inputs()
    return discover_kernel_calls(
        lambda *args: ops.ssm_scan(*args)[0], (x, dt, a, b, c))


def test_discovery_finds_kernel_without_compiling():
    calls = _discovered_ssm()
    (entry,) = calls.values()
    assert entry["kernel"] == "ssm_scan"
    assert entry["shapes"]["x"] == (1, L, 2, 8)


def test_tuner_budget_caps_swept_candidates():
    tuner = _tuner(budget=2)
    (entry,) = _discovered_ssm().values()
    record = tuner.tune("ssm_scan", entry["shapes"], entry["meta"])
    assert record["n_candidates"] <= 2
    # default-first grid: the named default is always candidate 0
    assert record["candidates"][0]["schedule"] == {"chunk": 128}
    assert tuner.stats()["tunes"] == 1


def test_tuner_override_pins_kernel_without_sweeping():
    tuner = _tuner(overrides={"ssm_scan": {"chunk": 64}})
    plan = tuner.plan(_discovered_ssm())
    assert plan["ssm_scan"].chunk == 64
    assert tuner.stats() == {"tunes": 0, "cache_hits": 0, "tune_time_s": 0.0}


def test_tuner_memoizes_sweeps_in_cache(tmp_path):
    from repro.evaluation.cache import EvaluationCache
    cache = EvaluationCache(disk=str(tmp_path / "store"))
    (entry,) = _discovered_ssm().values()
    first = _tuner(budget=2, cache=cache)
    r1 = first.tune("ssm_scan", entry["shapes"], entry["meta"])
    assert first.stats()["tunes"] == 1
    # a fresh tuner over the same store re-tunes nothing (warm restart)
    second = _tuner(budget=2, cache=EvaluationCache(disk=str(tmp_path / "store")))
    r2 = second.tune("ssm_scan", entry["shapes"], entry["meta"])
    assert second.stats() == {"tunes": 0, "cache_hits": 1, "tune_time_s": 0.0}
    assert r2["schedule"] == r1["schedule"]
    # the persisted winner is the *requested* (validated) schedule
    validate_schedule("ssm_scan", as_schedule("ssm_scan", r2["schedule"]))


def test_shape_bucket_rounds_up_and_keeps_flags():
    tuner = _tuner()
    b1 = tuner.shape_bucket("ssm_scan", {"x": (1, 200, 2, 8)}, {"dtype": "float32"})
    b2 = tuner.shape_bucket("ssm_scan", {"x": (1, 256, 2, 8)}, {"dtype": "float32"})
    b3 = tuner.shape_bucket("ssm_scan", {"x": (1, 256, 2, 8)}, {"dtype": "bfloat16"})
    assert b1 == b2  # 200 buckets with 256
    assert b2 != b3  # dtype flag is part of the bucket


# ---------------------------------------------------------------------------
# spec layer: kernel_tuning section
# ---------------------------------------------------------------------------

def test_kernel_tuning_spec_roundtrip():
    from repro.explorer.experiment import KernelTuningSpec
    spec = KernelTuningSpec.from_raw(
        {"mode": "cached", "budget": 3, "kernels": {"ssm_scan": {"chunk": 64}}})
    assert spec.mode == "cached" and spec.budget == 3
    assert KernelTuningSpec.from_raw(spec.to_dict()).to_dict() == spec.to_dict()
    # bare string shorthand
    assert KernelTuningSpec.from_raw("search").mode == "search"
    assert KernelTuningSpec.from_raw(None) is None


def test_kernel_tuning_spec_rejects_bad_sections():
    from repro.explorer.experiment import ExperimentError, KernelTuningSpec
    with pytest.raises(ExperimentError, match="mode"):
        KernelTuningSpec.from_raw({"mode": "always"})
    with pytest.raises(ExperimentError, match="budget"):
        KernelTuningSpec.from_raw({"budget": 0})
    with pytest.raises(ExperimentError, match="unknown kernel"):
        KernelTuningSpec.from_raw({"kernels": {"warp_drive": {"chunk": 64}}})
    with pytest.raises(ExperimentError, match="'chunk'=7"):
        KernelTuningSpec.from_raw({"kernels": {"ssm_scan": {"chunk": 7}}})
