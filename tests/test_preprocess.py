"""Pre-processing design space (paper §IV-E)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.preprocess import build_preprocessing, build_stage


def test_downsample():
    fn, shape = build_stage({"stage": "downsample", "factor": 4}, (64, 2))
    assert shape == (16, 2)
    x = jnp.arange(64.0)[None, :, None] * jnp.ones((1, 1, 2))
    assert fn(x).shape == (1, 16, 2)
    np.testing.assert_array_equal(np.asarray(fn(x)[0, :, 0]), np.arange(0, 64, 4))


def test_sequential_window():
    fn, shape = build_stage({"stage": "window", "size": 16, "offset": 8}, (64, 3))
    assert shape == (16, 3)
    x = jnp.arange(64.0)[None, :, None] * jnp.ones((1, 1, 3))
    np.testing.assert_array_equal(np.asarray(fn(x)[0, :, 0]), np.arange(8, 24))


def test_event_window_centers_on_energy():
    fn, shape = build_stage({"stage": "event_window", "size": 16, "energy_window": 4}, (128, 1))
    x = np.zeros((2, 128, 1), np.float32)
    x[0, 60:64] = 5.0  # event near 62
    x[1, 100:104] = 5.0
    y = fn(jnp.asarray(x))
    assert y.shape == (2, 16, 1)
    assert float(jnp.sum(jnp.abs(y[0]))) > 0  # event captured in the crop
    assert float(jnp.sum(jnp.abs(y[1]))) > 0


def test_normalize_zscore():
    fn, _ = build_stage({"stage": "normalize", "kind": "zscore"}, (32, 2))
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32, 2)) * 7 + 3
    y = fn(x)
    np.testing.assert_allclose(np.asarray(jnp.mean(y, axis=1)), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(jnp.std(y, axis=1)), 1.0, atol=1e-4)


def test_filter_lowpass_attenuates_high_freq():
    fn, _ = build_stage({"stage": "filter", "taps": 63, "cutoff": 0.05, "kind": "lowpass"}, (256, 1))
    t = jnp.arange(256.0)
    lo = jnp.sin(2 * jnp.pi * 0.01 * t)
    hi = jnp.sin(2 * jnp.pi * 0.4 * t)
    x = (lo + hi)[None, :, None]
    y = fn(x)[0, 64:192, 0]  # interior (edge effects)
    resid = y - lo[64:192]
    assert float(jnp.std(resid)) < 0.2 * float(jnp.std(hi))


def test_pipeline_composition_and_shape():
    stages = [
        {"stage": "normalize", "kind": "zscore"},
        {"stage": "filter", "taps": 15, "cutoff": 0.2, "kind": "lowpass"},
        {"stage": "downsample", "factor": 2},
        {"stage": "window", "size": 20, "offset": 0},
    ]
    fn, shape = build_preprocessing(stages, (128, 2))
    assert shape == (20, 2)
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 128, 2))
    assert fn(x).shape == (3, 20, 2)


def test_empty_pipeline():
    fn, shape = build_preprocessing([], (10, 1))
    assert fn is None and shape == (10, 1)


def test_joint_sampling_with_architecture():
    from repro.core.space import parse_search_space
    from repro.core.translate import sample_architecture
    from repro.search import RandomSampler, Study

    y = """
input: [1, 64]
output: 2
sequence:
  - block: "h"
    op_candidates: "linear"
preprocessing:
  downsample:
    factor: [1, 2, 4]
  normalize:
    kind: ["zscore", "minmax"]
"""
    space = parse_search_space(y)
    study = Study(sampler=RandomSampler(seed=0))
    factors = set()
    for _ in range(10):
        arch = sample_architecture(space, study.ask())
        assert len(arch.preprocessing) == 2
        factors.add([s for s in arch.preprocessing if s["stage"] == "downsample"][0]["factor"])
    assert len(factors) > 1  # actually being searched
