"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.builder import ModelBuilder
from repro.core.space import parse_search_space
from repro.core.translate import sample_architecture
from repro.nn.moe import MoEConfig, moe_apply, moe_init, route_topk, _slot_assignment
from repro.nn.rope import apply_rope
from repro.nn.types import split
from repro.search import RandomSampler, Study

# ---------------------------------------------------------------------------
# DSL -> builder: any sampled architecture from a well-formed space builds
# and runs with the declared output shape
# ---------------------------------------------------------------------------

SPACE_TMPL = """
input: [2, {length}]
output: {out}
sequence:
  - block: "features"
    op_candidates: ["conv-unit", "maxpool", "identity"]
    type_repeat:
      type: "{mode}"
      depth: [1, 2, 3]
  - block: "head"
    op_candidates: "linear"
    linear:
      width: [8, 16]
default_op_params:
  conv1d:
    kernel_size: [3, 5]
    out_channels: [4, 8]
    stride: [1, 2]
  maxpool:
    window: [2, 4]
composites:
  conv-unit:
    sequence:
      - block: "c"
        op_candidates: "conv1d"
      - block: "n"
        op_candidates: ["layernorm", "identity"]
"""


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    mode=st.sampled_from(["vary_all", "repeat_op", "repeat_params"]),
    length=st.sampled_from([32, 48, 64]),
    out=st.integers(2, 7),
)
def test_any_sampled_architecture_builds_and_runs(seed, mode, length, out):
    space = parse_search_space(SPACE_TMPL.format(mode=mode, length=length, out=out))
    study = Study(sampler=RandomSampler(seed=seed))
    arch = sample_architecture(space, study.ask())
    model = ModelBuilder(space.input_shape, space.output_dim).build(arch)
    x = jnp.ones((2, length, 2))
    params = model.init(jax.random.PRNGKey(seed))
    y = model.apply(params, x)
    assert y.shape == (2, out)
    assert bool(jnp.isfinite(y).all())


# ---------------------------------------------------------------------------
# RoPE is an isometry per (position, head)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), d=st.sampled_from([16, 32, 64]), s=st.sampled_from([4, 9]))
def test_rope_preserves_norm(seed, d, s):
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, s, 2, d))
    pos = jnp.arange(s)[None]
    y = apply_rope(x, pos)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(y, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)),
        rtol=1e-4,
    )


# ---------------------------------------------------------------------------
# MoE dispatch invariants
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 1000),
    e=st.sampled_from([4, 8]),
    k=st.sampled_from([1, 2]),
    s=st.sampled_from([8, 16]),
)
def test_moe_slot_assignment_invariants(seed, e, k, s):
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (2, s, e))
    ids, gates, _ = route_topk(logits, k)
    cap = max(1, int(k * s * 1.0 / e))
    slot_token, token_slot = _slot_assignment(ids, e, cap)
    st_np, tt_np = np.asarray(slot_token), np.asarray(token_slot)
    for b in range(2):
        # every filled slot points at a choice routed to that expert
        for ei in range(e):
            for c in range(cap):
                f = st_np[b, ei, c]
                if f >= 0:
                    s_idx, k_idx = divmod(f, k)
                    assert np.asarray(ids)[b, s_idx, k_idx] == ei
        # no slot is assigned twice
        filled = st_np[b][st_np[b] >= 0]
        assert len(set(filled.tolist())) == len(filled)
        # kept choices round-trip through their slot
        for s_idx in range(s):
            for k_idx in range(k):
                c = tt_np[b, s_idx, k_idx]
                if c >= 0:
                    ei = np.asarray(ids)[b, s_idx, k_idx]
                    assert st_np[b, ei, c] == s_idx * k + k_idx


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_moe_output_finite_and_gate_normalized(seed):
    cfg = MoEConfig(d_model=16, d_ff=32, n_experts=4, top_k=2, capacity_factor=2.0)
    params, _ = split(moe_init(cfg, jax.random.PRNGKey(seed)))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8, 16))
    y, aux = moe_apply(params, cfg, x, return_aux=True)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert 0.0 <= float(aux["dropped_fraction"]) <= 1.0
    assert float(aux["load_balance_loss"]) >= 0.99  # >= 1 at perfect balance


# ---------------------------------------------------------------------------
# artifact store: canonical keys collide iff the content is equal
# ---------------------------------------------------------------------------

from repro.evaluation.artifact_store import ArtifactStore, content_hash

# small component pools so hypothesis actually generates equal pairs: the
# property is an iff, and both directions need coverage
_NAMES = st.sampled_from(["latency_s", "peak_bytes", "roofline_terms",
                          "serving_sim"])
_SCOPES = st.sampled_from(["1x1", "2x1", "2x4"])
_BATCHES = st.sampled_from([1, 2, 8])
_SIGNATURES = st.sampled_from([
    "conv1d(kernel_size=3,out_channels=4)|linear(width=8)",
    "conv1d(kernel_size=5,out_channels=4)|linear(width=8)",
    "conv1d(kernel_size=3,out_channels=4)|linear(width=16)",
])
_SCHEDULES = st.sampled_from([None, "ssm_scan:chunk=64", "ssm_scan:chunk=128"])


@st.composite
def program_keys(draw):
    key = (draw(_NAMES), draw(_SCOPES), draw(_BATCHES), draw(_SIGNATURES))
    sched = draw(_SCHEDULES)
    if sched is not None:
        key = key + (("sched", sched),)
    return key


@settings(max_examples=60, deadline=None)
@given(k1=program_keys(), k2=program_keys())
def test_store_keys_equal_iff_content_equal(k1, k2):
    """Two program keys share a store entry iff every component —
    estimator name, mesh scope, batch, full architecture signature, and
    effective schedule signature — is equal.  A collision here is the
    wrong-executable-served class of bug; a spurious mismatch is a
    silent recompile."""
    c1, c2 = ArtifactStore.canonical(k1), ArtifactStore.canonical(k2)
    assert c1 is not None and c2 is not None
    assert (c1 == c2) == (k1 == k2)
    # blob addressing follows the same identity
    assert (content_hash(c1) == content_hash(c2)) == (k1 == k2)
    # and the canonical form is deterministic
    assert ArtifactStore.canonical(k1) == c1


@settings(max_examples=20, deadline=None)
@given(k=program_keys(), where=st.integers(0, 3))
def test_store_key_with_uncacheable_component_is_unstorable(k, where):
    """Any None component (an uncacheable candidate) makes the whole key
    unstorable — the store must refuse rather than hash a partial
    identity."""
    broken = tuple(None if i == where else v for i, v in enumerate(k))
    assert ArtifactStore.canonical(broken) is None


# ---------------------------------------------------------------------------
# optimizer: zero grads + no weight decay = fixed point
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), name=st.sampled_from(["adamw", "sgd"]))
def test_optimizer_zero_grad_fixed_point(seed, name):
    from repro.train.optimizer import Optimizer, OptimizerConfig

    opt = Optimizer(OptimizerConfig(name=name, learning_rate=0.1, weight_decay=0.0,
                                    grad_clip_norm=None))
    params = {"w": jax.random.normal(jax.random.PRNGKey(seed), (4, 4))}
    state = opt.init(params)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    new_params, _, _ = opt.update(zeros, state, params)
    np.testing.assert_allclose(np.asarray(new_params["w"]), np.asarray(params["w"]), atol=1e-7)
