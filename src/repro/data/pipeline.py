"""Deterministic synthetic data pipeline with host sharding + prefetch.

Pod-scale properties:
  * deterministic by (seed, step, host): any host can regenerate any
    shard — restarts and *elastic re-assignment* (a host taking over a
    failed peer's shard) need no data-state checkpoint beyond the step
    counter;
  * straggler-tolerant: batches are indexed by step, so a host that
    skips/repeats work cannot desynchronize the global batch contents;
  * double-buffered prefetch thread overlaps host data generation with
    device compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


class SyntheticLMData:
    """Zipf-ish token stream with a fixed structure so loss decreases
    measurably when models train (markov-flavored transitions)."""

    def __init__(self, vocab: int, seq: int, global_batch: int,
                 seed: int = 0, n_hosts: int = 1, host_id: int = 0):
        assert global_batch % n_hosts == 0
        self.vocab = vocab
        self.seq = seq
        self.host_batch = global_batch // n_hosts
        self.seed = seed
        self.host_id = host_id

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, self.host_id, step))
        b, s, v = self.host_batch, self.seq, self.vocab
        # second-order structure: x[t+1] = (a*x[t] + noise) % v
        base = rng.integers(0, v, (b, 1))
        mult = rng.integers(2, 8, (b, 1))
        noise = rng.integers(0, max(2, v // 64), (b, s))
        tokens = np.zeros((b, s), np.int64)
        tokens[:, 0:1] = base
        for t in range(1, s):
            tokens[:, t] = (tokens[:, t - 1] * mult[:, 0] + noise[:, t]) % v
        labels = np.roll(tokens, -1, axis=1)
        return {"tokens": tokens.astype(np.int32), "labels": labels.astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Double-buffered background prefetch over a step-indexed source."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.depth = depth
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            self._q.put((step, batch))
            step += 1

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        while not self._q.empty():
            try:
                self._q.get_nowait()
            except queue.Empty:
                break


class SyntheticClassificationData:
    """(B, L, C) sensor-like streams for the NAS example spaces."""

    def __init__(self, n: int, length: int, channels: int, classes: int, seed: int = 0):
        rng = np.random.default_rng(seed)
        t = np.linspace(0, 1, length)[None, :, None]
        self.y = rng.integers(0, classes, n)
        # Two class signals so the label survives any searched pre-processing:
        # amplitude (destroyed by per-sample normalization) AND a disjoint
        # frequency band per class (normalization-invariant).
        band = 28.0 / classes
        lo = 2.0 + self.y[:, None, None] * band
        freqs = lo + rng.uniform(0, 1, (n, 1, channels)) * band
        phase = rng.uniform(0, 2 * np.pi, (n, 1, channels))
        amp = 1.0 + self.y[:, None, None] * 0.35
        self.x = (amp * np.sin(2 * np.pi * freqs * t + phase)
                  + 0.3 * rng.standard_normal((n, length, channels))).astype(np.float32)
        self.y = self.y.astype(np.int32)

    def split(self, frac: float = 0.8):
        k = int(len(self.y) * frac)
        return {
            "x_train": self.x[:k], "y_train": self.y[:k],
            "x_val": self.x[k:], "y_val": self.y[k:],
        }
