"""``python -m repro.worker`` — the remote evaluation worker daemon.

Thin entry-point shim; the implementation lives in
:mod:`repro.search.remote.worker`.  Typical launch::

    python -m repro.worker --host 0.0.0.0 --port 7471 \
        --cache-dir /shared/repro-cache

Then point an experiment at it with ``executor: {backend: remote,
workers: [host:7471, ...]}`` (or ``REPRO_REMOTE_WORKERS``).  Daemons
execute arbitrary pickled code from connected clients — only expose
them on trusted networks.
"""
from __future__ import annotations

import sys

from repro.search.remote.worker import main

if __name__ == "__main__":
    sys.exit(main())
