"""repro: hardware-aware NAS on a JAX/Pallas substrate.

The package initializer re-exports the unified Explorer facade — the
stable front API — lazily (PEP 562), so ``import repro.kernels`` and
friends don't pay for (or cycle through) the search/evaluation stack.

    from repro import Explorer

    report = Explorer.from_yaml("examples/experiments/quickstart.yaml").run()

The layered API (``repro.core``, ``repro.search``, ``repro.evaluation``,
``repro.hwgen``, ...) remains the extension surface; the facade only
composes it.
"""
from __future__ import annotations

import importlib

_EXPORTS = {
    "Explorer": "repro.explorer.explorer",
    "ExplorationReport": "repro.explorer.explorer",
    "ExperimentSpec": "repro.explorer.experiment",
    "ExperimentError": "repro.explorer.experiment",
    "ExplorerError": "repro.explorer.registry",
    "UnknownComponentError": "repro.explorer.registry",
    "register_component": "repro.explorer.registry",
    "SweepSpec": "repro.explorer.sweep",
    "SweepReport": "repro.explorer.sweep",
    "SweepError": "repro.explorer.sweep",
    "run_sweep": "repro.explorer.sweep",
}

__all__ = sorted(_EXPORTS)

# public alias: `register` is too generic a name at the top level
_ALIASES = {"register_component": "register"}


def __getattr__(name: str):
    try:
        module = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module 'repro' has no attribute {name!r}") from None
    return getattr(importlib.import_module(module), _ALIASES.get(name, name))


def __dir__():
    return __all__
