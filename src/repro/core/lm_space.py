"""LM-backbone search spaces: the paper's DSL driving the pod-scale
substrate (DESIGN.md §Arch-applicability).

The same YAML format describes spaces over *LM layers* instead of conv
stacks; the LMSpaceBuilder maps the sampled ArchitectureIR onto the
ModelSpec IR executed by `repro.models.lm.LM` — so hardware-in-the-loop
NAS (XLA generator + roofline feedback) runs over the assigned
architecture families.  Each assigned arch family has a DSL space whose
identity sample reproduces it (see `repro/configs/spaces/`).

LM ops (usable as op_candidates):
  transformer_layer: heads, kv_heads, d_ff, activation, gated, qk_norm
  moe_layer:         heads, kv_heads, d_ff, n_experts, top_k, dense_residual
  mamba2_layer:      d_state, d_head, expand
  mlstm_layer:       heads, expand
  slstm_layer:       heads
"""
from __future__ import annotations

from typing import Any, Dict

from repro.core.translate import ArchitectureIR
from repro.models.specs import LayerSpec, ModelSpec, SubBlock, moe_layer, transformer_layer
from repro.nn.ssm import Mamba2Config
from repro.nn.xlstm import MLSTMConfig, SLSTMConfig

LM_OPS = ("transformer_layer", "moe_layer", "mamba2_layer", "mlstm_layer", "slstm_layer")


def _fit_heads(heads: int, d_model: int) -> int:
    """Adapt a sampled head count to the actual width (the LM analogue of
    the ModelBuilder's shape-compatibility logic): heads must divide
    d_model and leave an even head_dim (RoPE splits it in two)."""
    heads = max(1, min(int(heads), d_model // 2))
    while heads > 1 and (d_model % heads or (d_model // heads) % 2):
        heads -= 1
    return heads


def _fit_kv(kv: int, heads: int) -> int:
    kv = max(1, min(int(kv), heads))
    while heads % kv:
        kv -= 1
    return kv


def _layer_from_ir(op: str, p: Dict[str, Any], d_model: int) -> LayerSpec:
    if op == "transformer_layer":
        heads = _fit_heads(p.get("heads", d_model // 128), d_model)
        return transformer_layer(
            d_model,
            heads,
            _fit_kv(p.get("kv_heads", max(heads // 2, 1)), heads),
            int(p.get("d_ff", 4 * d_model)),
            activation=str(p.get("activation", "silu")),
            gated=bool(p.get("gated", True)),
            qk_norm=bool(p.get("qk_norm", False)),
            window=p.get("window"),
        )
    if op == "moe_layer":
        heads = _fit_heads(p.get("heads", d_model // 128), d_model)
        return moe_layer(
            d_model,
            heads,
            _fit_kv(p.get("kv_heads", max(heads // 2, 1)), heads),
            int(p.get("d_ff", 2 * d_model)),
            n_experts=int(p.get("n_experts", 8)),
            top_k=int(p.get("top_k", 2)),
            dense_residual=bool(p.get("dense_residual", False)),
        )
    if op == "mamba2_layer":
        return LayerSpec(subs=(SubBlock("mamba2", Mamba2Config(
            d_model,
            d_state=int(p.get("d_state", 64)),
            d_head=int(p.get("d_head", 64)),
            expand=int(p.get("expand", 2)),
        )),))
    if op == "mlstm_layer":
        return LayerSpec(subs=(SubBlock("mlstm", MLSTMConfig(
            d_model, n_heads=int(p.get("heads", 4)), expand=int(p.get("expand", 2)),
        )),))
    if op == "slstm_layer":
        return LayerSpec(subs=(SubBlock("slstm", SLSTMConfig(
            d_model, n_heads=int(p.get("heads", 4)),
        )),))
    raise KeyError(f"not an LM op: {op!r}")


class LMSpaceBuilder:
    """ArchitectureIR -> ModelSpec (the LM analogue of ModelBuilder)."""

    def __init__(self, d_model: int, vocab: int, *, tie_embeddings: bool = True,
                 norm: str = "rmsnorm"):
        self.d_model = d_model
        self.vocab = vocab
        self.tie_embeddings = tie_embeddings
        self.norm = norm

    def build(self, arch: ArchitectureIR) -> ModelSpec:
        layers = tuple(
            _layer_from_ir(l.op, l.params, self.d_model) for l in arch.layers
        )
        attention_free = all(
            all(s.kind not in ("attention", "cross_attention") for s in layer.subs)
            for layer in layers
        )
        return ModelSpec(
            name=f"lm-nas-{arch.signature()[:40]}",
            d_model=self.d_model,
            vocab=self.vocab,
            layers=layers,
            norm=self.norm,
            tie_embeddings=self.tie_embeddings,
            positional="none" if attention_free else "rope",
        )
