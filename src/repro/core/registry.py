"""Operation & adapter registries (paper §IV-D, Listing 4).

New operations implement :class:`LayerBuilder` and self-register with
``@register_layer("op_name")``; the op is then usable in the YAML DSL
under that name with zero engine changes.  Adapters between structurally
incompatible data formats live in the *transition registry*, keyed by
(from_format, to_format) — the ModelBuilder consults it automatically
when two consecutive layers disagree (paper §IV-C).

Data formats:
  ``BLC`` — (batch, length, channels) sequence features
  ``BF``  — (batch, features) flat features
"""
from __future__ import annotations

import abc
import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn import conv as conv_mod
from repro.nn import initializers as init
from repro.nn.types import P

Shape = Tuple[int, ...]  # without the batch dim


@dataclasses.dataclass
class BuiltLayer:
    """An instantiated operation: pure init/apply + static metadata."""

    name: str
    init: Callable[[Any], Any]  # key -> params (P-tree)
    apply: Callable[[Any, Any], Any]  # (params, x) -> y
    out_shape: Shape
    out_format: str
    flops: int = 0  # fwd FLOPs per example (analytical estimate)
    n_params: int = 0
    # decode-state footprint per sequence, in *elements* (the serving
    # estimators scale by the declared cache dtype): grows-with-context
    # state (attention K/V) vs fixed-size state (SSM recurrent state)
    state_elems_per_token: int = 0
    state_elems_fixed: int = 0


class LayerBuilder(abc.ABC):
    """Implement & register to add an op (paper Listing 4).

    ``build`` receives the sampled parameter dict for this op, the input
    shape (batchless) and format, whether this is the network's last
    layer, and the target output dim (used by heads when ``is_last``).
    """

    op_name: str = ""
    in_format: str = "any"  # "BLC" | "BF" | "any"

    @abc.abstractmethod
    def build(
        self,
        params: Dict[str, Any],
        in_shape: Shape,
        in_format: str,
        *,
        is_last: bool,
        output_dim: Optional[int],
    ) -> BuiltLayer:
        ...


LAYER_REGISTRY: Dict[str, LayerBuilder] = {}
TRANSITION_REGISTRY: Dict[Tuple[str, str], Callable[[Shape], BuiltLayer]] = {}


def register_layer(name: str):
    """Class decorator: ``@register_layer("linear")`` (paper Listing 4)."""

    def wrap(cls):
        inst = cls()
        inst.op_name = name
        LAYER_REGISTRY[name] = inst
        return cls

    return wrap


def register_transition(from_format: str, to_format: str):
    def wrap(fn):
        TRANSITION_REGISTRY[(from_format, to_format)] = fn
        return fn

    return wrap


def get_layer_builder(name: str) -> LayerBuilder:
    if name not in LAYER_REGISTRY:
        raise KeyError(f"op {name!r} not registered; known: {sorted(LAYER_REGISTRY)}")
    return LAYER_REGISTRY[name]


def get_transition(from_format: str, to_format: str) -> Callable[[Shape], BuiltLayer]:
    key = (from_format, to_format)
    if key not in TRANSITION_REGISTRY:
        raise KeyError(f"no adapter registered for transition {key}")
    return TRANSITION_REGISTRY[key]


# ---------------------------------------------------------------------------
# built-in adapters
# ---------------------------------------------------------------------------

@register_transition("BLC", "BF")
def _flatten_adapter(in_shape: Shape) -> BuiltLayer:
    l, c = in_shape
    return BuiltLayer(
        name="adapter/flatten",
        init=lambda key: {},
        apply=lambda p, x: x.reshape(x.shape[0], -1),
        out_shape=(l * c,),
        out_format="BF",
    )


@register_transition("BF", "BLC")
def _unsqueeze_adapter(in_shape: Shape) -> BuiltLayer:
    (f,) = in_shape
    return BuiltLayer(
        name="adapter/unsqueeze",
        init=lambda key: {},
        apply=lambda p, x: x[:, None, :],
        out_shape=(1, f),
        out_format="BLC",
    )


# ---------------------------------------------------------------------------
# built-in operations
# ---------------------------------------------------------------------------

@register_layer("linear")
class LinearBuilder(LayerBuilder):
    in_format = "BF"

    def build(self, params, in_shape, in_format, *, is_last, output_dim):
        (fan_in,) = in_shape
        if is_last and "width" not in params:
            # bare head: project straight to the task's output dim
            width, act = int(output_dim), None
        else:
            width = int(params.get("width", 64))
            act = params.get("activation", "relu")

        def init_fn(key):
            kw, _ = jax.random.split(key)
            p = {
                "w": P(init.scaled_normal(kw, (fan_in, width)), ("embed", "mlp")),
                "b": P(jnp.zeros((width,)), ("mlp",)),
            }
            return p

        def apply_fn(p, x):
            y = x @ p["w"] + p["b"]
            if act == "relu":
                y = jax.nn.relu(y)
            elif act == "gelu":
                y = jax.nn.gelu(y)
            return y

        return BuiltLayer(
            name=f"linear({width})",
            init=init_fn,
            apply=apply_fn,
            out_shape=(width,),
            out_format="BF",
            flops=2 * fan_in * width,
            n_params=fan_in * width + width,
        )


@register_layer("conv1d")
class Conv1dBuilder(LayerBuilder):
    in_format = "BLC"

    def build(self, params, in_shape, in_format, *, is_last, output_dim):
        l, c_in = in_shape
        k = int(params.get("kernel_size", 3))
        c_out = int(params.get("out_channels", 16))
        stride = int(params.get("stride", 1))
        act = params.get("activation", "relu")
        out_l = conv_mod.conv1d_out_len(l, k, stride, "SAME")

        def init_fn(key):
            return conv_mod.conv1d_init(key, c_in, c_out, k)

        def apply_fn(p, x):
            y = conv_mod.conv1d_apply(p, x, stride=stride, padding="SAME")
            if act == "relu":
                y = jax.nn.relu(y)
            elif act == "gelu":
                y = jax.nn.gelu(y)
            return y

        return BuiltLayer(
            name=f"conv1d(k={k},c={c_out},s={stride})",
            init=init_fn,
            apply=apply_fn,
            out_shape=(out_l, c_out),
            out_format="BLC",
            flops=2 * out_l * k * c_in * c_out,
            n_params=k * c_in * c_out + c_out,
        )


class _PoolBuilder(LayerBuilder):
    in_format = "BLC"
    pool_fn = staticmethod(conv_mod.maxpool1d)
    tag = "maxpool"

    def build(self, params, in_shape, in_format, *, is_last, output_dim):
        l, c = in_shape
        w = int(params.get("window", 2))
        w = min(w, l)
        out_l = conv_mod.pool_out_len(l, w)
        fn = self.pool_fn
        return BuiltLayer(
            name=f"{self.tag}({w})",
            init=lambda key: {},
            apply=lambda p, x: fn(x, window=w),
            out_shape=(out_l, c),
            out_format="BLC",
            flops=out_l * w * c,
        )


@register_layer("maxpool")
class MaxPoolBuilder(_PoolBuilder):
    pool_fn = staticmethod(conv_mod.maxpool1d)
    tag = "maxpool"


@register_layer("avgpool")
class AvgPoolBuilder(_PoolBuilder):
    pool_fn = staticmethod(conv_mod.avgpool1d)
    tag = "avgpool"


@register_layer("identity")
class IdentityBuilder(LayerBuilder):
    in_format = "any"

    def build(self, params, in_shape, in_format, *, is_last, output_dim):
        return BuiltLayer(
            name="identity",
            init=lambda key: {},
            apply=lambda p, x: x,
            out_shape=in_shape,
            out_format=in_format,
        )


@register_layer("global_avg_pool")
class GlobalAvgPoolBuilder(LayerBuilder):
    in_format = "BLC"

    def build(self, params, in_shape, in_format, *, is_last, output_dim):
        l, c = in_shape
        return BuiltLayer(
            name="global_avg_pool",
            init=lambda key: {},
            apply=lambda p, x: jnp.mean(x, axis=1),
            out_shape=(c,),
            out_format="BF",
            flops=l * c,
        )


@register_layer("layernorm")
class LayerNormBuilder(LayerBuilder):
    in_format = "any"

    def build(self, params, in_shape, in_format, *, is_last, output_dim):
        d = in_shape[-1]

        def init_fn(key):
            return {
                "scale": P(jnp.ones((d,)), ("embed",)),
                "bias": P(jnp.zeros((d,)), ("embed",)),
            }

        def apply_fn(p, x):
            mu = jnp.mean(x, axis=-1, keepdims=True)
            var = jnp.var(x, axis=-1, keepdims=True)
            return (x - mu) * (var + 1e-5) ** -0.5 * p["scale"] + p["bias"]

        return BuiltLayer(
            name="layernorm",
            init=init_fn,
            apply=apply_fn,
            out_shape=in_shape,
            out_format=in_format,
            flops=6 * math.prod(in_shape),
            n_params=2 * d,
        )


@register_layer("attention")
class AttentionBuilder(LayerBuilder):
    """Self-attention over a BLC sequence (residual, pre-norm)."""

    in_format = "BLC"

    def build(self, params, in_shape, in_format, *, is_last, output_dim):
        from repro.nn.attention import AttentionConfig, attention_apply, attention_init

        l, c = in_shape
        heads = int(params.get("heads", 4))
        heads = max(1, min(heads, c))
        while c % heads:
            heads -= 1
        # impl "pallas" routes through kernels/ops.flash_attention, where
        # an active kernel schedule (tuned or searched) controls blocking
        cfg = AttentionConfig(d_model=c, n_heads=heads, n_kv_heads=heads,
                              causal=bool(params.get("causal", False)),
                              impl=str(params.get("impl", "xla")))

        def apply_fn(p, x):
            return x + attention_apply(p, cfg, x)

        return BuiltLayer(
            name=f"attention(h={heads})",
            init=lambda key: attention_init(cfg, key),
            apply=apply_fn,
            out_shape=in_shape,
            out_format="BLC",
            flops=2 * l * (4 * c * c) + 4 * l * l * c,
            n_params=4 * c * c,
            state_elems_per_token=2 * c,  # K + V rows per cached token
        )


@register_layer("ssm")
class SSMBuilder(LayerBuilder):
    """Mamba2 SSD block over a BLC sequence (residual).

    impl "pallas" routes through kernels/ops.ssm_scan, making the block's
    chunk size a schedulable (autotunable) kernel parameter.
    """

    in_format = "BLC"

    def build(self, params, in_shape, in_format, *, is_last, output_dim):
        from repro.nn.ssm import Mamba2Config, mamba2_apply, mamba2_init

        l, c = in_shape
        expand = int(params.get("expand", 2))
        d_inner = expand * c
        d_head = min(int(params.get("d_head", 64)), d_inner)
        while d_inner % d_head:
            d_head //= 2
        cfg = Mamba2Config(
            d_model=c,
            d_state=int(params.get("d_state", 16)),
            d_head=max(1, d_head),
            expand=expand,
            impl=str(params.get("impl", "xla")),
        )

        def apply_fn(p, x):
            return x + mamba2_apply(p, cfg, x)

        # dominant terms: in/out projections + the SSD scan's state update
        n_params = c * (2 * cfg.d_inner + 2 * cfg.d_state + cfg.n_heads) \
            + cfg.d_inner * c
        return BuiltLayer(
            name=f"ssm(n={cfg.d_state},e={expand})",
            init=lambda key: mamba2_init(cfg, key),
            apply=apply_fn,
            out_shape=in_shape,
            out_format="BLC",
            flops=2 * l * n_params + 6 * l * cfg.d_inner * cfg.d_state,
            n_params=n_params,
            # recurrent state is context-length independent: SSD state
            # (heads, d_state, d_head) + rolling conv window
            state_elems_fixed=(
                cfg.n_heads * cfg.d_state * cfg.d_head
                + (cfg.conv_width - 1) * (cfg.d_inner + 2 * cfg.n_groups * cfg.d_state)
            ),
        )
