"""YAML search-space DSL -> SearchSpaceDef IR (paper §IV, Listings 1-3).

Top-level syntax::

    input: <SHAPE>            # e.g. [4, 1250]  (channels, length)
    output: <INT>
    sequence:
      - block: <UNIQUE_BLOCK_NAME>
        op_candidates: <OP_NAME> | [<OP_NAME>, ...]
        type_repeat:                      # optional
          type: repeat_op | repeat_params | vary_all | repeat_block
          depth: <INT | [INT, ...]>       # optional
          ref_block: <BLOCK_NAME>         # repeat_block only
        <OP_NAME>:
          <PARAM>: <VALUE | [CHOICES] | {low:, high:, step:, log:}>
    default_op_params:                    # global fallback (paper §IV-A)
      <OP_NAME>: {<PARAM>: <VALUE|CHOICES|RANGE>}
    composites:                           # reusable sub-search-spaces (§IV-B)
      <NAME>:
        sequence: [ ...blocks... ]
    preprocessing:                        # joint pre-processing space (§IV-E)
      <STAGE>: {<PARAM>: <VALUE|CHOICES|RANGE>}

Repeat semantics follow paper Table I:
  repeat_op     — one op for the whole block, params resampled per layer
  repeat_params — op AND params sampled once, reused for every layer
  vary_all      — op and params sampled independently per layer
  repeat_block  — repeat the *sampled* configuration of ``ref_block``
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import yaml

REPEAT_MODES = ("repeat_op", "repeat_params", "vary_all", "repeat_block")


class SpaceError(ValueError):
    pass


@dataclasses.dataclass
class RepeatSpec:
    mode: str
    depth: Optional[Union[int, List[int]]] = None
    ref_block: Optional[str] = None

    def __post_init__(self):
        if self.mode not in REPEAT_MODES:
            raise SpaceError(f"unknown repeat mode {self.mode!r}; expected one of {REPEAT_MODES}")
        if self.mode == "repeat_block" and not self.ref_block:
            raise SpaceError("repeat_block requires ref_block")
        if self.mode == "repeat_op" and self.depth is None:
            raise SpaceError("repeat_op requires depth")


@dataclasses.dataclass
class BlockDef:
    name: str
    op_candidates: List[str]
    repeat: Optional[RepeatSpec] = None
    local_params: Dict[str, Dict[str, Any]] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class SearchSpaceDef:
    input_shape: Tuple[int, ...]
    output_dim: int
    blocks: List[BlockDef]
    default_op_params: Dict[str, Dict[str, Any]] = dataclasses.field(default_factory=dict)
    composites: Dict[str, List[BlockDef]] = dataclasses.field(default_factory=dict)
    preprocessing: Dict[str, Dict[str, Any]] = dataclasses.field(default_factory=dict)

    def op_params(self, block: BlockDef, op: str) -> Dict[str, Any]:
        """Local params override the global default_op_params fallback."""
        merged = dict(self.default_op_params.get(op, {}))
        merged.update(block.local_params.get(op, {}))
        return merged


RESERVED_KEYS = {"block", "op_candidates", "type_repeat"}


def _parse_block(raw: Dict[str, Any]) -> BlockDef:
    if "block" not in raw:
        raise SpaceError(f"sequence entry missing 'block': {raw}")
    name = str(raw["block"])
    cands = raw.get("op_candidates")
    if cands is None:
        raise SpaceError(f"block {name!r} missing op_candidates")
    if isinstance(cands, str):
        cands = [cands]
    repeat = None
    if "type_repeat" in raw:
        tr = raw["type_repeat"]
        repeat = RepeatSpec(
            mode=str(tr.get("type")),
            depth=tr.get("depth"),
            ref_block=tr.get("ref_block") or tr.get("reference_block"),
        )
    local = {k: dict(v) for k, v in raw.items() if k not in RESERVED_KEYS and isinstance(v, dict)}
    return BlockDef(name=name, op_candidates=[str(c) for c in cands], repeat=repeat, local_params=local)


def parse_search_space(source: Union[str, Dict[str, Any]]) -> SearchSpaceDef:
    """Parse a YAML string (or pre-loaded dict) into a SearchSpaceDef."""
    raw = yaml.safe_load(source) if isinstance(source, str) else source
    if not isinstance(raw, dict):
        raise SpaceError("search space must be a mapping")
    if "sequence" not in raw:
        raise SpaceError("search space missing top-level 'sequence'")
    inp = raw.get("input")
    input_shape = tuple(inp) if isinstance(inp, (list, tuple)) else ((int(inp),) if inp is not None else ())
    output_dim = int(raw.get("output", 0))
    blocks = [_parse_block(b) for b in raw["sequence"]]
    names = [b.name for b in blocks]
    if len(set(names)) != len(names):
        raise SpaceError(f"duplicate block names: {names}")
    composites = {}
    for cname, cdef in (raw.get("composites") or {}).items():
        if "sequence" not in cdef:
            raise SpaceError(f"composite {cname!r} missing 'sequence'")
        composites[str(cname)] = [_parse_block(b) for b in cdef["sequence"]]
    space = SearchSpaceDef(
        input_shape=input_shape,
        output_dim=output_dim,
        blocks=blocks,
        default_op_params={str(k): dict(v) for k, v in (raw.get("default_op_params") or {}).items()},
        composites=composites,
        preprocessing={str(k): dict(v) for k, v in (raw.get("preprocessing") or {}).items()},
    )
    _validate(space)
    return space


def parse_search_space_file(path: str) -> SearchSpaceDef:
    with open(path) as f:
        return parse_search_space(f.read())


def _validate(space: SearchSpaceDef) -> None:
    block_names = {b.name for b in space.blocks}
    for blocks in [space.blocks] + list(space.composites.values()):
        for b in blocks:
            if b.repeat and b.repeat.mode == "repeat_block":
                if b.repeat.ref_block not in block_names:
                    raise SpaceError(
                        f"block {b.name!r}: ref_block {b.repeat.ref_block!r} is not a defined block"
                    )
    # composite recursion guard
    def expand(name: str, stack: Tuple[str, ...]):
        if name in stack:
            raise SpaceError(f"composite cycle: {' -> '.join(stack + (name,))}")
        for b in space.composites.get(name, []):
            for cand in b.op_candidates:
                if cand in space.composites:
                    expand(cand, stack + (name,))

    for cname in space.composites:
        expand(cname, ())
