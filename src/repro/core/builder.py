"""ModelBuilder: ArchitectureIR -> executable JAX model (paper §IV-C).

Modules are instantiated only after the sampler has fixed all values.
The builder walks the layer IR, asks each registered LayerBuilder for an
instantiated ``BuiltLayer`` (which includes shape inference), and inserts
adapter modules from the transition registry wherever consecutive layers
disagree on data format — so heterogeneous (conv / attention / linear)
architectures compose without per-architecture glue code.

The result is a :class:`BuiltModel` with pure ``init``/``apply`` functions
(jit-able, shardable — the same functional convention as the LM substrate)
plus analytical cost metadata used by the evaluation API.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.preprocess import build_preprocessing
from repro.core.registry import BuiltLayer, get_layer_builder, get_transition
from repro.core.translate import ArchitectureIR


class BuildError(ValueError):
    pass


@dataclasses.dataclass
class BuiltModel:
    layers: List[BuiltLayer]
    input_shape: Tuple[int, ...]
    output_dim: int
    arch: ArchitectureIR
    preprocess: Optional[Callable[[Any], Any]] = None

    # -- functional interface -------------------------------------------------

    def init_annotated(self, key):
        """Params with logical-axis annotations (P-tree) for sharding."""
        keys = jax.random.split(key, max(1, len(self.layers)))
        return {f"layer_{i}": l.init(k) for i, (l, k) in enumerate(zip(self.layers, keys))}

    def init(self, key):
        from repro.nn.types import split

        values, _ = split(self.init_annotated(key))
        return values

    def apply(self, params, x):
        if self.preprocess is not None:
            x = self.preprocess(x)
        for i, layer in enumerate(self.layers):
            x = layer.apply(params[f"layer_{i}"], x)
        return x

    # -- analytical costs ------------------------------------------------------

    @property
    def flops(self) -> int:
        return sum(l.flops for l in self.layers)

    @property
    def n_params(self) -> int:
        return sum(l.n_params for l in self.layers)

    @property
    def state_elems_per_token(self) -> int:
        """Decode-state elements that grow with context (K/V caches)."""
        return sum(l.state_elems_per_token for l in self.layers)

    @property
    def state_elems_fixed(self) -> int:
        """Context-length-independent decode-state elements (SSM state)."""
        return sum(l.state_elems_fixed for l in self.layers)

    def summary(self) -> str:
        rows = [f"input  {self.input_shape}"]
        for l in self.layers:
            rows.append(f"{l.name:<28} -> {l.out_shape} [{l.out_format}] "
                        f"flops={l.flops:,} params={l.n_params:,}")
        return "\n".join(rows)


class ModelBuilder:
    """Builds executable models from sampled architecture IR."""

    def __init__(self, input_shape: Tuple[int, ...], output_dim: int,
                 input_format: str = "BLC", ensure_head: bool = True):
        self.input_shape = tuple(int(s) for s in input_shape)
        self.output_dim = int(output_dim)
        self.input_format = input_format
        self.ensure_head = ensure_head

    def build(self, arch: ArchitectureIR) -> BuiltModel:
        # paper: (length, channels) YAML order is [channels, length]
        if self.input_format == "BLC" and len(self.input_shape) == 2:
            c, l = self.input_shape
            shape: Tuple[int, ...] = (l, c)
        else:
            shape = self.input_shape
        fmt = self.input_format
        layers: List[BuiltLayer] = []

        pre_fn, pre_out_shape = build_preprocessing(arch.preprocessing, shape)
        shape = pre_out_shape

        n = len(arch.layers)
        for i, layer_ir in enumerate(arch.layers):
            builder = get_layer_builder(layer_ir.op)
            is_last = self.ensure_head and (i == n - 1)
            # adapter insertion when formats disagree
            if builder.in_format not in ("any", fmt):
                adapter = get_transition(fmt, builder.in_format)(shape)
                layers.append(adapter)
                shape, fmt = adapter.out_shape, adapter.out_format
            built = builder.build(
                dict(layer_ir.params), shape, fmt,
                is_last=is_last, output_dim=self.output_dim,
            )
            layers.append(built)
            shape, fmt = built.out_shape, built.out_format

        if self.ensure_head and (fmt != "BF" or shape != (self.output_dim,)):
            # guarantee a classifier head of the requested output dim
            if fmt != "BF":
                adapter = get_transition(fmt, "BF")(shape)
                layers.append(adapter)
                shape, fmt = adapter.out_shape, adapter.out_format
            if shape != (self.output_dim,):
                head = get_layer_builder("linear").build(
                    {}, shape, fmt, is_last=True, output_dim=self.output_dim
                )
                layers.append(head)
                shape = head.out_shape

        return BuiltModel(
            layers=layers,
            input_shape=self.input_shape,
            output_dim=self.output_dim,
            arch=arch,
            preprocess=pre_fn,
        )
