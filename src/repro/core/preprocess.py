"""Pre-processing design space (paper §IV-E).

Five searchable stages over (B, L, C) sensor streams, jointly sampled with
the architecture so the whole signal path is optimized end-to-end:

  * ``filter``      — windowed-sinc low/high-pass FIR (cutoff, taps, kind)
  * ``downsample``  — integer-factor decimation
  * ``window``      — sequential windowing: fixed-offset crop of length W
  * ``event_window``— event-based windowing: crop centred on the maximum
                      short-time energy (the "event")
  * ``normalize``   — zscore | minmax | none

The deployed stream system applies windowing continuously; during NAS each
example contributes one window (documented simplification).  All stages
are pure jnp -> they compile into the same XLA program as the model, so
hardware-in-the-loop latency measurements include the pre-processing cost
— the paper's end-to-end claim.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

Shape = Tuple[int, ...]


def _sinc_kernel(taps: int, cutoff: float, kind: str) -> jnp.ndarray:
    """Windowed-sinc FIR kernel.  cutoff in (0, 0.5) of sampling rate."""
    m = taps - 1
    n = jnp.arange(taps) - m / 2.0
    h = 2 * cutoff * jnp.sinc(2 * cutoff * n)
    # Hamming window
    w = 0.54 - 0.46 * jnp.cos(2 * math.pi * jnp.arange(taps) / m)
    h = h * w
    h = h / jnp.sum(h)
    if kind == "highpass":
        delta = jnp.zeros(taps).at[m // 2].set(1.0)
        h = delta - h
    return h


def _apply_fir(x, kernel):
    """Depthwise 'SAME' FIR along L.  x: (B, L, C)."""
    k = kernel[:, None, None] * jnp.eye(x.shape[-1])[None]
    return jax.lax.conv_general_dilated(
        x, k, window_strides=(1,), padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"),
    )


def build_stage(cfg: Dict[str, Any], shape: Shape) -> Tuple[Callable, Shape]:
    l, c = shape
    stage = cfg["stage"]
    if stage == "filter":
        taps = int(cfg.get("taps", 31))
        cutoff = float(cfg.get("cutoff", 0.25))
        kind = str(cfg.get("kind", "lowpass"))
        kernel = _sinc_kernel(taps, cutoff, kind)
        return (lambda x: _apply_fir(x, kernel)), (l, c)
    if stage == "downsample":
        factor = max(1, int(cfg.get("factor", 1)))
        out_l = (l + factor - 1) // factor
        return (lambda x: x[:, ::factor]), (out_l, c)
    if stage == "window":
        w = min(int(cfg.get("size", l)), l)
        off = min(int(cfg.get("offset", 0)), l - w)
        return (lambda x: x[:, off : off + w]), (w, c)
    if stage == "event_window":
        w = min(int(cfg.get("size", l)), l)
        energy_w = min(int(cfg.get("energy_window", 16)), l)

        def fn(x):
            energy = jax.lax.reduce_window(
                jnp.sum(x.astype(jnp.float32) ** 2, axis=-1),
                0.0, jax.lax.add, (1, energy_w), (1, 1), "VALID",
            )
            centre = jnp.argmax(energy, axis=1) + energy_w // 2
            start = jnp.clip(centre - w // 2, 0, x.shape[1] - w)

            def crop(xi, s):
                return jax.lax.dynamic_slice_in_dim(xi, s, w, axis=0)

            return jax.vmap(crop)(x, start)

        return fn, (w, c)
    if stage == "normalize":
        kind = str(cfg.get("kind", "zscore"))
        if kind == "minmax":
            def fn(x):
                lo = jnp.min(x, axis=1, keepdims=True)
                hi = jnp.max(x, axis=1, keepdims=True)
                return (x - lo) / jnp.maximum(hi - lo, 1e-6)
        elif kind == "zscore":
            def fn(x):
                mu = jnp.mean(x, axis=1, keepdims=True)
                sd = jnp.std(x, axis=1, keepdims=True)
                return (x - mu) / jnp.maximum(sd, 1e-6)
        else:
            fn = lambda x: x
        return fn, (l, c)
    raise ValueError(f"unknown pre-processing stage {stage!r}")


def build_preprocessing(stages: List[Dict[str, Any]], shape: Shape):
    """Compose sampled stages -> (callable | None, out_shape)."""
    if not stages:
        return None, shape
    fns = []
    for cfg in stages:
        fn, shape = build_stage(cfg, shape)
        fns.append(fn)

    def pipeline(x):
        for f in fns:
            x = f(x)
        return x

    return pipeline, shape
