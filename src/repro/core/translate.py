"""Search-space translation: SearchSpaceDef + Trial -> ArchitectureIR.

This is the paper's "search space translator": the declarative space is
walked during each trial; every decision point becomes a named suggestion
(`<block>.<layer>.<op>.<param>`), which makes the space Optuna-compatible
(conditional decisions only materialize when their parent choice selects
them) and keeps trial records reproducible.

Models are *not* instantiated here — the output is an intermediate
architectural representation (a flat list of LayerIR with expanded
composites), consumed by the ModelBuilder (paper §IV-C).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from repro.core.space import BlockDef, RepeatSpec, SearchSpaceDef, SpaceError
from repro.search.trial import Trial


@dataclasses.dataclass
class LayerIR:
    op: str
    params: Dict[str, Any]
    path: str  # provenance: block path in the space (for debugging/repro)


@dataclasses.dataclass
class ArchitectureIR:
    layers: List[LayerIR]
    preprocessing: List[Dict[str, Any]] = dataclasses.field(default_factory=list)

    def signature(self) -> str:
        """Canonical identity of the *full* candidate: pre-processing AND
        layers.  The pre-processing stages compile into the same XLA
        program as the model, so two candidates with identical layers but
        different pre-processing are different programs — omitting the
        stages here caused cache collisions in compiled-cost estimators."""
        body = "|".join(
            f"{l.op}({','.join(f'{k}={v}' for k, v in sorted(l.params.items()))})"
            for l in self.layers
        )
        if not self.preprocessing:
            return body
        pre = "|".join(
            f"{s.get('stage')}({','.join(f'{k}={v}' for k, v in sorted(s.items()) if k != 'stage')})"
            for s in self.preprocessing
        )
        return f"{pre}>>{body}"


def _suggest_value(trial: Trial, name: str, spec: Any) -> Any:
    """Fixed scalar, [choices] list, or {low, high, step?, log?} range."""
    if isinstance(spec, dict) and "low" in spec and "high" in spec:
        if isinstance(spec["low"], float) or isinstance(spec["high"], float) or spec.get("float"):
            return trial.suggest_float(name, float(spec["low"]), float(spec["high"]), log=bool(spec.get("log")))
        return trial.suggest_int(name, int(spec["low"]), int(spec["high"]), step=int(spec.get("step", 1)), log=bool(spec.get("log")))
    if isinstance(spec, (list, tuple)):
        return trial.suggest_categorical(name, list(spec))
    return spec  # fixed value — not a search decision


def _sample_op_params(trial: Trial, space: SearchSpaceDef, block: BlockDef, op: str, prefix: str) -> Dict[str, Any]:
    out = {}
    for pname, pspec in space.op_params(block, op).items():
        out[pname] = _suggest_value(trial, f"{prefix}.{op}.{pname}", pspec)
    return out


def _sample_depth(trial: Trial, repeat: Optional[RepeatSpec], prefix: str) -> int:
    if repeat is None or repeat.depth is None:
        return 1
    if isinstance(repeat.depth, int):
        return repeat.depth
    return int(trial.suggest_categorical(f"{prefix}.depth", list(repeat.depth)))


class SpaceTranslator:
    """Walks a SearchSpaceDef with a Trial, expanding repeats/composites."""

    def __init__(self, space: SearchSpaceDef, allowed_ops: Optional[set] = None):
        self.space = space
        # backend reflection (paper §VI): mask op_candidates to what the
        # target generator supports
        self.allowed_ops = allowed_ops
        self._block_layers: Dict[str, List[LayerIR]] = {}

    def _candidates(self, block: BlockDef) -> List[str]:
        cands = block.op_candidates
        if self.allowed_ops is not None:
            masked = [c for c in cands if c in self.allowed_ops or c in self.space.composites]
            if not masked:
                raise SpaceError(
                    f"block {block.name!r}: no op candidate supported by backend "
                    f"(candidates={cands})"
                )
            cands = masked
        return cands

    def _expand_op(self, trial: Trial, block: BlockDef, op: str, prefix: str) -> List[LayerIR]:
        """One sampled op -> one LayerIR, or a composite's expansion."""
        if op in self.space.composites:
            layers: List[LayerIR] = []
            for sub in self.space.composites[op]:
                layers.extend(self._expand_block(trial, sub, f"{prefix}/{op}"))
            return layers
        params = _sample_op_params(trial, self.space, block, op, prefix)
        return [LayerIR(op=op, params=params, path=prefix)]

    def _expand_block(self, trial: Trial, block: BlockDef, path: str) -> List[LayerIR]:
        prefix = f"{path}/{block.name}" if path else block.name
        repeat = block.repeat
        mode = repeat.mode if repeat else None

        if mode == "repeat_block":
            ref = repeat.ref_block
            if ref not in self._block_layers:
                raise SpaceError(
                    f"block {block.name!r}: ref_block {ref!r} not expanded yet "
                    "(must appear earlier in the sequence)"
                )
            depth = _sample_depth(trial, repeat, prefix)
            layers = []
            for _ in range(depth):
                layers.extend(
                    LayerIR(op=l.op, params=dict(l.params), path=f"{prefix}<~{ref}")
                    for l in self._block_layers[ref]
                )
            self._block_layers[block.name] = layers
            return layers

        depth = _sample_depth(trial, repeat, prefix)
        cands = self._candidates(block)

        def choose_op(layer_prefix: str) -> str:
            if len(cands) == 1:
                return cands[0]
            return trial.suggest_categorical(f"{layer_prefix}.op", cands)

        layers = []
        if mode is None:
            op = choose_op(prefix)
            layers = self._expand_op(trial, block, op, prefix)
        elif mode == "vary_all":
            for i in range(depth):
                op = choose_op(f"{prefix}.{i}")
                layers.extend(self._expand_op(trial, block, op, f"{prefix}.{i}"))
        elif mode == "repeat_op":
            op = choose_op(prefix)
            for i in range(depth):
                layers.extend(self._expand_op(trial, block, op, f"{prefix}.{i}"))
        elif mode == "repeat_params":
            op = choose_op(prefix)
            once = self._expand_op(trial, block, op, prefix)
            for i in range(depth):
                layers.extend(LayerIR(op=l.op, params=dict(l.params), path=f"{prefix}.{i}") for l in once)
        else:
            raise SpaceError(f"unhandled repeat mode {mode!r}")

        self._block_layers[block.name] = layers
        return layers

    def sample(self, trial: Trial) -> ArchitectureIR:
        self._block_layers = {}
        layers: List[LayerIR] = []
        for block in self.space.blocks:
            layers.extend(self._expand_block(trial, block, ""))
        pre = sample_preprocessing(trial, self.space)
        return ArchitectureIR(layers=layers, preprocessing=pre)


def sample_preprocessing(trial: Trial, space: SearchSpaceDef) -> List[Dict[str, Any]]:
    """Jointly sample the pre-processing pipeline (paper §IV-E)."""
    stages = []
    for stage, params in space.preprocessing.items():
        sampled = {"stage": stage}
        for pname, pspec in params.items():
            sampled[pname] = _suggest_value(trial, f"pre/{stage}.{pname}", pspec)
        stages.append(sampled)
    return stages


def sample_architecture(space: SearchSpaceDef, trial: Trial, allowed_ops=None) -> ArchitectureIR:
    return SpaceTranslator(space, allowed_ops=allowed_ops).sample(trial)
