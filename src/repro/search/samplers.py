"""Samplers: Random, Grid, TPE-lite, Regularized Evolution, NSGA-II.

These provide the Optuna sampler surface the paper builds on.  All
samplers implement *independent* per-distribution sampling through
``sample(study, trial, name, dist)`` — population-based samplers
additionally precompute a full parent configuration per trial and serve
values from it, falling back to random for never-seen parameters (which
naturally handles conditional search spaces created by the DSL's dynamic
block expansion).
"""
from __future__ import annotations

import random
from typing import Any, Dict, List, Optional, Tuple

from repro.explorer.registry import SAMPLERS
from repro.search.detached import (
    DetachedEvolution,
    DetachedGrid,
    DetachedNSGA2,
    DetachedSampler,
    DetachedTPE,
    grid_value,
    tpe_pick,
    tpe_split,
)
from repro.search.trial import Distribution, Trial, TrialState


class BaseSampler:
    #: True when the sampler's suggestions for trial *n* do not depend on
    #: which other trials have completed (been told) by the time trial *n*
    #: is asked.  Random and Grid qualify — their values derive from the
    #: per-trial RNG stream / the trial number alone — so the sliding-
    #: window scheduler (schedule="auto") runs them fully asynchronously.
    #: Population-based samplers (TPE/evolution/NSGA-II) consult completed
    #: history at ask time, so "auto" keeps them on the batch scheduler,
    #: whose snapshot boundaries are deterministic.
    order_independent = False

    def __init__(self, seed: Optional[int] = None):
        self._base_seed = seed if seed is not None else random.Random().getrandbits(31)
        self.rng = random.Random(seed)

    def trial_rng(self, trial: Trial) -> random.Random:
        """Concurrency-safe randomness hook: a per-trial RNG stream derived
        from (sampler seed, trial number).  Each trial is evaluated by at
        most one worker, so suggestions drawn from this stream are
        deterministic regardless of how many workers run concurrently or
        in which order their suggestions interleave."""
        rng = getattr(trial, "_sampler_rng", None)
        if rng is None:
            rng = random.Random(f"{self._base_seed}/{trial.number}")
            trial._sampler_rng = rng
        return rng

    def sample(self, study, trial: Trial, name: str, dist: Distribution) -> Any:
        raise NotImplementedError

    def on_trial_start(self, study, trial: Trial) -> None:
        """Hook run serially under the study lock at ask() time —
        population-based samplers snapshot parents here so their shared
        ``self.rng`` is never touched from worker threads."""

    def detached(self, study, trial: Trial) -> DetachedSampler:
        """Picklable sampling plan for evaluating ``trial`` in another
        process (see :mod:`repro.search.detached`).  The default plan is
        pure per-trial-stream random — correct for ``RandomSampler``;
        samplers that consult study state must override this to snapshot
        whatever their ``sample`` reads.  Called under the study lock."""
        return DetachedSampler(self._base_seed)


@SAMPLERS.register("random")
class RandomSampler(BaseSampler):
    order_independent = True

    def sample(self, study, trial, name, dist):
        return dist.random(self.trial_rng(trial))


@SAMPLERS.register("grid")
class GridSampler(BaseSampler):
    """Exhaustive sweep over categorical/int grids (continuous -> random)."""

    order_independent = True  # position = f(trial number, registry) only

    def sample(self, study, trial, name, dist):
        if dist.kind == "float":
            return dist.random(self.trial_rng(trial))
        # position determined by trial number so the cartesian product is
        # swept in mixed-radix order across trials
        with study._lock:
            return grid_value(study.distribution_registry, name, dist, trial.number)

    def detached(self, study, trial):
        return DetachedGrid(self._base_seed, study.distribution_registry)


@SAMPLERS.register("tpe")
class TPESampler(BaseSampler):
    """Tree-structured Parzen Estimator (lite).

    Splits completed trials into good/bad by the gamma-quantile of the
    first objective and samples the candidate maximizing l(x)/g(x)
    (kernel density for continuous, smoothed counts for categorical).
    """

    def __init__(self, seed: Optional[int] = None, gamma: float = 0.25,
                 n_candidates: int = 24, n_startup: int = 10):
        super().__init__(seed)
        self.gamma = gamma
        self.n_candidates = n_candidates
        self.n_startup = n_startup

    @staticmethod
    def _records(study) -> List[Tuple[Dict[str, Any], float]]:
        return [
            (t.params, t.values[0]) for t in study.trials
            if t.state == TrialState.COMPLETE and t.values
        ]

    @staticmethod
    def _sign(study) -> float:
        return 1.0 if study.directions[0] == "minimize" else -1.0

    def sample(self, study, trial, name, dist):
        rng = self.trial_rng(trial)
        gvals, bvals = tpe_split(
            self._records(study), name, self.n_startup, self.gamma, self._sign(study))
        if gvals is None:
            return dist.random(rng)
        return tpe_pick(rng, dist, gvals, bvals, self.n_candidates)

    def detached(self, study, trial):
        # One records snapshot per batch, not per trial: every plan in a
        # batch sees the same completed set (tells only happen between
        # batches, and asks bump len(study.trials) before plans are
        # built), so key the memo on the trial count.  Each worker submit
        # still pickles the shared list — inherent to shipping TPE state.
        key = len(study.trials)
        cached = getattr(self, "_detached_snapshot", None)
        if cached is None or cached[0] != key:
            cached = self._detached_snapshot = (key, self._records(study))
        return DetachedTPE(self._base_seed, cached[1], self.gamma,
                           self.n_candidates, self.n_startup, self._sign(study))


@SAMPLERS.register("evolution")
class RegularizedEvolutionSampler(BaseSampler):
    """Regularized evolution (Real et al., 2019): tournament-select a parent
    from a sliding population, mutate one parameter."""

    def __init__(self, seed: Optional[int] = None, population: int = 20,
                 tournament: int = 5, mutation_rate: float = 1.0):
        super().__init__(seed)
        self.population = population
        self.tournament = tournament
        self.mutation_rate = mutation_rate
        self._parent_params: Dict[int, Dict[str, Any]] = {}
        self._mutated: Dict[int, set] = {}

    def on_trial_start(self, study, trial):
        done = [t for t in study.trials if t.state == TrialState.COMPLETE and t.values]
        pop = done[-self.population :]
        if not pop:
            return
        sign = 1.0 if study.directions[0] == "minimize" else -1.0
        cohort = [pop[self.rng.randrange(len(pop))] for _ in range(min(self.tournament, len(pop)))]
        parent = min(cohort, key=lambda t: sign * t.values[0])
        self._parent_params[trial.number] = dict(parent.params)
        names = list(parent.params)
        n_mut = max(1, int(round(self.mutation_rate)))
        self._mutated[trial.number] = set(self.rng.sample(names, min(n_mut, len(names))))

    def sample(self, study, trial, name, dist):
        parent = self._parent_params.get(trial.number)
        if parent is None or name not in parent or name in self._mutated.get(trial.number, ()):
            return dist.random(self.trial_rng(trial))
        return parent[name]

    def detached(self, study, trial):
        return DetachedEvolution(self._base_seed, self._parent_params.get(trial.number),
                                 self._mutated.get(trial.number, ()))


def _dominates(a, b, directions) -> bool:
    signs = [1.0 if d == "minimize" else -1.0 for d in directions]
    av = [s * v for s, v in zip(signs, a)]
    bv = [s * v for s, v in zip(signs, b)]
    return all(x <= y for x, y in zip(av, bv)) and any(x < y for x, y in zip(av, bv))


def pareto_front(trials, directions) -> List[Trial]:
    done = [t for t in trials if t.state == TrialState.COMPLETE and t.values]
    front = []
    for t in done:
        if not any(_dominates(o.values, t.values, directions) for o in done if o is not t):
            front.append(t)
    return front


@SAMPLERS.register("nsga2")
class NSGA2Sampler(BaseSampler):
    """Multi-objective evolutionary sampler: nondominated-rank + crowding
    tournament selection, uniform crossover, per-param mutation."""

    def __init__(self, seed: Optional[int] = None, population: int = 24, mutation_p: float = 0.1):
        super().__init__(seed)
        self.population = population
        self.mutation_p = mutation_p
        self._parent_params: Dict[int, Dict[str, Any]] = {}

    def _rank(self, trials, directions):
        ranks = {}
        remaining = list(trials)
        r = 0
        while remaining:
            front = [
                t for t in remaining
                if not any(_dominates(o.values, t.values, directions) for o in remaining if o is not t)
            ]
            if not front:
                front = list(remaining)
            for t in front:
                ranks[t.number] = r
            remaining = [t for t in remaining if t not in front]
            r += 1
        return ranks

    def _crowding(self, pop):
        """Crowding distance per trial: boundary points get inf, interior
        points the normalized objective-space gap to their neighbours."""
        dist = {t.number: 0.0 for t in pop}
        for k in range(len(pop[0].values)):
            srt = sorted(pop, key=lambda t: t.values[k])
            span = max(srt[-1].values[k] - srt[0].values[k], 1e-12)
            dist[srt[0].number] = dist[srt[-1].number] = float("inf")
            for i in range(1, len(srt) - 1):
                dist[srt[i].number] += (srt[i + 1].values[k] - srt[i - 1].values[k]) / span
        return dist

    def on_trial_start(self, study, trial):
        done = [t for t in study.trials if t.state == TrialState.COMPLETE and t.values]
        pop = done[-self.population :]
        if len(pop) < 2:
            return
        ranks = self._rank(pop, study.directions)
        crowd = self._crowding(pop)
        pick = lambda: min(
            (pop[self.rng.randrange(len(pop))] for _ in range(2)),
            key=lambda t: (ranks[t.number], -crowd[t.number]),
        )
        p1, p2 = pick(), pick()
        child = {
            k: (p1.params.get(k) if self.rng.random() < 0.5 else p2.params.get(k, p1.params.get(k)))
            for k in set(p1.params) | set(p2.params)
        }
        self._parent_params[trial.number] = child

    def sample(self, study, trial, name, dist):
        rng = self.trial_rng(trial)
        parent = self._parent_params.get(trial.number)
        if parent is None or name not in parent or parent[name] is None:
            return dist.random(rng)
        if rng.random() < self.mutation_p:
            # local (polynomial-style) mutation around the inherited value
            return dist.perturb(rng, parent[name])
        return parent[name]

    def detached(self, study, trial):
        return DetachedNSGA2(self._base_seed, self._parent_params.get(trial.number),
                             self.mutation_p)
