"""Samplers: Random, Grid, TPE-lite, Regularized Evolution, NSGA-II.

These provide the Optuna sampler surface the paper builds on.  All
samplers implement *independent* per-distribution sampling through
``sample(study, trial, name, dist)`` — population-based samplers
additionally precompute a full parent configuration per trial and serve
values from it, falling back to random for never-seen parameters (which
naturally handles conditional search spaces created by the DSL's dynamic
block expansion).
"""
from __future__ import annotations

import math
import random
from collections import defaultdict
from typing import Any, Dict, List, Optional

from repro.search.trial import Distribution, Trial, TrialState


class BaseSampler:
    def __init__(self, seed: Optional[int] = None):
        self._base_seed = seed if seed is not None else random.Random().getrandbits(31)
        self.rng = random.Random(seed)

    def trial_rng(self, trial: Trial) -> random.Random:
        """Concurrency-safe randomness hook: a per-trial RNG stream derived
        from (sampler seed, trial number).  Each trial is evaluated by at
        most one worker, so suggestions drawn from this stream are
        deterministic regardless of how many workers run concurrently or
        in which order their suggestions interleave."""
        rng = getattr(trial, "_sampler_rng", None)
        if rng is None:
            rng = random.Random(f"{self._base_seed}/{trial.number}")
            trial._sampler_rng = rng
        return rng

    def sample(self, study, trial: Trial, name: str, dist: Distribution) -> Any:
        raise NotImplementedError

    def on_trial_start(self, study, trial: Trial) -> None:
        """Hook run serially under the study lock at ask() time —
        population-based samplers snapshot parents here so their shared
        ``self.rng`` is never touched from worker threads."""


class RandomSampler(BaseSampler):
    def sample(self, study, trial, name, dist):
        return dist.random(self.trial_rng(trial))


class GridSampler(BaseSampler):
    """Exhaustive sweep over categorical/int grids (continuous -> random)."""

    def __init__(self, seed: Optional[int] = None):
        super().__init__(seed)
        self._cursor: Dict[str, int] = defaultdict(int)

    def sample(self, study, trial, name, dist):
        if dist.kind == "float":
            return dist.random(self.trial_rng(trial))
        grid = dist.grid()
        # position determined by trial number so the cartesian product is
        # swept in mixed-radix order across trials
        with study._lock:
            seen_dists = study.distribution_registry
            if name not in seen_dists:
                seen_dists[name] = dist
            names = sorted(seen_dists)
            radix = 1
            for n in names:
                if n == name:
                    break
                d = seen_dists[n]
                if d.kind != "float":
                    radix *= max(1, len(d.grid()))
        return grid[(trial.number // radix) % len(grid)]


class TPESampler(BaseSampler):
    """Tree-structured Parzen Estimator (lite).

    Splits completed trials into good/bad by the gamma-quantile of the
    first objective and samples the candidate maximizing l(x)/g(x)
    (kernel density for continuous, smoothed counts for categorical).
    """

    def __init__(self, seed: Optional[int] = None, gamma: float = 0.25,
                 n_candidates: int = 24, n_startup: int = 10):
        super().__init__(seed)
        self.gamma = gamma
        self.n_candidates = n_candidates
        self.n_startup = n_startup

    def _split(self, study, name):
        done = [
            t for t in study.trials
            if t.state == TrialState.COMPLETE and name in t.params and t.values
        ]
        if len(done) < self.n_startup:
            return None, None
        sign = 1.0 if study.directions[0] == "minimize" else -1.0
        done.sort(key=lambda t: sign * t.values[0])
        n_good = max(1, int(self.gamma * len(done)))
        return done[:n_good], done[n_good:]

    def sample(self, study, trial, name, dist):
        rng = self.trial_rng(trial)
        good, bad = self._split(study, name)
        if good is None:
            return dist.random(rng)
        gvals = [t.params[name] for t in good]
        bvals = [t.params[name] for t in bad] or gvals
        if dist.kind == "categorical":
            def score(c):
                lg = (gvals.count(c) + 0.5) / (len(gvals) + 0.5 * len(dist.choices))
                lb = (bvals.count(c) + 0.5) / (len(bvals) + 0.5 * len(dist.choices))
                return lg / lb
            return max(dist.choices, key=score)
        # continuous / int: KDE with Scott bandwidth over candidates
        lo, hi = float(dist.low), float(dist.high)
        width = max(hi - lo, 1e-12)

        def kde(vals, x):
            bw = max(1.06 * width * len(vals) ** -0.2, width / 50)
            return sum(math.exp(-0.5 * ((x - v) / bw) ** 2) for v in vals) / (len(vals) * bw)

        cands = [dist.random(rng) for _ in range(self.n_candidates)]
        best = max(cands, key=lambda x: (kde(gvals, x) + 1e-12) / (kde(bvals, x) + 1e-12))
        if dist.kind == "int":
            best = dist.snap_int(best)
        return best


class RegularizedEvolutionSampler(BaseSampler):
    """Regularized evolution (Real et al., 2019): tournament-select a parent
    from a sliding population, mutate one parameter."""

    def __init__(self, seed: Optional[int] = None, population: int = 20,
                 tournament: int = 5, mutation_rate: float = 1.0):
        super().__init__(seed)
        self.population = population
        self.tournament = tournament
        self.mutation_rate = mutation_rate
        self._parent_params: Dict[int, Dict[str, Any]] = {}
        self._mutated: Dict[int, set] = {}

    def on_trial_start(self, study, trial):
        done = [t for t in study.trials if t.state == TrialState.COMPLETE and t.values]
        pop = done[-self.population :]
        if not pop:
            return
        sign = 1.0 if study.directions[0] == "minimize" else -1.0
        cohort = [pop[self.rng.randrange(len(pop))] for _ in range(min(self.tournament, len(pop)))]
        parent = min(cohort, key=lambda t: sign * t.values[0])
        self._parent_params[trial.number] = dict(parent.params)
        names = list(parent.params)
        n_mut = max(1, int(round(self.mutation_rate)))
        self._mutated[trial.number] = set(self.rng.sample(names, min(n_mut, len(names))))

    def sample(self, study, trial, name, dist):
        parent = self._parent_params.get(trial.number)
        if parent is None or name not in parent or name in self._mutated.get(trial.number, ()):
            return dist.random(self.trial_rng(trial))
        return parent[name]


def _dominates(a, b, directions) -> bool:
    signs = [1.0 if d == "minimize" else -1.0 for d in directions]
    av = [s * v for s, v in zip(signs, a)]
    bv = [s * v for s, v in zip(signs, b)]
    return all(x <= y for x, y in zip(av, bv)) and any(x < y for x, y in zip(av, bv))


def pareto_front(trials, directions) -> List[Trial]:
    done = [t for t in trials if t.state == TrialState.COMPLETE and t.values]
    front = []
    for t in done:
        if not any(_dominates(o.values, t.values, directions) for o in done if o is not t):
            front.append(t)
    return front


class NSGA2Sampler(BaseSampler):
    """Multi-objective evolutionary sampler: nondominated-rank + crowding
    tournament selection, uniform crossover, per-param mutation."""

    def __init__(self, seed: Optional[int] = None, population: int = 24, mutation_p: float = 0.1):
        super().__init__(seed)
        self.population = population
        self.mutation_p = mutation_p
        self._parent_params: Dict[int, Dict[str, Any]] = {}

    def _rank(self, trials, directions):
        ranks = {}
        remaining = list(trials)
        r = 0
        while remaining:
            front = [
                t for t in remaining
                if not any(_dominates(o.values, t.values, directions) for o in remaining if o is not t)
            ]
            if not front:
                front = list(remaining)
            for t in front:
                ranks[t.number] = r
            remaining = [t for t in remaining if t not in front]
            r += 1
        return ranks

    def _crowding(self, pop):
        """Crowding distance per trial: boundary points get inf, interior
        points the normalized objective-space gap to their neighbours."""
        dist = {t.number: 0.0 for t in pop}
        for k in range(len(pop[0].values)):
            srt = sorted(pop, key=lambda t: t.values[k])
            span = max(srt[-1].values[k] - srt[0].values[k], 1e-12)
            dist[srt[0].number] = dist[srt[-1].number] = float("inf")
            for i in range(1, len(srt) - 1):
                dist[srt[i].number] += (srt[i + 1].values[k] - srt[i - 1].values[k]) / span
        return dist

    def on_trial_start(self, study, trial):
        done = [t for t in study.trials if t.state == TrialState.COMPLETE and t.values]
        pop = done[-self.population :]
        if len(pop) < 2:
            return
        ranks = self._rank(pop, study.directions)
        crowd = self._crowding(pop)
        pick = lambda: min(
            (pop[self.rng.randrange(len(pop))] for _ in range(2)),
            key=lambda t: (ranks[t.number], -crowd[t.number]),
        )
        p1, p2 = pick(), pick()
        child = {
            k: (p1.params.get(k) if self.rng.random() < 0.5 else p2.params.get(k, p1.params.get(k)))
            for k in set(p1.params) | set(p2.params)
        }
        self._parent_params[trial.number] = child

    def _mutate(self, rng, dist, value):
        """Local (polynomial-style) mutation: perturb the inherited value
        instead of resampling uniformly, so late mutations explore around
        the current front rather than teleporting across the domain."""
        if dist.kind == "float":
            span = float(dist.high) - float(dist.low)
            v = value + rng.gauss(0.0, 0.15 * span)
            return min(max(v, float(dist.low)), float(dist.high))
        if dist.kind == "int":
            span = int(dist.high) - int(dist.low)
            step = int(dist.step or 1)
            v = value + rng.gauss(0.0, max(0.15 * span, step))
            return dist.snap_int(v)
        return dist.random(rng)

    def sample(self, study, trial, name, dist):
        rng = self.trial_rng(trial)
        parent = self._parent_params.get(trial.number)
        if parent is None or name not in parent or parent[name] is None:
            return dist.random(rng)
        if rng.random() < self.mutation_p:
            return self._mutate(rng, dist, parent[name])
        return parent[name]
