"""Trial: one sampled configuration + its evaluation lifecycle.

API mirrors the Optuna surface the paper relies on (§III, §V):
``suggest_categorical/int/float``, intermediate ``report`` + ``should_prune``
for pruners, and user attributes for bookkeeping (e.g. measured hardware
cost from the deployment pipeline).
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple


class TrialState(enum.Enum):
    RUNNING = "running"
    COMPLETE = "complete"
    PRUNED = "pruned"
    FAIL = "fail"
    INFEASIBLE = "infeasible"  # hard constraint violated
    SCREENED = "screened"      # cut by a fidelity-cascade screening stage


@dataclasses.dataclass
class Distribution:
    kind: str  # "categorical" | "int" | "float"
    choices: Optional[Tuple[Any, ...]] = None
    low: Optional[float] = None
    high: Optional[float] = None
    step: Optional[float] = None
    log: bool = False

    def grid(self) -> Tuple[Any, ...]:
        if self.kind == "categorical":
            return tuple(self.choices)
        if self.kind == "int":
            step = int(self.step or 1)
            return tuple(range(int(self.low), int(self.high) + 1, step))
        raise ValueError(f"cannot grid a continuous distribution")

    def snap_int(self, value: float) -> int:
        """Round an int suggestion onto the ``low + k*step`` grid, clamped
        so the result never leaves [low, high]."""
        step = int(self.step or 1)
        lo, hi = int(self.low), int(self.high)
        v = lo + step * int(round((value - lo) / step))
        return max(lo, min(v, lo + step * ((hi - lo) // step)))

    def perturb(self, rng, value: Any) -> Any:
        """Local (polynomial-style) mutation: perturb ``value`` instead of
        resampling uniformly, so late mutations explore around the current
        front rather than teleporting across the domain.  Categorical
        distributions fall back to a uniform resample."""
        if self.kind == "float":
            span = float(self.high) - float(self.low)
            v = value + rng.gauss(0.0, 0.15 * span)
            return min(max(v, float(self.low)), float(self.high))
        if self.kind == "int":
            span = int(self.high) - int(self.low)
            step = int(self.step or 1)
            v = value + rng.gauss(0.0, max(0.15 * span, step))
            return self.snap_int(v)
        return self.random(rng)

    def random(self, rng) -> Any:
        if self.kind == "categorical":
            return self.choices[rng.randrange(len(self.choices))]
        if self.kind == "int":
            if self.log:
                lo, hi = math.log(self.low), math.log(self.high)
                # snap keeps log-sampled values on the step grid
                return self.snap_int(math.exp(lo + (hi - lo) * rng.random()))
            step = int(self.step or 1)
            n = (int(self.high) - int(self.low)) // step
            return int(self.low) + step * rng.randrange(n + 1)
        if self.kind == "float":
            if self.log:
                lo, hi = math.log(self.low), math.log(self.high)
                return math.exp(lo + (hi - lo) * rng.random())
            return self.low + (self.high - self.low) * rng.random()
        raise ValueError(self.kind)

    # -- (de)serialization ----------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        if d.get("choices") is not None:
            d["choices"] = list(d["choices"])
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Distribution":
        d = dict(d)
        if d.get("choices") is not None:
            d["choices"] = tuple(d["choices"])
        return cls(**d)


class Trial:
    def __init__(self, number: int, study):
        self.number = number
        self.study = study
        self.params: Dict[str, Any] = {}
        self.distributions: Dict[str, Distribution] = {}
        self.state = TrialState.RUNNING
        self.values: Optional[Tuple[float, ...]] = None
        self.intermediate: Dict[int, float] = {}
        self.user_attrs: Dict[str, Any] = {}
        self.system_attrs: Dict[str, Any] = {}

    # -- suggestions ---------------------------------------------------------

    def _suggest(self, name: str, dist: Distribution) -> Any:
        if name in self.params:
            return self.params[name]
        value = self.study.sampler.sample(self.study, self, name, dist)
        self.params[name] = value
        self.distributions[name] = dist
        return value

    def suggest_categorical(self, name: str, choices: Sequence[Any]) -> Any:
        return self._suggest(name, Distribution("categorical", choices=tuple(choices)))

    def suggest_int(self, name: str, low: int, high: int, step: int = 1, log: bool = False) -> int:
        return int(self._suggest(name, Distribution("int", low=low, high=high, step=step, log=log)))

    def suggest_float(self, name: str, low: float, high: float, log: bool = False) -> float:
        return float(self._suggest(name, Distribution("float", low=low, high=high, log=log)))

    # -- pruning -------------------------------------------------------------

    def report(self, step: int, value: float) -> None:
        self.intermediate[int(step)] = float(value)

    def should_prune(self) -> bool:
        pruner = self.study.pruner
        if pruner is None or not self.intermediate:
            return False
        return pruner.prune(self.study, self)

    def set_user_attr(self, key: str, value: Any) -> None:
        self.user_attrs[key] = value

    # -- (de)serialization ----------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return {
            "number": self.number,
            "state": self.state.value,
            "values": list(self.values) if self.values is not None else None,
            "params": self.params,
            "distributions": {k: d.to_dict() for k, d in self.distributions.items()},
            "intermediate": {str(k): v for k, v in self.intermediate.items()},
            "user_attrs": self.user_attrs,
            "system_attrs": self.system_attrs,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any], study=None) -> "Trial":
        t = cls(d["number"], study)
        t.state = TrialState(d["state"])
        t.values = tuple(d["values"]) if d.get("values") is not None else None
        t.params = dict(d.get("params", {}))
        t.distributions = {
            k: Distribution.from_dict(v) for k, v in d.get("distributions", {}).items()
        }
        t.intermediate = {int(k): v for k, v in d.get("intermediate", {}).items()}
        t.user_attrs = dict(d.get("user_attrs", {}))
        t.system_attrs = dict(d.get("system_attrs", {}))
        return t

    @property
    def value(self) -> Optional[float]:
        return self.values[0] if self.values else None
