from repro.search.executors import (
    BaseExecutor,
    ProcessExecutor,
    SerialExecutor,
    ThreadExecutor,
    make_executor,
)
from repro.search.parallel import ParallelStudy
from repro.search.pruners import MedianPruner, SuccessiveHalvingPruner
from repro.search.samplers import (
    GridSampler,
    NSGA2Sampler,
    RandomSampler,
    RegularizedEvolutionSampler,
    TPESampler,
    pareto_front,
)
from repro.search.study import HardConstraintViolated, Study, TrialPruned
from repro.search.trial import Distribution, Trial, TrialState

__all__ = [
    "BaseExecutor",
    "Distribution",
    "GridSampler",
    "HardConstraintViolated",
    "MedianPruner",
    "NSGA2Sampler",
    "ParallelStudy",
    "ProcessExecutor",
    "RandomSampler",
    "RegularizedEvolutionSampler",
    "SerialExecutor",
    "Study",
    "SuccessiveHalvingPruner",
    "TPESampler",
    "ThreadExecutor",
    "Trial",
    "TrialPruned",
    "TrialState",
    "make_executor",
    "pareto_front",
]
