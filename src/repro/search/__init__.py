from repro.search.parallel import ParallelStudy
from repro.search.pruners import MedianPruner, SuccessiveHalvingPruner
from repro.search.samplers import (
    GridSampler,
    NSGA2Sampler,
    RandomSampler,
    RegularizedEvolutionSampler,
    TPESampler,
    pareto_front,
)
from repro.search.study import HardConstraintViolated, Study, TrialPruned
from repro.search.trial import Distribution, Trial, TrialState

__all__ = [
    "Distribution",
    "GridSampler",
    "HardConstraintViolated",
    "MedianPruner",
    "NSGA2Sampler",
    "ParallelStudy",
    "RandomSampler",
    "RegularizedEvolutionSampler",
    "Study",
    "SuccessiveHalvingPruner",
    "TPESampler",
    "Trial",
    "TrialPruned",
    "TrialState",
    "pareto_front",
]
