"""Pluggable evaluation executors for :class:`ParallelStudy`.

The study owns *what* runs (scheduling, tell order, error draining); an
executor owns *where* objective calls run:

  * :class:`SerialExecutor`  — in the calling thread, one at a time.
    The reference backend: zero concurrency, zero surprises.
  * :class:`ThreadExecutor`  — a thread pool.  Wins when the objective
    blocks (wall-clock benchmarking, I/O, remote devices) but is bound
    by the GIL + compile admission gate for compile-heavy objectives.
  * :class:`ProcessExecutor` — a ``ProcessPoolExecutor``.  Real compile
    concurrency: each worker process owns its own XLA compiler and GIL.
    Objectives must be picklable (module-level functions or callables —
    closures won't cross the process boundary), and each trial ships as
    a picklable payload: the trial number plus the sampler's *detached
    plan* (see :mod:`repro.search.detached`).  Per-trial RNG streams are
    re-derived in the worker from the same ``(seed, number)`` key, so a
    fixed seed yields identical trials on every backend at any worker
    count.  Everything the worker-side trial accumulates — params,
    distributions, user/system attrs, intermediate reports — is merged
    back into the parent's trial before ``tell``.  When the study has a
    (picklable) pruner, every submission also carries a
    :class:`~repro.search.detached.PrunerContext` snapshot and a report
    channel, so doomed trials terminate *inside* the worker.

The primary surface is **streaming**: ``submit(study, objective, trial,
catch)`` schedules one evaluation, ``next_completed()`` blocks for the
next finished one and returns ``(trial, outcome)`` where the outcome is
either ``(values, state)`` or the ``BaseException`` the objective
escaped with — never raised, so the scheduler sees every sibling
result.  ``run_batch`` is a shim over the streaming surface kept for the
batch scheduler and executor-parity tests.  ``cancel_pending()`` pulls
back submissions whose evaluation has not started (the error path uses
it so queued trials don't run — or stay RUNNING — after a failure).
"""
from __future__ import annotations

import dataclasses
import multiprocessing
import os
import pickle
import queue as queue_module
import shutil
import tempfile
import threading
import traceback
import uuid
import warnings
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from concurrent.futures.process import BrokenProcessPool
from typing import Any, Callable, Dict, Hashable, List, Optional, Tuple, Union

from repro import faults
from repro.envvars import read_env
from repro.explorer.registry import EXECUTORS
from repro.search.detached import (
    DetachedSampler,
    DetachedTrial,
    PrunerContext,
)
from repro.search.study import evaluate_trial
from repro.search.trial import Distribution, Trial, TrialState

Outcome = Union[Tuple[Optional[object], TrialState], BaseException]

#: Returned by a completion thunk when the trial was resubmitted (worker
#: death below the quarantine threshold) — ``next_completed`` keeps
#: waiting instead of surfacing it.
RESUBMITTED = object()


# ---------------------------------------------------------------------------
# process-backend payloads
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WorkerResult:
    """What one out-of-process trial evaluation sends back to the parent."""

    number: int
    values: Optional[object]
    state: TrialState
    params: Dict[str, Any]
    distributions: Dict[str, Distribution]
    user_attrs: Dict[str, Any]
    system_attrs: Dict[str, Any]
    intermediate: Dict[int, float]
    # (context_id, pid, applied_len): which pruner delta-log prefix the
    # worker process holds (see PrunerContext) — lets the parent truncate
    pruner_ack: Optional[Tuple[str, int, int]] = None
    error: Optional[BaseException] = None


def _record_values(values: Any) -> Optional[Tuple[float, ...]]:
    """Normalize a worker's raw objective value(s) to the tuple form
    :class:`~repro.search.detached.TrialRecord` carries."""
    if values is None:
        return None
    if isinstance(values, (tuple, list)):
        try:
            return tuple(float(v) for v in values)
        except (TypeError, ValueError):
            return None
    try:
        return (float(values),)
    except (TypeError, ValueError):
        return None


def _portable_exception(e: BaseException) -> BaseException:
    """Return ``e`` if it survives a pickle round-trip, else a
    ``RuntimeError`` carrying its repr + traceback (the parent re-raises
    whichever comes back)."""
    try:
        pickle.loads(pickle.dumps(e))
        return e
    except Exception:
        return RuntimeError(
            f"unpicklable {type(e).__name__} in process worker: {e}\n"
            + "".join(traceback.format_exception(type(e), e, e.__traceback__))
        )


def run_detached_trial(objective: Callable, number: int, plan: DetachedSampler,
                       catch: Tuple, pruner: Optional[PrunerContext] = None,
                       report_queue: Any = None,
                       params: Optional[Dict[str, Any]] = None,
                       start_dir: Optional[str] = None) -> WorkerResult:
    """Worker entry point: evaluate the objective on a detached trial.
    Uncaught exceptions are *returned* (not raised) so the sampled params
    and attrs collected before the failure still reach the parent.
    ``params`` pre-seeds suggestions already sampled in the parent (the
    cascade's in-parent screening), so the worker evaluates exactly the
    configuration that was screened.  ``start_dir`` is the process
    backend's blame channel: a marker file written *before* the objective
    runs survives a SIGKILL, so on pool breakage the parent knows which
    trials were actually executing (and may be poison) versus merely
    queued (innocent, resubmitted without a strike)."""
    if start_dir is not None:
        try:
            with open(os.path.join(start_dir, str(number)), "w"):
                pass
        except OSError:
            pass  # blame degrades to "unknown": the trial is never struck
    trial = DetachedTrial(number, plan, pruner=pruner, report_queue=report_queue,
                          params=params)
    if pruner is not None:
        # fold the shipped delta slice into this process's history up
        # front, so the ack reflects it even if the objective never
        # reports (and the first should_prune() pays no apply cost)
        pruner.apply()
    error: Optional[BaseException] = None
    try:
        # the worker.trial fault site: `kill` here SIGKILLs this worker
        # process/daemon mid-trial, exactly like an OOM kill would
        faults.fault_point("worker.trial", key=number)
        values, state = evaluate_trial(objective, trial, catch)
    except BaseException as e:  # uncaught objective error
        trial.set_user_attr("error", repr(e))
        values, state = None, TrialState.FAIL
        error = _portable_exception(e)
    return WorkerResult(
        number=number, values=values, state=state, params=trial.params,
        distributions=trial.distributions, user_attrs=trial.user_attrs,
        system_attrs=trial.system_attrs, intermediate=trial.intermediate,
        pruner_ack=pruner.ack() if pruner is not None else None,
        error=error,
    )


def merge_worker_result(study, trial: Trial, res: WorkerResult) -> None:
    """Fold everything a worker-side trial accumulated — params,
    distributions, attrs, intermediate reports — back into the parent's
    trial before ``tell`` (shared by the process and remote backends)."""
    trial.params.update(res.params)
    trial.distributions.update(res.distributions)
    trial.user_attrs.update(res.user_attrs)
    trial.system_attrs.update(res.system_attrs)
    trial.intermediate.update(res.intermediate)
    with study._lock:
        for name, dist in res.distributions.items():
            study.distribution_registry.setdefault(name, dist)


# ---------------------------------------------------------------------------
# pruner delta log (shared by the process + remote backends)
# ---------------------------------------------------------------------------

class PrunerDeltaLog:
    """Parent-side append-only log of pruning history, the O(n)-not-O(n²)
    source for :class:`~repro.search.detached.PrunerContext` snapshots.

    Instead of re-serializing the full intermediate history of every
    trial per submission — O(trials × reports) each time — the parent
    appends streamed ``("report", ...)`` entries and merged ``("final",
    ...)`` terminal records here, and each submission ships only the
    suffix past the prefix every worker has acknowledged holding.
    Workers ack via ``WorkerResult.pruner_ack`` (and, for the remote
    backend, ``refresh_ack`` frames), keyed by a caller-chosen worker
    identity: the worker *pid* for the process pool, the connection's
    worker id for remote daemons (two loopback daemons can share a pid).

    Thread-safe under an internal lock: the process backend only touches
    it from the scheduler thread, but the remote backend's per-connection
    receiver threads append reports and acks concurrently with the
    scheduler's snapshots."""

    def __init__(self):
        self._lock = threading.RLock()
        self._study = None            # study the current context belongs to
        self.context_id: Optional[str] = None
        self._log: List[Tuple] = []
        self._offset = 0              # global index of _log[0]
        self._finalized: set = set()  # trial numbers with a final delta
        self._reported: set = set()   # numbers with streamed, unfinalized reports
        self._acked: Dict[Hashable, int] = {}  # worker key -> applied log length
        self._pruner_ok: Dict[int, Tuple[Any, bool]] = {}  # id -> (pruner, picklable?)

    def clear(self) -> None:
        """Forget the context entirely (executor shutdown: workers died
        with their ``_DELTA_HISTORY``, so a restart must open fresh)."""
        with self._lock:
            self._study = None
            self.context_id = None
            self._log = []
            self._offset = 0
            self._finalized = set()
            self._reported = set()
            self._acked = {}

    def pruner_ok(self, pruner) -> bool:
        """Memoized "does this pruner survive pickling" check (a failure
        degrades that study to no worker-side pruning)."""
        with self._lock:
            # the memo holds a strong reference alongside the verdict:
            # keyed by id() alone, a collected pruner's address could be
            # reused and return the wrong cached answer
            entry = self._pruner_ok.get(id(pruner))
            if entry is not None and entry[0] is pruner:
                return entry[1]
            try:
                pickle.dumps(pruner)
                ok = True
            except Exception:
                ok = False
            self._pruner_ok[id(pruner)] = (pruner, ok)
            return ok

    def reset(self, study) -> None:
        """Open a fresh delta context when the study changes (a reused
        executor), seeding the log with the history visible now."""
        with self._lock:
            if study is self._study:
                return
            self._study = study
            self.context_id = uuid.uuid4().hex
            self._offset = 0
            self._acked = {}
            self._finalized = set()
            self._reported = set()
            self._log = []
            for t in study.trials:
                if t.intermediate:
                    self._log.append(
                        ("final", t.number, t.state, _record_values(t.values),
                         dict(t.intermediate)))
                if t.state != TrialState.RUNNING:
                    self._finalized.add(t.number)

    def add_report(self, number: int, step: int, value: float) -> None:
        """Append one streamed intermediate report."""
        with self._lock:
            if self.context_id is None:
                return
            number = int(number)
            if number in self._finalized:
                return  # the merged terminal record already supersedes these
            self._reported.add(number)
            self._log.append(("report", number, int(step), float(value)))

    def finalize(self, number: int, state: TrialState,
                 values: Any, intermediate: Dict[int, float]) -> None:
        """Append a trial's terminal record, superseding its streamed
        reports (an empty record drops a dead worker's partial values
        from future snapshots)."""
        with self._lock:
            if self.context_id is None or number in self._finalized:
                return
            self._finalized.add(number)
            if intermediate or number in self._reported:
                self._log.append(
                    ("final", number, state, _record_values(values),
                     dict(intermediate)))
            self._reported.discard(number)

    def ack(self, key: Hashable, context_id: Optional[str], applied: int) -> None:
        """Record that worker ``key`` holds the log up to ``applied``."""
        with self._lock:
            if context_id is not None and context_id == self.context_id:
                self._acked[key] = max(self._acked.get(key, 0), int(applied))

    def drop_worker(self, key: Hashable) -> None:
        """Forget a dead worker's ack so truncation tracks the living."""
        with self._lock:
            self._acked.pop(key, None)

    def truncate(self, n_workers: int) -> None:
        """Drop the prefix every one of ``n_workers`` workers has
        acknowledged applying.  Until all have acked at least once,
        everything ships from the context origin — a worker that misses
        a truncated prefix can never prune again for this study (see
        PrunerContext), so truncation waits for proof of delivery."""
        with self._lock:
            if self._acked and len(self._acked) >= n_workers:
                base = max(self._offset, min(self._acked.values()))
                if base > self._offset:
                    del self._log[: base - self._offset]
                    self._offset = base

    def snapshot(self, pruner, directions) -> PrunerContext:
        """A picklable :class:`PrunerContext` of the current log slice
        (copied under the lock: the pickling thread must not race
        appends)."""
        with self._lock:
            return PrunerContext(pruner, directions,
                                 deltas=list(self._log),
                                 base=self._offset,
                                 context_id=self.context_id)

    def tail_for(self, key: Hashable) -> Optional[Tuple[str, int, List[Tuple]]]:
        """The ``(context_id, base, deltas)`` slice worker ``key`` has not
        acknowledged yet, for a mid-trial refresh push — or ``None`` when
        there is no context or nothing new for that worker."""
        with self._lock:
            if self.context_id is None:
                return None
            acked = self._acked.get(key, 0)
            end = self._offset + len(self._log)
            if acked >= end:
                return None
            base = max(self._offset, acked)
            return (self.context_id, base, self._log[base - self._offset:])


# ---------------------------------------------------------------------------
# executors
# ---------------------------------------------------------------------------

class _StreamState:
    """Per-executor streaming bookkeeping.  ``pending`` is touched only
    by the scheduler thread; ``done`` is the completion channel fed by
    pool callbacks (or inline, for the serial backend)."""

    def __init__(self):
        self.done: "queue_module.SimpleQueue" = queue_module.SimpleQueue()
        self.pending: Dict[int, Tuple[Trial, Any]] = {}  # number -> (trial, future|None)


class BaseExecutor:
    """Lifecycle: ``start(n_workers)``, any number of ``submit`` /
    ``next_completed`` rounds (or ``run_batch`` calls), then
    ``shutdown()`` (optimize does all of it; an executor instance is
    restartable).  ``start`` on an already-started executor keeps the
    existing pool, so a caller can pre-start (and :meth:`warmup`) an
    executor before handing it to ``optimize``.

    Subclasses implement :meth:`submit`; completions flow through the
    shared stream state via :meth:`_complete`, as ``(trial, thunk)``
    pairs where the thunk — run in the scheduler thread by
    :meth:`next_completed` — produces the final outcome (and, for the
    process backend, merges worker state back into the parent trial).
    """

    name = "base"

    def _stream(self) -> _StreamState:
        st = getattr(self, "_stream_state", None)
        if st is None:
            st = self._stream_state = _StreamState()
        return st

    def _track(self, trial: Trial, future: Any = None) -> None:
        faults.fault_point("executor.submit", key=trial.number)
        self._stream().pending[trial.number] = (trial, future)

    def _complete(self, trial: Trial, thunk: Callable[[], Outcome]) -> None:
        self._stream().done.put((trial, thunk))

    # -- lifecycle -------------------------------------------------------------

    def start(self, n_workers: int) -> None:
        pass

    def shutdown(self) -> None:
        pass

    def warmup(self, fn: Callable[[], Any]) -> None:
        """Best-effort: run ``fn()`` once per worker so one-time
        per-process costs (interpreter spawn, heavyweight imports, XLA
        backend init) land before the first measured batch.  In-process
        executors share the parent's modules, so the default is a no-op."""

    # -- streaming surface -----------------------------------------------------

    def submit(self, study, objective: Callable, trial: Trial, catch: Tuple) -> None:
        """Schedule one objective evaluation; returns immediately (the
        serial backend evaluates inline, which is its semantics)."""
        raise NotImplementedError

    def pending_count(self) -> int:
        """Submissions not yet returned by :meth:`next_completed`."""
        return len(self._stream().pending)

    def next_completed(self) -> Tuple[Trial, Outcome]:
        """Block until any in-flight submission finishes; return its
        trial and outcome.  Outcomes are ``(values, state)`` or the
        ``BaseException`` the objective escaped with — never raised, so
        the scheduler's draining error path sees every sibling result."""
        st = self._stream()
        while True:
            if not st.pending:
                raise RuntimeError("next_completed() with no in-flight submissions")
            trial, thunk = st.done.get()
            # identity check, not just number: a cancelled submission's
            # callback still enqueues here, and a stale entry left from a
            # previous optimize round on a reused executor could otherwise
            # collide with a new study's trial of the same number
            entry = st.pending.get(trial.number)
            if entry is None or entry[0] is not trial:
                continue
            st.pending.pop(trial.number)
            outcome = thunk()
            if outcome is RESUBMITTED:
                # a worker death below the quarantine threshold: the
                # thunk re-submitted the trial (it is pending again), so
                # keep waiting for a real completion
                continue
            return trial, outcome

    def cancel_pending(self) -> List[Trial]:
        """Cancel submissions whose evaluation has not started and return
        their trials (the scheduler tells them FAIL with the cancellation
        recorded).  Already-running evaluations keep going — drain them
        with :meth:`next_completed`."""
        st = self._stream()
        cancelled: List[Trial] = []
        for number, (trial, future) in list(st.pending.items()):
            if future is not None and future.cancel():
                st.pending.pop(number, None)
                cancelled.append(trial)
        return cancelled

    # -- batch shim ------------------------------------------------------------

    def run_batch(self, study, objective: Callable, trials: List[Trial],
                  catch: Tuple) -> List[Outcome]:
        """Submit ``trials``, wait for all of them, return outcomes in
        trial order.  The whole batch drains before any outcome is
        surfaced, so sibling results of a failing trial are preserved."""
        for trial in trials:
            self.submit(study, objective, trial, catch)
        outcomes: Dict[int, Outcome] = {}
        for _ in trials:
            trial, outcome = self.next_completed()
            outcomes[trial.number] = outcome
        return [outcomes[t.number] for t in trials]


def _future_outcome(future) -> Outcome:
    try:
        return future.result()
    except BaseException as e:
        return e


@EXECUTORS.register("serial")
class SerialExecutor(BaseExecutor):
    name = "serial"

    def submit(self, study, objective, trial, catch):
        outcome: Outcome
        try:
            outcome = evaluate_trial(objective, trial, catch)
        except BaseException as e:
            outcome = e
        self._track(trial)
        self._complete(trial, lambda outcome=outcome: outcome)


@EXECUTORS.register("thread")
class ThreadExecutor(BaseExecutor):
    name = "thread"

    def __init__(self):
        self._pool: Optional[ThreadPoolExecutor] = None

    def start(self, n_workers):
        if self._pool is None:
            self._pool = ThreadPoolExecutor(max_workers=n_workers)

    def shutdown(self):
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def submit(self, study, objective, trial, catch):
        future = self._pool.submit(evaluate_trial, objective, trial, catch)
        self._track(trial, future)
        future.add_done_callback(
            lambda f, trial=trial: self._complete(trial, lambda: _future_outcome(f)))


@EXECUTORS.register("process")
class ProcessExecutor(BaseExecutor):
    """Evaluate trials in worker processes (default start method: spawn —
    forking a process that already initialized XLA's thread pools is not
    safe).  When the study has a picklable pruner, each submission ships
    a pruner snapshot + a report channel, so workers prune doomed trials
    themselves (see :class:`~repro.search.detached.PrunerContext`)."""

    name = "process"

    def __init__(self, mp_context: str = "spawn",
                 quarantine_after: Optional[int] = None):
        self.mp_context = mp_context
        # worker deaths one trial may be implicated in before it is told
        # FAIL (user_attrs["quarantined"]) instead of resubmitted — a
        # poison trial that OOM-kills every process it lands on must not
        # break the pool for its siblings forever
        self.quarantine_after = (
            quarantine_after if quarantine_after is not None
            else read_env("REPRO_QUARANTINE_DEATHS", 2))
        self._pool: Optional[ProcessPoolExecutor] = None
        self._n_workers = 0
        self._manager = None          # multiprocessing.Manager for the report channel
        self._report_queue = None     # proxy queue workers stream reports into
        self._deaths: Dict[int, int] = {}  # trial number -> implicated deaths
        self._start_dir: Optional[str] = None  # blame markers (see run_detached_trial)
        # append-only pruner-history delta log (see _pruner_context);
        # this backend touches it only from the scheduler thread (submit
        # + next_completed's collect thunks), acks keyed by worker pid
        self._delta = PrunerDeltaLog()

    def start(self, n_workers):
        if self._pool is not None:
            return
        self._pool = self._make_pool(n_workers)
        self._n_workers = n_workers
        if self._start_dir is None:
            self._start_dir = tempfile.mkdtemp(prefix="repro-trial-blame-")

    def _make_pool(self, n_workers: int) -> ProcessPoolExecutor:
        ctx = multiprocessing.get_context(self.mp_context)
        return ProcessPoolExecutor(max_workers=n_workers, mp_context=ctx)

    def _restart_pool(self, broken: ProcessPoolExecutor) -> None:
        """Replace a broken pool exactly once: the first in-flight future
        to observe the breakage swaps it, siblings (whose ``broken`` ref
        no longer matches) reuse the replacement."""
        if self._pool is not broken:
            return
        try:
            broken.shutdown(wait=False)
        except Exception:
            pass
        self._pool = self._make_pool(self._n_workers)

    def shutdown(self):
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
        if self._manager is not None:
            self._manager.shutdown()
            self._manager = None
            self._report_queue = None
        if self._start_dir is not None:
            shutil.rmtree(self._start_dir, ignore_errors=True)
            self._start_dir = None
        self._deaths.clear()
        # pool workers died with their _DELTA_HISTORY; a restarted
        # executor must open a fresh context rather than resume this log
        self._delta.clear()

    def warmup(self, fn):
        """Run ``fn`` once per worker.  ``fn`` should be slow enough
        (importing jax qualifies) that every worker process spawns and
        takes one task; a racy double-grab only means one worker warms
        lazily at its first real trial."""
        if self._pool is None:
            return
        for fut in [self._pool.submit(fn) for _ in range(self._n_workers)]:
            fut.result()

    # -- worker-side pruning ---------------------------------------------------

    def _drain_reports(self) -> None:
        """Pull streamed (number, step, value) intermediate reports into
        the delta log consulted by new pruner snapshots."""
        q = self._report_queue
        if q is None:
            return
        while True:
            try:
                number, step, value = q.get_nowait()
            except Exception:  # queue.Empty, or the manager going down
                break
            self._delta.add_report(number, step, value)

    def _pruner_context(self, study) -> Optional[PrunerContext]:
        """Snapshot the pruner + history *slice* for one submission
        (called under the study lock, so siblings' merged state is
        stable).  See :class:`PrunerDeltaLog` for why a delta slice and
        not a full history snapshot."""
        pruner = getattr(study, "pruner", None)
        if pruner is None or not self._delta.pruner_ok(pruner):
            return None
        if self._report_queue is None:
            ctx = multiprocessing.get_context(self.mp_context)
            self._manager = ctx.Manager()
            self._report_queue = self._manager.Queue()
        self._delta.reset(study)
        self._drain_reports()
        self._delta.truncate(self._n_workers)
        return self._delta.snapshot(pruner, study.directions)

    # -- submission ------------------------------------------------------------

    def _merge(self, study, trial: Trial, res: WorkerResult) -> None:
        merge_worker_result(study, trial, res)

    def _blame_marker(self, number: int) -> str:
        return os.path.join(self._start_dir or "", str(number))

    def _worker_death(self, study, objective, trial: Trial, catch,
                      pool: ProcessPoolExecutor, exc: BaseException) -> Outcome:
        """One in-flight future observed pool breakage (a worker process
        was SIGKILLed / OOM-killed / segfaulted).  Restart the pool, then
        either resubmit the trial or — if its blame marker shows it was
        actually *executing* across ``quarantine_after`` deaths —
        quarantine it so a poison trial cannot break the pool forever.
        Trials that were only queued when the pool broke carry no marker
        and are resubmitted without a strike."""
        self._restart_pool(pool)
        marker = self._blame_marker(trial.number)
        implicated = self._start_dir is not None and os.path.exists(marker)
        if implicated:
            self._deaths[trial.number] = deaths = self._deaths.get(trial.number, 0) + 1
            try:
                os.unlink(marker)  # re-arm the marker for the resubmission
            except OSError:
                pass
            if deaths >= self.quarantine_after:
                warnings.warn(
                    f"trial {trial.number} implicated in {deaths} worker "
                    f"death(s); quarantining it instead of resubmitting",
                    RuntimeWarning, stacklevel=2)
                self._delta.finalize(trial.number, TrialState.FAIL, None, {})
                trial.set_user_attr("quarantined", {
                    "deaths": deaths, "error": repr(exc)})
                trial.set_user_attr("error", repr(exc))
                return (None, TrialState.FAIL)
        try:
            self.submit(study, objective, trial, catch)
        except BrokenProcessPool as e:  # replacement pool died instantly
            self._delta.finalize(trial.number, TrialState.FAIL, None, {})
            trial.set_user_attr("error", repr(e))
            return e
        return RESUBMITTED

    def _collect(self, study, objective, trial: Trial, catch,
                 pool: ProcessPoolExecutor, future) -> Outcome:
        try:
            res = future.result()
        except BrokenProcessPool as e:
            return self._worker_death(study, objective, trial, catch, pool, e)
        except BaseException as e:  # payload/result failed to pickle
            # retract any reports the dead worker streamed: no merge
            # happened, so later pruner snapshots must not count its
            # partial values
            self._delta.finalize(trial.number, TrialState.FAIL, None, {})
            trial.set_user_attr("error", repr(e))
            return e
        if self._start_dir is not None:
            try:
                os.unlink(self._blame_marker(trial.number))
            except OSError:
                pass
        self._deaths.pop(trial.number, None)
        self._merge(study, trial, res)
        if res.pruner_ack is not None:
            cid, pid, applied = res.pruner_ack
            self._delta.ack(pid, cid, applied)
        self._delta.finalize(res.number, res.state, res.values, res.intermediate)
        if res.error is not None:
            return res.error
        return (res.values, res.state)

    def submit(self, study, objective, trial, catch):
        with study._lock:
            plan = study.sampler.detached(study, trial)
            pruner_ctx = self._pruner_context(study)
        pool = self._pool
        future = pool.submit(
            run_detached_trial, objective, trial.number, plan, catch,
            pruner=pruner_ctx, report_queue=self._report_queue,
            params=dict(trial.params) or None, start_dir=self._start_dir)
        self._track(trial, future)
        future.add_done_callback(
            lambda f, trial=trial: self._complete(
                trial, lambda: self._collect(study, objective, trial, catch,
                                             pool, f)))


def make_executor(backend: Union[str, BaseExecutor]) -> BaseExecutor:
    """Resolve a backend name through the executor registry ("serial" |
    "thread" | "process" | any plugin key) or pass an instance through.
    Unknown names raise a ValueError listing the registered backends."""
    if isinstance(backend, BaseExecutor):
        return backend
    return EXECUTORS.get(backend)()
