"""Pluggable evaluation executors for :class:`ParallelStudy`.

The study owns *what* runs (batch-ask, tell-in-trial-order, batch
draining on errors); an executor owns *where* a batch of objective calls
runs:

  * :class:`SerialExecutor`  — in the calling thread, one at a time.
    The reference backend: zero concurrency, zero surprises.
  * :class:`ThreadExecutor`  — a thread pool.  Wins when the objective
    blocks (wall-clock benchmarking, I/O, remote devices) but is bound
    by the GIL + compile admission gate for compile-heavy objectives.
  * :class:`ProcessExecutor` — a ``ProcessPoolExecutor``.  Real compile
    concurrency: each worker process owns its own XLA compiler and GIL.
    Objectives must be picklable (module-level functions or callables —
    closures won't cross the process boundary), and each trial ships as
    a picklable payload: the trial number plus the sampler's *detached
    plan* (see :mod:`repro.search.detached`).  Per-trial RNG streams are
    re-derived in the worker from the same ``(seed, number)`` key, so a
    fixed seed yields identical trials on every backend at any worker
    count.  Everything the worker-side trial accumulates — params,
    distributions, user/system attrs, intermediate reports — is merged
    back into the parent's trial before ``tell``.

All three return, for each trial in the batch, either a
``(values, state)`` outcome or the ``BaseException`` the objective
escaped with; they never raise from ``run_batch`` itself, so the study's
batch-draining error path sees every sibling result.
"""
from __future__ import annotations

import dataclasses
import multiprocessing
import pickle
import traceback
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

from repro.explorer.registry import EXECUTORS
from repro.search.detached import DetachedSampler, DetachedTrial
from repro.search.study import evaluate_trial
from repro.search.trial import Distribution, Trial, TrialState

Outcome = Union[Tuple[Optional[object], TrialState], BaseException]


# ---------------------------------------------------------------------------
# process-backend payloads
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WorkerResult:
    """What one out-of-process trial evaluation sends back to the parent."""

    number: int
    values: Optional[object]
    state: TrialState
    params: Dict[str, Any]
    distributions: Dict[str, Distribution]
    user_attrs: Dict[str, Any]
    system_attrs: Dict[str, Any]
    intermediate: Dict[int, float]
    error: Optional[BaseException] = None


def _portable_exception(e: BaseException) -> BaseException:
    """Return ``e`` if it survives a pickle round-trip, else a
    ``RuntimeError`` carrying its repr + traceback (the parent re-raises
    whichever comes back)."""
    try:
        pickle.loads(pickle.dumps(e))
        return e
    except Exception:
        return RuntimeError(
            f"unpicklable {type(e).__name__} in process worker: {e}\n"
            + "".join(traceback.format_exception(type(e), e, e.__traceback__))
        )


def run_detached_trial(objective: Callable, number: int, plan: DetachedSampler,
                       catch: Tuple) -> WorkerResult:
    """Worker entry point: evaluate the objective on a detached trial.
    Uncaught exceptions are *returned* (not raised) so the sampled params
    and attrs collected before the failure still reach the parent."""
    trial = DetachedTrial(number, plan)
    error: Optional[BaseException] = None
    try:
        values, state = evaluate_trial(objective, trial, catch)
    except BaseException as e:  # uncaught objective error
        trial.set_user_attr("error", repr(e))
        values, state = None, TrialState.FAIL
        error = _portable_exception(e)
    return WorkerResult(
        number=number, values=values, state=state, params=trial.params,
        distributions=trial.distributions, user_attrs=trial.user_attrs,
        system_attrs=trial.system_attrs, intermediate=trial.intermediate,
        error=error,
    )


# ---------------------------------------------------------------------------
# executors
# ---------------------------------------------------------------------------

class BaseExecutor:
    """Lifecycle: ``start(n_workers)``, any number of ``run_batch`` calls,
    then ``shutdown()`` (optimize does all three; an executor instance is
    restartable).  ``start`` on an already-started executor keeps the
    existing pool, so a caller can pre-start (and :meth:`warmup`) an
    executor before handing it to ``optimize``."""

    name = "base"

    def start(self, n_workers: int) -> None:
        pass

    def shutdown(self) -> None:
        pass

    def warmup(self, fn: Callable[[], Any]) -> None:
        """Best-effort: run ``fn()`` once per worker so one-time
        per-process costs (interpreter spawn, heavyweight imports, XLA
        backend init) land before the first measured batch.  In-process
        executors share the parent's modules, so the default is a no-op."""

    def run_batch(self, study, objective: Callable, trials: List[Trial],
                  catch: Tuple) -> List[Outcome]:
        raise NotImplementedError


@EXECUTORS.register("serial")
class SerialExecutor(BaseExecutor):
    name = "serial"

    def run_batch(self, study, objective, trials, catch):
        out: List[Outcome] = []
        for trial in trials:
            try:
                out.append(evaluate_trial(objective, trial, catch))
            except BaseException as e:
                out.append(e)
        return out


@EXECUTORS.register("thread")
class ThreadExecutor(BaseExecutor):
    name = "thread"

    def __init__(self):
        self._pool: Optional[ThreadPoolExecutor] = None

    def start(self, n_workers):
        if self._pool is None:
            self._pool = ThreadPoolExecutor(max_workers=n_workers)

    def shutdown(self):
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def run_batch(self, study, objective, trials, catch):
        futures = [self._pool.submit(evaluate_trial, objective, t, catch) for t in trials]
        out: List[Outcome] = []
        for fut in futures:
            try:
                out.append(fut.result())
            except BaseException as e:
                out.append(e)
        return out


@EXECUTORS.register("process")
class ProcessExecutor(BaseExecutor):
    """Evaluate trials in worker processes (default start method: spawn —
    forking a process that already initialized XLA's thread pools is not
    safe).  Worker-side pruning is disabled; see DetachedTrial."""

    name = "process"

    def __init__(self, mp_context: str = "spawn"):
        self.mp_context = mp_context
        self._pool: Optional[ProcessPoolExecutor] = None
        self._n_workers = 0

    def start(self, n_workers):
        if self._pool is not None:
            return
        ctx = multiprocessing.get_context(self.mp_context)
        self._pool = ProcessPoolExecutor(max_workers=n_workers, mp_context=ctx)
        self._n_workers = n_workers

    def shutdown(self):
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def warmup(self, fn):
        """Run ``fn`` once per worker.  ``fn`` should be slow enough
        (importing jax qualifies) that every worker process spawns and
        takes one task; a racy double-grab only means one worker warms
        lazily at its first real trial."""
        if self._pool is None:
            return
        for fut in [self._pool.submit(fn) for _ in range(self._n_workers)]:
            fut.result()

    def _merge(self, study, trial: Trial, res: WorkerResult) -> None:
        trial.params.update(res.params)
        trial.distributions.update(res.distributions)
        trial.user_attrs.update(res.user_attrs)
        trial.system_attrs.update(res.system_attrs)
        trial.intermediate.update(res.intermediate)
        with study._lock:
            for name, dist in res.distributions.items():
                study.distribution_registry.setdefault(name, dist)

    def run_batch(self, study, objective, trials, catch):
        with study._lock:
            plans = [study.sampler.detached(study, t) for t in trials]
        futures = [
            self._pool.submit(run_detached_trial, objective, t.number, plan, catch)
            for t, plan in zip(trials, plans)
        ]
        out: List[Outcome] = []
        for fut, trial in zip(futures, trials):
            try:
                res = fut.result()
            except BaseException as e:  # payload/result failed to pickle, worker died
                trial.set_user_attr("error", repr(e))
                out.append(e)
                continue
            self._merge(study, trial, res)
            if res.error is not None:
                out.append(res.error)
            else:
                out.append((res.values, res.state))
        return out


def make_executor(backend: Union[str, BaseExecutor]) -> BaseExecutor:
    """Resolve a backend name through the executor registry ("serial" |
    "thread" | "process" | any plugin key) or pass an instance through.
    Unknown names raise a ValueError listing the registered backends."""
    if isinstance(backend, BaseExecutor):
        return backend
    return EXECUTORS.get(backend)()
