"""Client-side worker-pool plumbing shared by the remote executor and
the sweep-cell scheduler.

:class:`RemoteClient` owns everything between "a list of host:port
strings" and "call this function when the task finishes": connecting +
handshaking each address (unreachable or rejecting workers are warned
about and dropped), one receiver thread per connection, task dispatch
to idle workers with a FIFO overflow queue, and the fault-tolerance
discipline the acceptance tests pin down:

* **failure detection** — a connection error, EOF, a worker silent past
  ``heartbeat_timeout_s`` (daemons heartbeat every couple of seconds),
  or a task running past ``task_timeout_s`` (straggler; off by default)
  all declare the worker lost;
* **bounded resubmission** — a lost worker's in-flight task is re-built
  (``make_payload`` runs per attempt, so retried trials carry *fresh*
  pruner snapshots) and resubmitted to a sibling, up to ``retries``
  extra attempts.  This is safe for trials because detached plans are
  deterministic: the retry reproduces the original parameters exactly.
  Retries exhausted — or the last live worker gone — surface as an
  error through the task's completion callback, never as an exception
  on a pool thread.

Completion callbacks run on receiver threads; callers route them into
their own completion channel (the executor's stream state, the sweep
scheduler's queue) and must not block in them.
"""
from __future__ import annotations

import collections
import pickle
import random
import threading
import time
import uuid
import warnings
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

from repro.envvars import read_env
from repro.search.remote import transport
from repro.search.remote.transport import (
    Connection,
    HandshakeError,
    TransportError,
)

TIMEOUT_ENV = "REPRO_REMOTE_TIMEOUT_S"
RETRIES_ENV = "REPRO_REMOTE_RETRIES"
DEFAULT_HEARTBEAT_TIMEOUT_S = 10.0
DEFAULT_RETRIES = 2

_monotonic = time.monotonic  # stubable in tests

# reconnect backoff for rejoin-enabled pools: exponential from the base
# up to the cap, each sleep jittered so a restarted pool's clients don't
# thundering-herd one daemon socket
REJOIN_BACKOFF_BASE_S = 0.2
REJOIN_BACKOFF_CAP_S = 5.0

# (context_id, base, deltas) — what a pruner-refresh push ships
RefreshTail = Tuple[str, int, List[Tuple]]


class PoisonTrialError(RuntimeError):
    """A task was implicated in ``quarantine_after`` worker deaths: the
    evidence says the task itself kills workers (OOM, segfault in a
    compile), so resubmitting it anywhere would drain the pool.  The
    executor converts this into a quarantined FAIL for the trial."""

    def __init__(self, message: str, deaths: int):
        super().__init__(message)
        self.deaths = deaths


class RemoteTask:
    """One submitted unit of work.  ``cancel()`` implements the
    future-like protocol :meth:`BaseExecutor.cancel_pending` expects:
    only tasks not yet assigned to a worker cancel."""

    def __init__(self, key: Any, make_payload: Callable[[], bytes],
                 on_done: Callable[[Any, Any, Optional[BaseException],
                                    Optional[str]], None]):
        self.key = key
        self.make_payload = make_payload
        self.on_done = on_done
        self.attempts = 0
        self.deaths = 0  # workers lost while running this task
        self.task_id: Optional[str] = None  # fresh per attempt
        self.worker: Optional["_Worker"] = None
        self.done = False
        self.cancelled = False
        self._client: Optional["RemoteClient"] = None

    def cancel(self) -> bool:
        client = self._client
        return client is not None and client._cancel(self)


class _Worker:
    """Client-side view of one connected daemon."""

    def __init__(self, addr: str, conn: Connection, worker_id: str):
        self.addr = addr          # the pool-unique key callers see
        self.conn = conn
        self.worker_id = worker_id
        self.alive = True
        self.busy: Optional[RemoteTask] = None
        self.started = 0.0        # when the current task was assigned
        self.last_seen = _monotonic()
        self.last_refresh = 0.0
        self.tasks_done = 0


class RemoteClient:
    """See module docstring.  Callbacks (all optional, all invoked
    outside the pool lock):

    * ``on_report(worker_addr, meta)`` — a streamed intermediate report;
    * ``on_refresh_ack(worker_addr, context_id, applied)`` — a worker
      acknowledged a mid-trial pruner refresh;
    * ``on_worker_lost(worker_addr, reason)`` — bookkeeping hook (the
      executor drops the worker's delta-log ack entry)."""

    def __init__(self, addrs: List[str], *,
                 retries: Optional[int] = None,
                 heartbeat_timeout_s: Optional[float] = None,
                 task_timeout_s: Optional[float] = None,
                 connect_timeout_s: float = 5.0,
                 refresh_min_interval_s: float = 0.25,
                 quarantine_after: Optional[int] = None,
                 rejoin: bool = False,
                 on_report: Optional[Callable] = None,
                 on_refresh_ack: Optional[Callable] = None,
                 on_worker_lost: Optional[Callable] = None):
        self.addrs = [str(a) for a in addrs]
        for addr in self.addrs:
            transport.parse_addr(addr)  # fail fast on malformed config
        self.retries = (read_env(RETRIES_ENV, DEFAULT_RETRIES)
                        if retries is None else max(0, int(retries)))
        self.heartbeat_timeout_s = (
            read_env(TIMEOUT_ENV, DEFAULT_HEARTBEAT_TIMEOUT_S)
            if heartbeat_timeout_s is None else float(heartbeat_timeout_s))
        self.task_timeout_s = task_timeout_s
        self.connect_timeout_s = float(connect_timeout_s)
        self.refresh_min_interval_s = float(refresh_min_interval_s)
        # None disables quarantine at this layer: retry exhaustion stays
        # the client's only give-up path (the executor layers quarantine
        # on top with its own default)
        self.quarantine_after = (None if quarantine_after is None
                                 else max(1, int(quarantine_after)))
        self.rejoin = bool(rejoin)
        self.on_report = on_report
        self.on_refresh_ack = on_refresh_ack
        self.on_worker_lost = on_worker_lost
        self._lock = threading.Lock()
        self._workers: List[_Worker] = []
        self._queue: "collections.deque[RemoteTask]" = collections.deque()
        self._threads: List[threading.Thread] = []
        self._rejoining: Set[str] = set()  # addrs with a redial thread up
        self._wake = threading.Event()     # set at close: aborts backoff sleeps
        self._closing = False

    # -- pool lifecycle --------------------------------------------------------

    def connect(self) -> List[str]:
        """Connect + handshake every address; returns the addresses that
        made it into the pool.  Failures warn and are skipped — zero
        live workers is the *caller's* degradation decision."""
        for addr in self.addrs:
            self._connect_addr(addr)
        return self.live_workers()

    def _connect_addr(self, addr: str, quiet: bool = False) -> Optional["_Worker"]:
        """Connect + handshake one address and start its receiver thread.
        ``quiet`` suppresses the per-failure warnings (the rejoin loop
        retries for minutes and must not spam)."""
        try:
            conn = transport.connect(addr, timeout=self.connect_timeout_s)
        except OSError as e:
            if not quiet:
                warnings.warn(f"remote worker {addr} unreachable ({e}); skipping",
                              RuntimeWarning, stacklevel=3)
            return None
        try:
            hello = transport.client_hello(conn, timeout=self.connect_timeout_s)
        except (HandshakeError, TransportError) as e:
            conn.close()
            if not quiet:
                warnings.warn(f"remote worker {addr} rejected the handshake: {e}",
                              RuntimeWarning, stacklevel=3)
            return None
        worker = _Worker(addr, conn, str(hello.get("worker", addr)))
        with self._lock:
            if self._closing:
                conn.close()
                return None
            self._workers.append(worker)
        t = threading.Thread(target=self._recv_loop, args=(worker,),
                             daemon=True, name=f"repro-remote-recv-{addr}")
        t.start()
        self._threads.append(t)
        return worker

    # -- rejoin (dynamic pool membership) --------------------------------------

    def _start_rejoin(self, addr: str) -> None:
        """Begin redialing a lost worker's address on a background
        thread, with exponential backoff + jitter; on success the daemon
        re-enters the pool and queued work starts flowing to it."""
        with self._lock:
            if self._closing or addr in self._rejoining:
                return
            self._rejoining.add(addr)
        t = threading.Thread(target=self._rejoin_loop, args=(addr,),
                             daemon=True, name=f"repro-remote-rejoin-{addr}")
        t.start()
        self._threads.append(t)

    def _rejoin_loop(self, addr: str) -> None:
        delay = REJOIN_BACKOFF_BASE_S
        try:
            while not self._closing:
                # jittered sleep: simultaneous rejoiners (a whole pool
                # restarting) spread out instead of herding one socket
                self._wake.wait(delay * random.uniform(0.5, 1.5))
                if self._closing:
                    return
                worker = self._connect_addr(addr, quiet=True)
                if worker is not None:
                    warnings.warn(f"remote worker {addr} rejoined the pool",
                                  RuntimeWarning, stacklevel=2)
                    self._pump()
                    return
                delay = min(delay * 2.0, REJOIN_BACKOFF_CAP_S)
        finally:
            with self._lock:
                self._rejoining.discard(addr)

    def live_workers(self) -> List[str]:
        with self._lock:
            return [w.addr for w in self._workers if w.alive]

    def close(self) -> None:
        self._closing = True
        self._wake.set()  # abort rejoin backoff sleeps
        with self._lock:
            workers = list(self._workers)
            self._workers = []
            self._queue.clear()
        for w in workers:
            try:
                w.conn.send("bye")
            except TransportError:
                pass
            w.conn.close()
        for t in self._threads:
            if t is not threading.current_thread():
                t.join(timeout=2.0)

    # -- task dispatch ---------------------------------------------------------

    def submit(self, key: Any, make_payload: Callable[[], bytes],
               on_done: Callable[[Any, Any, Optional[BaseException],
                                  Optional[str]], None]
               ) -> RemoteTask:
        """Queue one task; ``on_done(key, value, error, worker_addr)``
        fires exactly once from a receiver thread (or inline here when
        the pool is already dead) — ``worker_addr`` names the worker
        that produced a result, ``None`` on client-side failures."""
        task = RemoteTask(key, make_payload, on_done)
        task._client = self
        with self._lock:
            # a rejoin-enabled pool that is mid-reconnect holds the task
            # (the rejoin loop pumps the queue when a daemon redials);
            # only a pool with no way back fails inline
            healing = self.rejoin and bool(self._rejoining) and not self._closing
            if not any(w.alive for w in self._workers) and not healing:
                task.done = True
                dead = RuntimeError(
                    "no live remote workers (all lost or never connected)")
            else:
                dead = None
                self._queue.append(task)
        if dead is not None:
            on_done(key, None, dead, None)
            return task
        self._pump()
        return task

    def pending_count(self) -> int:
        with self._lock:
            queued = sum(1 for t in self._queue if not t.done)
            running = sum(1 for w in self._workers if w.alive and w.busy is not None)
            return queued + running

    def _cancel(self, task: RemoteTask) -> bool:
        with self._lock:
            if task.done or task.worker is not None:
                return False
            task.cancelled = True
            task.done = True
            try:
                self._queue.remove(task)
            except ValueError:
                pass
            return True

    def _pump(self) -> None:
        """Move queued tasks onto idle live workers.  Runs on whatever
        thread noticed capacity (submit, a completion, a worker loss);
        concurrent pumps are safe — assignment happens under the lock."""
        while True:
            with self._lock:
                worker = next((w for w in self._workers
                               if w.alive and w.busy is None), None)
                if worker is None or not self._queue:
                    return
                task = self._queue.popleft()
                if task.done or task.cancelled:
                    continue
                task.attempts += 1
                task.task_id = uuid.uuid4().hex
                task.worker = worker
                worker.busy = task
                worker.started = _monotonic()
                tid = task.task_id
            try:
                payload = task.make_payload()
            except BaseException as e:
                # the payload itself cannot be built (unpicklable
                # objective, say): permanent, no retry will help
                with self._lock:
                    worker.busy = None
                    task.worker = None
                    task.done = True
                task.on_done(task.key, None, e, None)
                continue
            try:
                worker.conn.send("submit", {"task": tid}, payload)
            except TransportError as e:
                self._worker_lost(worker, f"send failed: {e}")

    # -- receiving -------------------------------------------------------------

    def _recv_loop(self, w: _Worker) -> None:
        poll = 0.2
        while w.alive and not self._closing:
            try:
                msg = w.conn.recv(timeout=poll)
            except TransportError as e:
                if not self._closing:
                    self._worker_lost(w, str(e) or type(e).__name__)
                    self._pump()
                return
            now = _monotonic()
            if msg is None:
                if (self.heartbeat_timeout_s
                        and now - w.last_seen > self.heartbeat_timeout_s):
                    self._worker_lost(
                        w, f"silent for {now - w.last_seen:.1f}s "
                           f"(heartbeat timeout {self.heartbeat_timeout_s}s)")
                    self._pump()
                    return
                if (self.task_timeout_s and w.busy is not None
                        and now - w.started > self.task_timeout_s):
                    self._worker_lost(
                        w, f"straggler: task running past {self.task_timeout_s}s")
                    self._pump()
                    return
                continue
            w.last_seen = now
            if msg.kind == "heartbeat":
                w.tasks_done = int(msg.meta.get("tasks_done", w.tasks_done))
            elif msg.kind == "report":
                if self.on_report is not None:
                    self.on_report(w.addr, msg.meta)
            elif msg.kind == "refresh_ack":
                if self.on_refresh_ack is not None:
                    self.on_refresh_ack(w.addr, msg.meta.get("context"),
                                        int(msg.meta.get("applied", 0)))
            elif msg.kind in ("result", "error"):
                self._finish(w, msg)
                self._pump()
            elif msg.kind == "shutdown":
                # graceful daemon exit (SIGTERM): resubmit its in-flight
                # work *now* instead of waiting out the heartbeat timeout
                self._worker_lost(w, "worker announced shutdown")
                self._pump()
                return
            # "ack" and unknown kinds: liveness signal only

    def _finish(self, w: _Worker, msg) -> None:
        with self._lock:
            task = w.busy
            if task is None or task.task_id != msg.meta.get("task"):
                return  # stale frame from a superseded attempt
            w.busy = None
            task.done = True
        value = error = None
        try:
            obj = pickle.loads(msg.payload)
            if msg.kind == "error":
                error = obj
            else:
                value = obj
        except BaseException as e:
            error = RuntimeError(f"undecodable result from {w.addr}: {e!r}")
        w.tasks_done += 1
        task.on_done(task.key, value, error, w.addr)

    # -- failure handling ------------------------------------------------------

    def _worker_lost(self, w: _Worker, reason: str) -> None:
        """Retire a worker and re-route its in-flight task.  Callers
        follow up with :meth:`_pump`."""
        to_fail: List[Tuple[RemoteTask, BaseException]] = []
        with self._lock:
            if not w.alive:
                return
            w.alive = False
            self._workers.remove(w)
            task = w.busy
            w.busy = None
            any_alive = any(x.alive for x in self._workers)
            if task is not None and not task.done:
                task.worker = None
                task.deaths += 1
                if (self.quarantine_after is not None
                        and task.deaths >= self.quarantine_after):
                    # the common factor across these deaths is the task:
                    # stop feeding it workers.  Checked before pool state
                    # on purpose — a poison task that just took down the
                    # last worker is still a poison task, not a pool
                    # outage
                    task.done = True
                    to_fail.append((task, PoisonTrialError(
                        f"task implicated in {task.deaths} worker death(s) "
                        f"(last: {w.addr}, {reason}); quarantined",
                        deaths=task.deaths)))
                elif task.attempts > self.retries:
                    task.done = True
                    to_fail.append((task, RuntimeError(
                        f"task failed after {task.attempts} attempts; last "
                        f"worker {w.addr} lost ({reason})")))
                elif not any_alive and not self.rejoin:
                    task.done = True
                    to_fail.append((task, RuntimeError(
                        f"worker {w.addr} lost ({reason}) and no live workers "
                        f"remain to resubmit to")))
                else:
                    # a sibling is alive, or rejoin will heal the pool
                    self._queue.appendleft(task)
            if not any_alive and not self.rejoin:
                # total pool loss with no way back: every queued task can
                # only fail (rejoin-enabled pools hold the queue instead
                # and drain it when a daemon redials)
                while self._queue:
                    queued = self._queue.popleft()
                    if queued.done:
                        continue
                    queued.done = True
                    to_fail.append((queued, RuntimeError(
                        f"worker {w.addr} lost ({reason}); no live workers "
                        f"remain")))
        w.conn.close()
        warnings.warn(
            f"remote worker {w.addr} lost ({reason})"
            + ("; resubmitting its in-flight work to a sibling"
               if not to_fail else ""),
            RuntimeWarning, stacklevel=2)
        if self.on_worker_lost is not None:
            self.on_worker_lost(w.addr, reason)
        for task, err in to_fail:
            task.on_done(task.key, None, err, None)
        if self.rejoin and not self._closing:
            self._start_rejoin(w.addr)

    # -- mid-trial pruner refresh ---------------------------------------------

    def push_refresh(self, make_tail: Callable[[str], Optional[RefreshTail]]
                     ) -> None:
        """Ship unacknowledged pruner delta-log tails to workers that are
        *currently running* a trial (throttled per worker), so long
        trials prune against sibling history that postdates their
        submission.  ``make_tail(worker_addr)`` returns ``(context_id,
        base, deltas)`` or ``None`` when that worker is up to date."""
        now = _monotonic()
        with self._lock:
            targets = [w for w in self._workers
                       if w.alive and w.busy is not None
                       and now - w.last_refresh >= self.refresh_min_interval_s]
        for w in targets:
            tail = make_tail(w.addr)
            if tail is None:
                continue
            context_id, base, deltas = tail
            try:
                w.conn.send("pruner_refresh",
                            {"context": context_id, "base": int(base)},
                            pickle.dumps(deltas, protocol=pickle.HIGHEST_PROTOCOL))
                w.last_refresh = now
            except TransportError:
                pass  # the receiver loop will notice and handle the death
