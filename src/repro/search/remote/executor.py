""":class:`RemoteExecutor` — the streaming executor surface over a pool
of worker daemons.

Registry-pluggable (``executor: {backend: remote, workers:
["host:7471", ...]}`` in an experiment YAML just works), and
semantically a sibling of the process backend: every submission ships
the trial number, the sampler's picklable detached plan, and (when the
study has a picklable pruner) a :class:`PrunerContext` slice of the
shared :class:`~repro.search.executors.PrunerDeltaLog`; everything a
worker-side trial accumulates merges back into the parent trial before
``tell``.  Because detached plans re-derive per-trial RNG streams from
``(seed, number)``, a fixed-seed study produces identical trials on the
remote backend as on serial — the property the parity tests and the
bounded-resubmission fault story both rest on.

What this class adds over :class:`RemoteClient` (which owns
connections, dispatch, failure detection, and retries):

* the delta-log bookkeeping — streamed ``report`` frames append to the
  log, worker acks (result-borne and refresh-borne) advance truncation,
  a lost worker's ack entry is dropped so truncation tracks the living;
* **mid-trial pruner refreshes**: after every report and every merged
  completion, unacknowledged log tails are pushed to workers still
  running trials, so a long trial prunes against sibling history that
  did not exist when it was submitted;
* **graceful degradation**: when zero configured workers are reachable
  at ``start()``, the executor warns once and delegates the entire
  surface to a local backend (``fallback``, default ``process``) — a
  cluster outage degrades a run to single-host speed, not to a crash.

Worker configuration precedence: the ``workers`` constructor argument
(what ``executor.workers`` in a spec feeds), else the
``REPRO_REMOTE_WORKERS`` environment list; neither set raises at
``start``.
"""
from __future__ import annotations

import pickle
import warnings
from typing import Any, Callable, List, Optional, Tuple

from repro.envvars import read_env
from repro.explorer.registry import EXECUTORS
from repro.search.executors import (
    BaseExecutor,
    Outcome,
    PrunerDeltaLog,
    WorkerResult,
    make_executor,
    merge_worker_result,
)
from repro.search.remote.client import PoisonTrialError, RemoteClient
from repro.search.trial import Trial, TrialState

WORKERS_ENV = "REPRO_REMOTE_WORKERS"


@EXECUTORS.register("remote")
class RemoteExecutor(BaseExecutor):
    name = "remote"

    def __init__(self, workers: Optional[List[str]] = None,
                 retries: Optional[int] = None,
                 heartbeat_timeout_s: Optional[float] = None,
                 task_timeout_s: Optional[float] = None,
                 connect_timeout_s: float = 5.0,
                 fallback: str = "process",
                 quarantine_after: Optional[int] = None,
                 rejoin: bool = True):
        self.workers = [str(w) for w in workers] if workers else None
        self.retries = retries
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.task_timeout_s = task_timeout_s
        self.connect_timeout_s = connect_timeout_s
        self.fallback = fallback
        # a trial implicated in this many worker deaths is a poison
        # trial: quarantined as FAIL instead of burning the whole pool
        self.quarantine_after = (quarantine_after
                                 if quarantine_after is not None
                                 else read_env("REPRO_QUARANTINE_DEATHS", 2))
        self.rejoin = rejoin
        self._client: Optional[RemoteClient] = None
        self._delegate: Optional[BaseExecutor] = None
        self._delta = PrunerDeltaLog()

    # -- lifecycle -------------------------------------------------------------

    def start(self, n_workers: int) -> None:
        if self._client is not None or self._delegate is not None:
            return
        addrs = self.workers or read_env(WORKERS_ENV, None)
        if not addrs:
            raise ValueError(
                "the remote executor needs a worker pool: pass "
                "workers=['host:port', ...], set executor.workers in the "
                "experiment spec, or export REPRO_REMOTE_WORKERS")
        client = RemoteClient(
            list(addrs),
            retries=self.retries,
            heartbeat_timeout_s=self.heartbeat_timeout_s,
            task_timeout_s=self.task_timeout_s,
            connect_timeout_s=self.connect_timeout_s,
            quarantine_after=self.quarantine_after,
            rejoin=self.rejoin,
            on_report=self._on_report,
            on_refresh_ack=self._on_refresh_ack,
            on_worker_lost=self._on_worker_lost)
        live = client.connect()
        if not live:
            client.close()
            warnings.warn(
                f"no remote workers reachable among {list(addrs)}; degrading "
                f"to local {self.fallback!r} execution for this run",
                RuntimeWarning, stacklevel=2)
            self._delegate = make_executor(self.fallback)
            self._delegate.start(n_workers)
            return
        self._client = client

    def shutdown(self) -> None:
        if self._delegate is not None:
            self._delegate.shutdown()
            self._delegate = None
        if self._client is not None:
            self._client.close()
            self._client = None
        # the daemons outlive us, but their _DELTA_HISTORY context does
        # not match any future study of ours: open fresh next time
        self._delta.clear()

    def warmup(self, fn: Callable[[], Any]) -> None:
        """Run ``fn`` once per live worker (daemons already warm jax at
        startup; this warms *caller* state such as objective globals)."""
        if self._delegate is not None:
            return self._delegate.warmup(fn)
        if self._client is None:
            return
        import threading

        events = []
        payload = pickle.dumps(("call", (fn, (), {})),
                               protocol=pickle.HIGHEST_PROTOCOL)
        for addr in self._client.live_workers():
            ev = threading.Event()
            self._client.submit(addr, lambda payload=payload: payload,
                                lambda *a, ev=ev: ev.set())
            events.append(ev)
        for ev in events:
            ev.wait(timeout=60.0)

    # -- streaming surface -----------------------------------------------------

    def pending_count(self) -> int:
        if self._delegate is not None:
            return self._delegate.pending_count()
        return super().pending_count()

    def next_completed(self) -> Tuple[Trial, Outcome]:
        if self._delegate is not None:
            return self._delegate.next_completed()
        return super().next_completed()

    def cancel_pending(self) -> List[Trial]:
        if self._delegate is not None:
            return self._delegate.cancel_pending()
        return super().cancel_pending()

    def submit(self, study, objective: Callable, trial: Trial, catch: Tuple) -> None:
        if self._delegate is not None:
            return self._delegate.submit(study, objective, trial, catch)
        with study._lock:
            plan = study.sampler.detached(study, trial)
            pruner = getattr(study, "pruner", None)
            use_pruner = pruner is not None and self._delta.pruner_ok(pruner)
            if use_pruner:
                self._delta.reset(study)
        params = dict(trial.params) or None

        def make_payload() -> bytes:
            # built per dispatch *attempt*, so a resubmitted trial
            # carries a pruner snapshot that includes everything learned
            # since the first attempt
            ctx = None
            if use_pruner:
                self._delta.truncate(len(self._client.live_workers()))
                ctx = self._delta.snapshot(pruner, study.directions)
            return pickle.dumps(
                ("trial", {"objective": objective, "number": trial.number,
                           "plan": plan, "catch": tuple(catch), "pruner": ctx,
                           "params": params}),
                protocol=pickle.HIGHEST_PROTOCOL)

        def on_done(key, value, error, worker_addr):
            # receiver thread: hand the merge to the scheduler thread via
            # the stream state, mirroring the process backend's _collect
            self._complete(trial, lambda: self._collect(
                study, trial, value, error, worker_addr))

        task = self._client.submit(trial, make_payload, on_done)
        self._track(trial, task)

    # -- completion + delta-log bookkeeping ------------------------------------

    def _collect(self, study, trial: Trial, value, error, worker_addr) -> Outcome:
        if isinstance(error, PoisonTrialError):
            # the trial itself keeps killing daemons — quarantine it as a
            # FAIL with forensics, and let its siblings finish the study
            self._delta.finalize(trial.number, TrialState.FAIL, None, {})
            warnings.warn(
                f"trial {trial.number} implicated in {error.deaths} worker "
                f"death(s); quarantining", RuntimeWarning, stacklevel=2)
            trial.set_user_attr(
                "quarantined", {"deaths": error.deaths, "error": repr(error)})
            trial.set_user_attr("error", repr(error))
            return (None, TrialState.FAIL)
        if error is not None or not isinstance(value, WorkerResult):
            # worker lost beyond retries, undecodable result, or payload
            # build failure: retract any reports the attempts streamed so
            # later pruner snapshots don't count partial values
            self._delta.finalize(trial.number, TrialState.FAIL, None, {})
            if error is None:
                error = RuntimeError(
                    f"remote worker returned {type(value).__name__}, "
                    f"expected WorkerResult")
            trial.set_user_attr("error", repr(error))
            return error
        res = value
        merge_worker_result(study, trial, res)
        if res.pruner_ack is not None and worker_addr is not None:
            cid, _pid, applied = res.pruner_ack
            self._delta.ack(worker_addr, cid, applied)
        self._delta.finalize(res.number, res.state, res.values, res.intermediate)
        self._push_refresh()
        if res.error is not None:
            return res.error
        return (res.values, res.state)

    def _on_report(self, worker_addr: str, meta) -> None:
        self._delta.add_report(meta.get("number"), meta.get("step"),
                               meta.get("value"))
        self._push_refresh()

    def _on_refresh_ack(self, worker_addr: str, context_id, applied: int) -> None:
        self._delta.ack(worker_addr, context_id, applied)

    def _on_worker_lost(self, worker_addr: str, reason: str) -> None:
        self._delta.drop_worker(worker_addr)

    def _push_refresh(self) -> None:
        """Ship unacked delta-log tails to busy workers (throttled inside
        the client), so running trials see fresh sibling history."""
        client = self._client
        if client is not None and self._delta.context_id is not None:
            client.push_refresh(self._delta.tail_for)
