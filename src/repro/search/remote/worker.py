"""The worker daemon behind ``python -m repro.worker``.

One :class:`WorkerServer` accepts any number of client connections
(each a :class:`~repro.search.remote.executor.RemoteExecutor` or
:class:`~repro.search.remote.client.RemoteClient`), handshakes them
(protocol version + toolchain salt, see
:mod:`repro.search.remote.transport`), and then serves two task kinds:

* ``("trial", {...})`` — a detached-plan trial evaluation: exactly the
  payload the process backend ships to a pool worker (objective,
  trial number, plan, catch tuple, optional
  :class:`~repro.search.detached.PrunerContext`, pre-seeded params),
  executed by the same :func:`~repro.search.executors.run_detached_trial`
  entry point.  Intermediate reports stream back as ``report`` frames
  while the trial runs, so the submitting host's pruner snapshots see
  this worker's progress before the trial finishes; the terminal
  ``result`` frame carries the pickled
  :class:`~repro.search.executors.WorkerResult` (including the pruner
  delta-log ack).
* ``("call", (fn, args, kwargs))`` — a generic picklable call; the
  sweep-cell scheduler uses it to run whole experiment cells.

Control frames: every ``submit`` is acknowledged with an ``ack`` before
execution starts (delivery confirmation for the client's retry logic);
a ``heartbeat`` frame goes out every ``heartbeat_s`` seconds on each
live connection (the client's liveness signal); ``pruner_refresh``
frames fold a delta-log tail into this process's pruning history *while
trials are running* — see :func:`repro.search.detached.apply_pruner_deltas`
— and are answered with ``refresh_ack``; ``cancel`` suppresses the
result of a task that has not finished (execution itself is not
interrupted — objectives are arbitrary code); ``bye`` closes cleanly.

Tasks run on their own threads so the receive loop keeps servicing
refreshes and cancels mid-trial.  Trials from different connections may
therefore run concurrently — operators who want one-trial-at-a-time
workers run one daemon per core, which is also what gives each daemon
its own XLA compiler (the remote analogue of the process pool).
"""
from __future__ import annotations

import argparse
import pickle
import random
import signal
import socket
import threading
import uuid
from typing import Any, Dict, Optional, Set, Tuple

from repro.envvars import read_env
from repro.search.detached import apply_pruner_deltas
from repro.search.executors import _portable_exception, run_detached_trial
from repro.search.remote import transport
from repro.search.remote.transport import Connection, ConnectionClosed, TransportError

HEARTBEAT_ENV = "REPRO_REMOTE_HEARTBEAT_S"
DEFAULT_HEARTBEAT_S = 2.0


class DropConnection(Exception):
    """Raised by a task hook to make the daemon sever the client's
    connection without sending a result — the test seam for
    deterministic worker-death scenarios."""


class _WireReportQueue:
    """Duck-typed report channel for :class:`DetachedTrial`: each
    ``put_nowait((number, step, value))`` becomes a ``report`` frame.
    Send failures propagate to the caller, which already treats report
    streaming as best-effort."""

    def __init__(self, conn: Connection, task_id: str):
        self._conn = conn
        self._task_id = task_id

    def put_nowait(self, item: Tuple[int, int, float]) -> None:
        number, step, value = item
        self._conn.send("report", {"task": self._task_id, "number": int(number),
                                   "step": int(step), "value": float(value)})


class WorkerServer:
    """One listening daemon.  ``start()`` runs the accept loop on a
    background thread (tests embed servers in-process; ``port=0`` binds
    an ephemeral port), ``serve_forever()`` blocks (the CLI path),
    ``stop()`` severs everything.

    ``heartbeat_s=0`` disables heartbeats and ``task_hook`` (called as
    ``hook(task_id, task)`` before execution) may raise
    :class:`DropConnection` — both are failure-injection seams used by
    the fault-tolerance tests."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 heartbeat_s: Optional[float] = None,
                 worker_id: Optional[str] = None,
                 toolchain: Optional[Dict[str, str]] = None,
                 task_hook: Any = None):
        self.host = host
        self.port = int(port)
        if heartbeat_s is None:
            heartbeat_s = read_env(HEARTBEAT_ENV, DEFAULT_HEARTBEAT_S)
        self.heartbeat_s = float(heartbeat_s)
        self.worker_id = worker_id or f"worker-{uuid.uuid4().hex[:8]}"
        self._toolchain = toolchain
        self._task_hook = task_hook
        self._listener: Optional[socket.socket] = None
        self._stopping = threading.Event()
        self._threads: list = []
        self._conns: Set[Connection] = set()
        self._lock = threading.Lock()
        self.tasks_done = 0

    # -- lifecycle -------------------------------------------------------------

    @property
    def address(self) -> str:
        return f"{self.host}:{self.port}"

    def start(self) -> Tuple[str, int]:
        """Bind + listen, accept on a background thread; returns the
        bound (host, port) — with ``port=0`` the OS picks one."""
        listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        listener.bind((self.host, self.port))
        listener.listen(16)
        listener.settimeout(0.25)  # so the accept loop notices stop()
        self.port = listener.getsockname()[1]
        self._listener = listener
        t = threading.Thread(target=self._accept_loop, daemon=True,
                             name=f"repro-worker-accept-{self.port}")
        t.start()
        self._threads.append(t)
        return self.host, self.port

    def serve_forever(self) -> None:
        """CLI entry: start (if needed) and block until stopped."""
        if self._listener is None:
            self.start()
        self._stopping.wait()

    def announce_shutdown(self) -> None:
        """Send a ``shutdown`` frame on every live connection so clients
        resubmit this daemon's in-flight work *immediately* instead of
        waiting out the heartbeat timeout.  Best-effort: a connection
        that cannot take the frame will be noticed the slow way."""
        with self._lock:
            conns = list(self._conns)
        for conn in conns:
            try:
                conn.send("shutdown", {"worker": self.worker_id})
            except TransportError:
                pass

    def stop(self) -> None:
        self._stopping.set()
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
        with self._lock:
            conns = list(self._conns)
        for conn in conns:
            conn.close()
        for t in self._threads:
            if t is not threading.current_thread():
                t.join(timeout=2.0)

    # -- serving ---------------------------------------------------------------

    def _accept_loop(self) -> None:
        while not self._stopping.is_set():
            try:
                sock, _ = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                return  # listener closed by stop()
            conn = Connection(sock)
            with self._lock:
                self._conns.add(conn)
            t = threading.Thread(target=self._serve_client, args=(conn,),
                                 daemon=True, name="repro-worker-client")
            t.start()
            self._threads.append(t)

    def _heartbeat_loop(self, conn: Connection) -> None:
        while not self._stopping.is_set() and not conn.closed:
            # ±20% jitter: a pool of daemons started by one job script
            # would otherwise heartbeat in lockstep and burst the
            # client's receive loops at the same instant
            if self._stopping.wait(self.heartbeat_s * random.uniform(0.8, 1.2)):
                return
            try:
                conn.send("heartbeat", {"worker": self.worker_id,
                                        "tasks_done": self.tasks_done})
            except TransportError:
                return

    def _serve_client(self, conn: Connection) -> None:
        cancelled: Set[str] = set()
        try:
            if not transport.server_hello(conn, self.worker_id,
                                          toolchain=self._toolchain):
                return
            if self.heartbeat_s > 0:
                hb = threading.Thread(target=self._heartbeat_loop, args=(conn,),
                                      daemon=True, name="repro-worker-heartbeat")
                hb.start()
                self._threads.append(hb)
            while not self._stopping.is_set():
                msg = conn.recv(timeout=0.25)
                if msg is None:
                    continue
                if msg.kind == "submit":
                    task_id = str(msg.meta.get("task", ""))
                    conn.send("ack", {"task": task_id})
                    t = threading.Thread(
                        target=self._run_task,
                        args=(conn, task_id, msg.payload, cancelled),
                        daemon=True, name=f"repro-worker-task-{task_id[:8]}")
                    t.start()
                    self._threads.append(t)
                elif msg.kind == "pruner_refresh":
                    applied = apply_pruner_deltas(
                        str(msg.meta.get("context")), int(msg.meta.get("base", 0)),
                        pickle.loads(msg.payload) if msg.payload else [])
                    conn.send("refresh_ack", {"context": msg.meta.get("context"),
                                              "applied": int(applied)})
                elif msg.kind == "cancel":
                    cancelled.add(str(msg.meta.get("task", "")))
                elif msg.kind == "bye":
                    return
                # unknown kinds are ignored: forward compatibility within
                # one protocol version
        except (ConnectionClosed, TransportError):
            pass  # client went away; nothing to tell it
        finally:
            conn.close()
            with self._lock:
                self._conns.discard(conn)

    def _run_task(self, conn: Connection, task_id: str, payload: bytes,
                  cancelled: Set[str]) -> None:
        try:
            kind, task = pickle.loads(payload)
            if self._task_hook is not None:
                self._task_hook(task_id, task)
            if kind == "trial":
                result = run_detached_trial(
                    task["objective"], task["number"], task["plan"],
                    tuple(task.get("catch") or ()),
                    pruner=task.get("pruner"),
                    report_queue=_WireReportQueue(conn, task_id),
                    params=task.get("params"))
            elif kind == "call":
                fn, args, kwargs = task
                result = fn(*args, **(kwargs or {}))
            else:
                raise ValueError(f"unknown task kind {kind!r}")
            body = pickle.dumps(result, protocol=pickle.HIGHEST_PROTOCOL)
            reply = ("result", {"task": task_id})
        except DropConnection:
            conn.close()  # simulate sudden worker death (test seam)
            return
        except (ConnectionClosed, TransportError):
            return  # client went away mid-trial; result has no recipient
        except BaseException as e:
            body = pickle.dumps(_portable_exception(e),
                                protocol=pickle.HIGHEST_PROTOCOL)
            reply = ("error", {"task": task_id})
        self.tasks_done += 1
        if task_id in cancelled:
            return  # the client moved on; a late result would be ignored anyway
        try:
            conn.send(reply[0], reply[1], body)
        except TransportError:
            pass  # connection died after the work: the client's retry logic owns it


def warmup() -> Dict[str, Any]:
    """Pay the one-time heavy costs (jax import, backend init) before
    the first trial arrives, and report what this worker runs on."""
    info: Dict[str, Any] = {}
    try:
        import jax

        info["jax"] = str(getattr(jax, "__version__", "unknown"))
        info["devices"] = [str(d) for d in jax.devices()]
    except Exception as e:  # pragma: no cover — jax is baked into the image
        info["jax"] = f"unavailable ({e})"
    return info


def main(argv: Optional[list] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.worker",
        description="Run a repro evaluation worker daemon.")
    parser.add_argument("--host", default="127.0.0.1",
                        help="interface to bind (default loopback; daemons "
                             "execute arbitrary pickled code — only expose "
                             "them on trusted networks)")
    parser.add_argument("--port", type=int, default=0,
                        help="port to bind (0 = OS-assigned, printed on stdout)")
    parser.add_argument("--cache-dir", default=None,
                        help="redirect every disk evaluation cache this worker "
                             "opens into one store (sets REPRO_CACHE_DIR); "
                             "point same-toolchain workers at one shared "
                             "directory to share compiled values")
    parser.add_argument("--heartbeat", type=float, default=None,
                        help="seconds between heartbeat frames (default "
                             "REPRO_REMOTE_HEARTBEAT_S or 2.0)")
    parser.add_argument("--no-warmup", action="store_true",
                        help="skip the jax import/backend warmup at startup")
    args = parser.parse_args(argv)

    if args.cache_dir:
        import os

        os.environ["REPRO_CACHE_DIR"] = args.cache_dir
    if not args.no_warmup:
        info = warmup()
        print(f"warmed up: jax {info.get('jax')}", flush=True)
    server = WorkerServer(host=args.host, port=args.port,
                          heartbeat_s=args.heartbeat)
    host, port = server.start()
    # the one line launchers parse: the bound address (meaningful with --port 0)
    print(f"listening on {host}:{port}", flush=True)

    def _graceful(signum, frame):  # noqa: ARG001 — signal handler signature
        # Announce before tearing down: the client resubmits this
        # daemon's in-flight trials immediately instead of waiting out
        # the heartbeat timeout.
        print(f"received {signal.Signals(signum).name}, shutting down",
              flush=True)
        server.announce_shutdown()
        server.stop()

    signal.signal(signal.SIGTERM, _graceful)
    signal.signal(signal.SIGINT, _graceful)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        # race: SIGINT delivered between handler install and the
        # interruptible wait inside serve_forever
        server.announce_shutdown()
    finally:
        server.stop()
    return 0
