"""Length-prefixed TCP framing + handshake for the remote backend.

Wire format — one frame per message, two length-prefixed parts::

    uint32 BE header_len | header JSON (utf-8) | uint32 BE payload_len | payload

The header is small JSON — ``{"kind": ..., "meta": {...}}`` — carrying
routing and bookkeeping (task ids, ack counters); the payload is an
opaque byte string, pickled Python for trial submissions and results,
empty for control frames (heartbeats, acks).  JSON for the envelope
keeps control traffic inspectable on the wire; pickle for the body is
what lets detached plans, pruner snapshots, and arbitrary objective
callables cross hosts unchanged.

**Trust model: pickle means code execution.**  A worker daemon
unpickles (and calls) whatever a connected client sends, which is the
entire point — objectives are arbitrary callables — so daemons must
only listen on trusted networks (loopback, a private cluster fabric, an
SSH tunnel).  The handshake is a compatibility check, not
authentication.

Handshake — first frame each way, before anything else:

* client → ``hello`` with ``{"protocol": PROTOCOL_VERSION, "toolchain":
  {...}}`` (the jax/jaxlib versions from
  :func:`repro.evaluation.disk_cache.toolchain_versions` — the same
  salt the disk cache keys by);
* worker → ``hello_ok`` with its worker id, or ``hello_reject`` with a
  reason.  A protocol mismatch means incompatible framing/semantics; a
  toolchain mismatch means the worker would compute latency/memory
  values under a different XLA than the submitting host expects (and
  would poison the shared disk-cache sharing story), so both reject.

Framing integrity vs. timeouts: :meth:`Connection.recv` only times out
*between* frames — once the first length byte of a frame has been read,
the rest is read under a generous fixed cap so a slow sender cannot
leave the stream desynchronized at a partial frame.  Sends take an
internal lock: a worker's heartbeat thread and its trial thread share
one socket.
"""
from __future__ import annotations

import json
import socket
import struct
import threading
import zlib
from typing import Any, Dict, Optional, Tuple

from repro import faults

PROTOCOL_VERSION = 1

# cap on reading the remainder of a frame whose first bytes arrived —
# past this the peer is wedged mid-send and the stream is unrecoverable
FRAME_REMAINDER_TIMEOUT_S = 30.0

# sanity bound on declared lengths: a desynchronized or hostile stream
# must not make us allocate gigabytes from four garbage bytes
MAX_PART_BYTES = 1 << 30

_U32 = struct.Struct(">I")


class TransportError(Exception):
    """The connection is unusable (EOF, reset, corrupt frame)."""


class ConnectionClosed(TransportError):
    """The peer closed the socket (clean EOF between frames)."""


class HandshakeError(TransportError):
    """The peer rejected or botched the hello exchange."""


class Message:
    """One decoded frame."""

    __slots__ = ("kind", "meta", "payload")

    def __init__(self, kind: str, meta: Dict[str, Any], payload: bytes):
        self.kind = kind
        self.meta = meta
        self.payload = payload

    def __repr__(self):  # pragma: no cover — debugging aid
        return f"Message({self.kind!r}, {self.meta!r}, {len(self.payload)}B)"


class Connection:
    """A framed, thread-safe-for-send wrapper over one TCP socket."""

    def __init__(self, sock: socket.socket):
        self._sock = sock
        self._send_lock = threading.Lock()
        self._closed = False
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:  # pragma: no cover — non-TCP test doubles
            pass

    @property
    def closed(self) -> bool:
        return self._closed

    def send(self, kind: str, meta: Optional[Dict[str, Any]] = None,
             payload: bytes = b"") -> None:
        """Write one frame atomically w.r.t. sibling sender threads.  The
        header carries a CRC32 of the payload so a mangled body is
        detected at recv as a :class:`TransportError` (worker-lost path)
        instead of surfacing as an unpickling error deep in a worker."""
        envelope: Dict[str, Any] = {"kind": kind, "meta": meta or {}}
        if payload:
            envelope["crc"] = zlib.crc32(payload)
        try:
            # fault injection models the wire, not the sender: the CRC is
            # computed over the intact payload, so injected corruption is
            # caught by the receiver's checksum
            payload = faults.fault_point("transport.send", payload)
        except faults.InjectedFault as e:
            self._closed = True
            raise TransportError(f"send failed: {e}") from e
        if payload is faults.DROP:
            return  # injected frame loss: the bytes never hit the socket
        header = json.dumps(envelope, separators=(",", ":")).encode("utf-8")
        frame = _U32.pack(len(header)) + header + _U32.pack(len(payload)) + payload
        with self._send_lock:
            if self._closed:
                raise ConnectionClosed("send on closed connection")
            try:
                self._sock.sendall(frame)
            except OSError as e:
                self._closed = True
                raise TransportError(f"send failed: {e}") from e

    def _recv_exact(self, n: int, deadline_error: str) -> bytes:
        buf = bytearray()
        while len(buf) < n:
            try:
                chunk = self._sock.recv(n - len(buf))
            except socket.timeout:
                raise TransportError(deadline_error) from None
            except OSError as e:
                raise TransportError(f"recv failed: {e}") from e
            if not chunk:
                raise ConnectionClosed("peer closed connection")
            buf += chunk
        return bytes(buf)

    def recv(self, timeout: Optional[float] = None) -> Optional[Message]:
        """Read one frame.  ``None`` means the timeout elapsed with no
        frame *started* — safe to call again.  Once a frame begins, the
        remainder is read under :data:`FRAME_REMAINDER_TIMEOUT_S` so a
        timeout can never strand the stream mid-frame."""
        while True:
            msg = self._recv_one(timeout)
            if msg is not None and msg.payload is faults.DROP:
                continue  # injected inbound frame loss: read the next one
            return msg

    def _recv_one(self, timeout: Optional[float]) -> Optional[Message]:
        try:
            self._sock.settimeout(timeout)
            first = self._sock.recv(1)
        except socket.timeout:
            return None
        except OSError as e:
            raise TransportError(f"recv failed: {e}") from e
        if not first:
            raise ConnectionClosed("peer closed connection")
        try:
            self._sock.settimeout(FRAME_REMAINDER_TIMEOUT_S)
        except OSError as e:
            raise TransportError(f"recv failed: {e}") from e
        wedged = "peer stalled mid-frame"
        header_len = _U32.unpack(first + self._recv_exact(3, wedged))[0]
        if header_len > MAX_PART_BYTES:
            raise TransportError(f"implausible header length {header_len}")
        try:
            header = json.loads(self._recv_exact(header_len, wedged).decode("utf-8"))
            kind = header["kind"]
            meta = header.get("meta") or {}
        except (ValueError, KeyError, UnicodeDecodeError) as e:
            raise TransportError(f"corrupt frame header: {e}") from e
        payload_len = _U32.unpack(self._recv_exact(4, wedged))[0]
        if payload_len > MAX_PART_BYTES:
            raise TransportError(f"implausible payload length {payload_len}")
        payload = self._recv_exact(payload_len, wedged) if payload_len else b""
        try:
            payload = faults.fault_point("transport.recv", payload)
        except faults.InjectedFault as e:
            raise TransportError(f"recv failed: {e}") from e
        if payload is faults.DROP:
            return Message(str(kind), meta, payload)  # recv() skips it
        crc = header.get("crc")
        if crc is not None and crc != zlib.crc32(payload):
            raise TransportError(
                f"corrupt frame payload: checksum mismatch on {kind!r}")
        return Message(str(kind), meta, payload)

    def close(self) -> None:
        self._closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass


def parse_addr(addr: str) -> Tuple[str, int]:
    """``"host:port"`` → ``(host, port)`` (the one address syntax the
    spec layer and REPRO_REMOTE_WORKERS accept)."""
    host, _, port = str(addr).rpartition(":")
    if not host or not port.isdigit():
        raise ValueError(f"worker address {addr!r} is not host:port")
    return host, int(port)


def connect(addr: str, timeout: float = 5.0) -> Connection:
    """Open a TCP connection to ``host:port`` (no handshake yet)."""
    host, port = parse_addr(addr)
    sock = socket.create_connection((host, port), timeout=timeout)
    return Connection(sock)


def local_toolchain() -> Dict[str, str]:
    """The jax/jaxlib salt both handshake sides compare — identical to
    the disk cache's key salt, so two hosts that shake hands also agree
    on cache-entry compatibility."""
    from repro.evaluation.disk_cache import toolchain_versions

    return toolchain_versions()


def client_hello(conn: Connection, timeout: float = 5.0,
                 hello_meta: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Run the client side of the handshake; returns the worker's
    ``hello_ok`` meta (worker id etc.).  Raises :class:`HandshakeError`
    on rejection.  ``hello_meta`` overrides outgoing fields (tests use
    it to provoke rejections)."""
    meta = {"protocol": PROTOCOL_VERSION, "toolchain": local_toolchain()}
    meta.update(hello_meta or {})
    conn.send("hello", meta)
    reply = conn.recv(timeout=timeout)
    if reply is None:
        raise HandshakeError("worker did not answer the hello in time")
    if reply.kind == "hello_reject":
        raise HandshakeError(str(reply.meta.get("reason", "rejected")))
    if reply.kind != "hello_ok":
        raise HandshakeError(f"unexpected handshake reply {reply.kind!r}")
    return reply.meta


def server_hello(conn: Connection, worker_id: str, timeout: float = 5.0,
                 toolchain: Optional[Dict[str, str]] = None) -> bool:
    """Run the worker side of the handshake; returns True when the
    client is accepted.  ``toolchain`` overrides the local salt (tests
    use it to provoke mismatches)."""
    msg = conn.recv(timeout=timeout)
    if msg is None or msg.kind != "hello":
        conn.send("hello_reject", {"reason": "expected hello frame first"})
        return False
    mine = toolchain if toolchain is not None else local_toolchain()
    theirs = msg.meta.get("toolchain")
    if msg.meta.get("protocol") != PROTOCOL_VERSION:
        conn.send("hello_reject", {
            "reason": (f"protocol mismatch: client {msg.meta.get('protocol')!r}, "
                       f"worker {PROTOCOL_VERSION!r}")})
        return False
    if theirs != mine:
        conn.send("hello_reject", {
            "reason": (f"toolchain mismatch: client {theirs!r}, worker {mine!r} "
                       f"— compiled values would not be comparable")})
        return False
    conn.send("hello_ok", {"worker": worker_id, "protocol": PROTOCOL_VERSION})
    return True
