"""Distributed execution: the layer between "one host" and the XLA call.

* :mod:`~repro.search.remote.transport` — length-prefixed JSON/pickle
  TCP framing, handshake (protocol version + toolchain salt);
* :mod:`~repro.search.remote.worker` — the daemon behind
  ``python -m repro.worker``: executes detached-plan trials and generic
  calls, streams pruner reports, heartbeats, applies mid-trial pruner
  refreshes;
* :mod:`~repro.search.remote.client` — :class:`RemoteClient`, the
  connection pool with failure detection and bounded resubmission;
* :mod:`~repro.search.remote.executor` — :class:`RemoteExecutor`, the
  registry-pluggable streaming executor (``executor: remote``), with
  graceful degradation to local execution.

Kept import-light: the registry's ``ensure_builtins`` imports the
executor module; everything else loads on demand.
"""
