"""Study: the optimization loop driver (+ resumable JSONL storage).

Mirrors Optuna's surface used by the paper: ``optimize(objective,
n_trials)``, multi-objective ``directions``, ``best_trial`` /
``best_trials`` (Pareto), ask/tell, pruning via exceptions, and a
crash-tolerant append-only storage so pod-scale NAS runs resume after
preemption (the framework's fault-tolerance story applies to the search
layer too, not just training).
"""
from __future__ import annotations

import json
import os
import threading
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro import faults
from repro.ioutils import lock_file, locked_append, unlock_file
from repro.search.samplers import BaseSampler, RandomSampler, pareto_front
from repro.search.trial import Distribution, Trial, TrialState


class TrialPruned(Exception):
    pass


class HardConstraintViolated(Exception):
    def __init__(self, name: str, value: float, limit: float,
                 direction: str = "minimize"):
        op = ">" if direction == "minimize" else "<"
        super().__init__(f"hard constraint '{name}' violated: {value} {op} {limit}")
        self.name, self.value, self.limit = name, value, limit
        self.direction = direction


def evaluate_trial(objective: Callable[[Trial], object], trial,
                   catch: Tuple) -> Tuple[Optional[object], TrialState]:
    """One objective call -> (values, state); control-flow exceptions map
    to trial states, anything else propagates to the caller.  The single
    source of this mapping: the serial Study loop and every executor
    backend (``repro.search.executors``) go through it, so they cannot
    drift."""
    try:
        return objective(trial), TrialState.COMPLETE
    except TrialPruned:
        return None, TrialState.PRUNED
    except HardConstraintViolated as e:
        trial.set_user_attr("violated", {"name": e.name, "value": e.value, "limit": e.limit})
        return None, TrialState.INFEASIBLE
    except catch as e:  # noqa: B030 — user-supplied exception classes
        trial.set_user_attr("error", repr(e))
        return None, TrialState.FAIL


class Study:
    def __init__(
        self,
        name: str = "study",
        sampler: Optional[BaseSampler] = None,
        pruner=None,
        directions: Sequence[str] = ("minimize",),
        storage: Optional[str] = None,
    ):
        for d in directions:
            assert d in ("minimize", "maximize"), d
        self.name = name
        self.sampler = sampler or RandomSampler()
        self.pruner = pruner
        self.directions = tuple(directions)
        self.storage = storage
        self.trials: List[Trial] = []
        self.distribution_registry: Dict[str, Distribution] = {}
        self._lock = threading.RLock()  # guards trials + registry + storage
        self._repair_to: Optional[int] = None  # byte offset of torn tail, if any
        if storage and os.path.exists(storage):
            self._load(storage)

    # -- persistence ----------------------------------------------------------

    def _load(self, path: str) -> None:
        # A crash mid-append (power loss, SIGKILL inside locked_append)
        # leaves a torn final record: truncated JSON, usually without its
        # newline.  That must never make the study unresumable — parse
        # what's intact, warn about the rest, and remember the byte
        # offset of the tail so the next persist truncates it away
        # (otherwise the append would concatenate onto the torn bytes
        # and corrupt the *next* record too).
        with open(path, "rb") as f:
            data = f.read()
        pos = 0
        intact_end = 0
        bad = 0
        for chunk in data.splitlines(keepends=True):
            start, pos = pos, pos + len(chunk)
            line = chunk.strip()
            if not line:
                intact_end = pos
                continue
            try:
                if not chunk.endswith(b"\n"):
                    raise ValueError("no trailing newline")
                rec = json.loads(line)
                trial_raw = rec["trial"] if rec.get("kind") == "trial" else None
                t = Trial.from_dict(trial_raw, self) if trial_raw else None
            except (ValueError, KeyError, TypeError):
                bad += 1
                continue
            intact_end = pos
            if t is not None:
                existing = {x.number: i for i, x in enumerate(self.trials)}
                if t.number in existing:
                    self.trials[existing[t.number]] = t
                else:
                    self.trials.append(t)
        if bad:
            torn_tail = intact_end < len(data)
            warnings.warn(
                f"study storage {path!r}: skipped {bad} unreadable "
                f"record(s) (torn write or corruption); resuming from "
                f"{len(self.trials)} intact trial(s)"
                + (" and repairing the torn tail on next persist"
                   if torn_tail else ""),
                RuntimeWarning, stacklevel=2)
            if torn_tail:
                self._repair_to = intact_end
        # Rebuild the distribution registry from the persisted trials so
        # grid-position bookkeeping (GridSampler's mixed-radix sweep)
        # continues where the crashed run stopped instead of restarting.
        for t in self.trials:
            for name, dist in t.distributions.items():
                self.distribution_registry.setdefault(name, dist)

    def _persist(self, trial: Trial) -> None:
        if not self.storage:
            return
        os.makedirs(os.path.dirname(self.storage) or ".", exist_ok=True)
        line = json.dumps({"kind": "trial", "trial": trial.to_dict()}) + "\n"
        line = faults.fault_point("study.persist", line)
        if line is faults.DROP:
            return
        if self._repair_to is not None:
            # Truncate the torn tail _load found before appending over
            # it.  Only the study-owning process appends to its storage
            # (executors tell in the parent), so truncating under the
            # file lock cannot drop a sibling's record.
            offset, self._repair_to = self._repair_to, None
            with open(self.storage, "r+b") as f:
                how = lock_file(f, self.storage)
                try:
                    f.truncate(offset)
                    f.seek(0, os.SEEK_END)
                    f.write(line.encode())
                    f.flush()
                    os.fsync(f.fileno())
                finally:
                    unlock_file(f, how)
            return
        # Lock-safe append: serialized against sibling threads by the study
        # lock (callers hold it) and against other processes sharing the
        # storage file by the flock inside locked_append.
        locked_append(self.storage, line)

    # -- ask / tell -------------------------------------------------------------

    def ask(self) -> Trial:
        with self._lock:
            trial = Trial(len(self.trials), self)
            self.trials.append(trial)
            self.sampler.on_trial_start(self, trial)
            return trial

    def tell(self, trial: Trial, values, state: TrialState = TrialState.COMPLETE) -> None:
        # The whole transition happens under the study lock: concurrent
        # best_trial / completed_trials readers must never observe a trial
        # whose state says COMPLETE while values is still being written
        # (or vice versa), and storage must get exactly one final record.
        with self._lock:
            if trial.state != TrialState.RUNNING:
                raise RuntimeError(
                    f"trial {trial.number} was already told "
                    f"(state={trial.state.value}); telling it again would "
                    "append a duplicate record to storage"
                )
            if values is not None:
                if isinstance(values, (int, float)):
                    values = (float(values),)
                trial.values = tuple(float(v) for v in values)
            trial.state = state
            self._persist(trial)

    # -- optimize ---------------------------------------------------------------

    def optimize(self, objective: Callable[[Trial], object], n_trials: int,
                 catch: Tuple = ()) -> None:
        for _ in range(n_trials):
            trial = self.ask()
            values, state = evaluate_trial(objective, trial, catch)
            self.tell(trial, values, state)

    # -- results ---------------------------------------------------------------

    @property
    def completed_trials(self) -> List[Trial]:
        with self._lock:
            return [t for t in self.trials if t.state == TrialState.COMPLETE and t.values]

    @property
    def best_trial(self) -> Optional[Trial]:
        done = self.completed_trials
        if not done:
            return None
        sign = 1.0 if self.directions[0] == "minimize" else -1.0
        return min(done, key=lambda t: sign * t.values[0])

    @property
    def best_trials(self) -> List[Trial]:
        """Pareto-optimal set under all directions."""
        with self._lock:
            return pareto_front(self.trials, self.directions)
