"""Study: the optimization loop driver (+ resumable JSONL storage).

Mirrors Optuna's surface used by the paper: ``optimize(objective,
n_trials)``, multi-objective ``directions``, ``best_trial`` /
``best_trials`` (Pareto), ask/tell, pruning via exceptions, and a
crash-tolerant append-only storage so pod-scale NAS runs resume after
preemption (the framework's fault-tolerance story applies to the search
layer too, not just training).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.ioutils import locked_append
from repro.search.samplers import BaseSampler, RandomSampler, pareto_front
from repro.search.trial import Distribution, Trial, TrialState


class TrialPruned(Exception):
    pass


class HardConstraintViolated(Exception):
    def __init__(self, name: str, value: float, limit: float,
                 direction: str = "minimize"):
        op = ">" if direction == "minimize" else "<"
        super().__init__(f"hard constraint '{name}' violated: {value} {op} {limit}")
        self.name, self.value, self.limit = name, value, limit
        self.direction = direction


def evaluate_trial(objective: Callable[[Trial], object], trial,
                   catch: Tuple) -> Tuple[Optional[object], TrialState]:
    """One objective call -> (values, state); control-flow exceptions map
    to trial states, anything else propagates to the caller.  The single
    source of this mapping: the serial Study loop and every executor
    backend (``repro.search.executors``) go through it, so they cannot
    drift."""
    try:
        return objective(trial), TrialState.COMPLETE
    except TrialPruned:
        return None, TrialState.PRUNED
    except HardConstraintViolated as e:
        trial.set_user_attr("violated", {"name": e.name, "value": e.value, "limit": e.limit})
        return None, TrialState.INFEASIBLE
    except catch as e:  # noqa: B030 — user-supplied exception classes
        trial.set_user_attr("error", repr(e))
        return None, TrialState.FAIL


class Study:
    def __init__(
        self,
        name: str = "study",
        sampler: Optional[BaseSampler] = None,
        pruner=None,
        directions: Sequence[str] = ("minimize",),
        storage: Optional[str] = None,
    ):
        for d in directions:
            assert d in ("minimize", "maximize"), d
        self.name = name
        self.sampler = sampler or RandomSampler()
        self.pruner = pruner
        self.directions = tuple(directions)
        self.storage = storage
        self.trials: List[Trial] = []
        self.distribution_registry: Dict[str, Distribution] = {}
        self._lock = threading.RLock()  # guards trials + registry + storage
        if storage and os.path.exists(storage):
            self._load(storage)

    # -- persistence ----------------------------------------------------------

    def _load(self, path: str) -> None:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                rec = json.loads(line)
                if rec.get("kind") == "trial":
                    t = Trial.from_dict(rec["trial"], self)
                    existing = {x.number: i for i, x in enumerate(self.trials)}
                    if t.number in existing:
                        self.trials[existing[t.number]] = t
                    else:
                        self.trials.append(t)
        # Rebuild the distribution registry from the persisted trials so
        # grid-position bookkeeping (GridSampler's mixed-radix sweep)
        # continues where the crashed run stopped instead of restarting.
        for t in self.trials:
            for name, dist in t.distributions.items():
                self.distribution_registry.setdefault(name, dist)

    def _persist(self, trial: Trial) -> None:
        if not self.storage:
            return
        os.makedirs(os.path.dirname(self.storage) or ".", exist_ok=True)
        # Lock-safe append: serialized against sibling threads by the study
        # lock (callers hold it) and against other processes sharing the
        # storage file by the flock inside locked_append.
        locked_append(self.storage,
                      json.dumps({"kind": "trial", "trial": trial.to_dict()}) + "\n")

    # -- ask / tell -------------------------------------------------------------

    def ask(self) -> Trial:
        with self._lock:
            trial = Trial(len(self.trials), self)
            self.trials.append(trial)
            self.sampler.on_trial_start(self, trial)
            return trial

    def tell(self, trial: Trial, values, state: TrialState = TrialState.COMPLETE) -> None:
        # The whole transition happens under the study lock: concurrent
        # best_trial / completed_trials readers must never observe a trial
        # whose state says COMPLETE while values is still being written
        # (or vice versa), and storage must get exactly one final record.
        with self._lock:
            if trial.state != TrialState.RUNNING:
                raise RuntimeError(
                    f"trial {trial.number} was already told "
                    f"(state={trial.state.value}); telling it again would "
                    "append a duplicate record to storage"
                )
            if values is not None:
                if isinstance(values, (int, float)):
                    values = (float(values),)
                trial.values = tuple(float(v) for v in values)
            trial.state = state
            self._persist(trial)

    # -- optimize ---------------------------------------------------------------

    def optimize(self, objective: Callable[[Trial], object], n_trials: int,
                 catch: Tuple = ()) -> None:
        for _ in range(n_trials):
            trial = self.ask()
            values, state = evaluate_trial(objective, trial, catch)
            self.tell(trial, values, state)

    # -- results ---------------------------------------------------------------

    @property
    def completed_trials(self) -> List[Trial]:
        with self._lock:
            return [t for t in self.trials if t.state == TrialState.COMPLETE and t.values]

    @property
    def best_trial(self) -> Optional[Trial]:
        done = self.completed_trials
        if not done:
            return None
        sign = 1.0 if self.directions[0] == "minimize" else -1.0
        return min(done, key=lambda t: sign * t.values[0])

    @property
    def best_trials(self) -> List[Trial]:
        """Pareto-optimal set under all directions."""
        with self._lock:
            return pareto_front(self.trials, self.directions)
