"""ParallelStudy: batch-synchronous concurrent trial evaluation.

Hardware-in-the-loop NAS is embarrassingly parallel across candidates —
each objective call is dominated by XLA compilation and benchmark I/O —
yet the base :class:`Study` evaluates strictly serially.
``ParallelStudy`` keeps the exact ask/tell surface and storage format
but overlaps objective evaluation on a pluggable executor backend
(:mod:`repro.search.executors`):

  * trials are **batch-asked** serially under the study lock (sampler
    ``on_trial_start`` hooks — population snapshots, grid bookkeeping —
    never run concurrently);
  * objectives run on the executor — in-thread (``serial``), on a thread
    pool (``thread``), or in worker processes (``process``) — drawing
    suggestions from per-trial RNG streams (``BaseSampler.trial_rng``,
    re-derived inside process workers from the same ``(seed, number)``
    key), so the sampled parameters for trial *n* are identical no
    matter which backend runs it, how many workers run, or how their
    suggestions interleave;
  * results are **told in trial order** once the batch completes, so the
    JSONL storage and the pruner/population state evolve exactly as a
    serial run with the same batch boundaries would.

Backend choice: ``thread`` (default) when the objective blocks without
holding the GIL (wall-clock benchmarking, remote devices) or when you
need intermediate-value pruning; ``process`` when the objective is
compile-bound — each worker process owns its own XLA compiler, which is
the only way to get real compile concurrency (the in-process admission
gate serializes sibling threads).  ``process`` requires a picklable
objective and disables worker-side pruning.

Determinism: with a stateless sampler (Random/Grid) and a deterministic
objective, every backend and every ``n_workers`` produce identical trial
parameters and identical best values.  The first trial runs
synchronously so GridSampler's distribution registry is complete before
workers fan out (spaces whose parameter set varies per trial — deeply
conditional DSL spaces — can still register parameters late, in which
case Grid's sweep order is best-effort, exactly as in a resumed serial
study).  Population-based samplers (TPE/evolution/NSGA-II) see
population snapshots at batch granularity, so their trajectory depends
on ``n_workers`` (like any batched ask/tell optimizer) but is
reproducible for a fixed ``n_workers`` and seed — and identical between
the thread and process backends, whose snapshots are taken at the same
batch boundaries.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple, Union

from repro.search.executors import BaseExecutor, evaluate_trial, make_executor
from repro.search.study import Study
from repro.search.trial import Trial, TrialState


class ParallelStudy(Study):
    """A Study whose ``optimize`` evaluates objectives concurrently."""

    def __init__(self, *args, n_workers: int = 4,
                 backend: Union[str, BaseExecutor] = "thread", **kwargs):
        super().__init__(*args, **kwargs)
        self.default_n_workers = max(1, int(n_workers))
        self.default_backend = backend

    def optimize(self, objective: Callable[[Trial], object], n_trials: int,
                 n_workers: Optional[int] = None, catch: Tuple = (),
                 backend: Optional[Union[str, BaseExecutor]] = None) -> None:
        workers = max(1, int(n_workers if n_workers is not None else self.default_n_workers))
        executor = make_executor(backend if backend is not None else self.default_backend)
        remaining = int(n_trials)

        # Evaluate the first trial synchronously: it registers the space's
        # distributions (GridSampler's mixed-radix bookkeeping) and warms
        # shared caches before workers fan out, so concurrent trials in
        # the first real batch see a complete registry regardless of
        # scheduling order.
        if remaining > 0 and not self.trials:
            trial = self.ask()
            values, state = evaluate_trial(objective, trial, catch)
            self.tell(trial, values, state)
            remaining -= 1

        if remaining <= 0:
            return
        executor.start(workers)
        try:
            while remaining > 0:
                batch = [self.ask() for _ in range(min(workers, remaining))]
                # The executor drains the whole batch before surfacing any
                # uncaught objective exception: the sibling evaluations
                # already ran, so their results must be told (and
                # persisted) rather than silently discarded, leaving
                # trials stranded as RUNNING.
                outcomes = executor.run_batch(self, objective, batch, catch)
                # tell in trial order — outcomes are ordered like the
                # batch, so storage appends and sampler population updates
                # are deterministic even when evaluations finish out of
                # order
                error: Optional[BaseException] = None
                for trial, outcome in zip(batch, outcomes):
                    if isinstance(outcome, BaseException):
                        error = error or outcome
                        trial.set_user_attr("error", repr(outcome))
                        self.tell(trial, None, TrialState.FAIL)
                    else:
                        values, state = outcome
                        self.tell(trial, values, state)
                if error is not None:
                    raise error
                remaining -= len(batch)
        finally:
            executor.shutdown()
