"""ParallelStudy: concurrent trial evaluation — sliding-window or batch.

Hardware-in-the-loop NAS is embarrassingly parallel across candidates —
each objective call is dominated by XLA compilation and benchmark I/O —
and that cost is highly *skewed*: one architecture compiles in 100 ms,
its batch sibling in 10 s.  ``ParallelStudy`` keeps the exact ask/tell
surface and storage format of :class:`Study` but overlaps objective
evaluation on a pluggable executor backend
(:mod:`repro.search.executors`) under one of two schedulers:

``schedule="sliding_window"`` (the fast path)
    Completion-driven: a new trial is asked the moment a slot frees and
    results are told as evaluations finish — no barrier, so workers
    never idle behind a straggler.  ``tell_order`` controls the tell
    stream:

      * ``"trial"`` (default) — a small reorder buffer defers each tell
        until every earlier trial has finished, so the JSONL storage and
        the study's completed-set evolve in exactly trial order (what
        the batch scheduler and a serial study produce);
      * ``"completion"`` — tell immediately.  Fastest and freshest (the
        pruner/history view lags nothing), at the price of a
        run-dependent storage order.  ``study.trials`` stays in trial
        order either way, and with a stateless sampler the sampled
        parameters and values are identical under both.

    ``window`` bounds in-flight submissions (default: ``n_workers``); a
    larger window keeps pool queues fed at the cost of asking further
    ahead of the tells.

``schedule="batch"`` (the legacy scheduler)
    Trials are asked ``n_workers`` at a time and every batch waits on
    its slowest member before any new trial is asked.  Population-based
    samplers see population snapshots at deterministic batch boundaries,
    so their trajectory is reproducible for a fixed ``n_workers`` and
    seed on every backend.

``schedule="auto"`` (the default) picks per sampler:
``sliding_window`` when the sampler declares itself
``order_independent`` (Random, Grid — suggestions derive from per-trial
RNG streams / the trial number alone, so a fixed seed yields identical
trials under either scheduler, any backend, any worker count), and
``batch`` for history-consulting samplers (TPE/evolution/NSGA-II),
whose sliding-window trajectory would depend on completion timing.

Determinism: with a stateless sampler (Random/Grid) and a deterministic
objective, every scheduler, backend and ``n_workers`` produce identical
trial parameters and identical best values.  The first trial of an
empty study runs synchronously so GridSampler's distribution registry
is complete before workers fan out (spaces whose parameter set varies
per trial — deeply conditional DSL spaces — can still register
parameters late, in which case Grid's sweep order is best-effort,
exactly as in a resumed serial study).

Timeouts: ``optimize(..., timeout_s=...)`` enforces the budget
per-submission under the sliding window (no new trial is submitted past
the deadline; in-flight ones drain) and per-batch under the batch
scheduler.

Error path: an uncaught objective exception stops new submissions,
**cancels** queued-but-not-started submissions (told FAIL with the
cancellation recorded in ``user_attrs["cancelled"]``), drains the
already-running evaluations (their results are told and persisted), and
then re-raises — no trial is ever left RUNNING.

Backend choice: ``thread`` (default) when the objective blocks without
holding the GIL (wall-clock benchmarking, remote devices); ``process``
when the objective is compile-bound — each worker process owns its own
XLA compiler, which is the only way to get real compile concurrency
(the in-process admission gate serializes sibling threads).
``process`` requires a picklable objective; with a picklable pruner it
prunes *worker-side* from submit-time snapshots (see
:mod:`repro.search.detached`).
"""
from __future__ import annotations

import time
from typing import Callable, Optional, Tuple, Union

from repro.search.executors import BaseExecutor, evaluate_trial, make_executor
from repro.search.study import Study
from repro.search.trial import Trial, TrialState

SCHEDULE_MODES = ("auto", "batch", "sliding_window")
TELL_ORDERS = ("trial", "completion")

# Clock used for timeout enforcement; module-level so tests can stub it.
_monotonic = time.monotonic


def _check_choice(value: str, allowed: Tuple[str, ...], what: str) -> str:
    if value not in allowed:
        raise ValueError(f"unknown {what} {value!r}; expected one of {allowed}")
    return value


class ParallelStudy(Study):
    """A Study whose ``optimize`` evaluates objectives concurrently."""

    def __init__(self, *args, n_workers: int = 4,
                 backend: Union[str, BaseExecutor] = "thread",
                 schedule: str = "auto", tell_order: str = "trial",
                 window: Optional[int] = None, **kwargs):
        super().__init__(*args, **kwargs)
        self.default_n_workers = max(1, int(n_workers))
        self.default_backend = backend
        self.default_schedule = _check_choice(schedule, SCHEDULE_MODES, "schedule")
        self.default_tell_order = _check_choice(tell_order, TELL_ORDERS, "tell_order")
        self.default_window = None if window is None else max(1, int(window))

    # -- scheduling helpers ----------------------------------------------------

    def _resolve_schedule(self, schedule: Optional[str]) -> str:
        mode = _check_choice(schedule if schedule is not None else self.default_schedule,
                             SCHEDULE_MODES, "schedule")
        if mode == "auto":
            return ("sliding_window"
                    if getattr(self.sampler, "order_independent", False) else "batch")
        return mode

    def _tell_outcome(self, trial: Trial, outcome) -> None:
        if isinstance(outcome, BaseException):
            trial.set_user_attr("error", repr(outcome))
            self.tell(trial, None, TrialState.FAIL)
        else:
            values, state = outcome
            self.tell(trial, values, state)

    # -- optimize --------------------------------------------------------------

    def optimize(self, objective: Callable[[Trial], object], n_trials: int,
                 n_workers: Optional[int] = None, catch: Tuple = (),
                 backend: Optional[Union[str, BaseExecutor]] = None,
                 schedule: Optional[str] = None,
                 tell_order: Optional[str] = None,
                 window: Optional[int] = None,
                 timeout_s: Optional[float] = None) -> None:
        workers = max(1, int(n_workers if n_workers is not None else self.default_n_workers))
        executor = make_executor(backend if backend is not None else self.default_backend)
        mode = self._resolve_schedule(schedule)
        order = _check_choice(tell_order if tell_order is not None else self.default_tell_order,
                              TELL_ORDERS, "tell_order")
        win = window if window is not None else self.default_window
        win = max(1, int(win)) if win is not None else workers
        deadline = None if timeout_s is None else _monotonic() + float(timeout_s)
        remaining = int(n_trials)

        # Evaluate the first trial synchronously: it registers the space's
        # distributions (GridSampler's mixed-radix bookkeeping) and warms
        # shared caches before workers fan out, so concurrent trials see a
        # complete registry regardless of scheduling order.
        if remaining > 0 and not self.trials:
            trial = self.ask()
            values, state = evaluate_trial(objective, trial, catch)
            self.tell(trial, values, state)
            remaining -= 1

        if remaining <= 0 or (deadline is not None and _monotonic() >= deadline):
            return
        executor.start(workers)
        try:
            if mode == "batch":
                self._optimize_batch(objective, remaining, workers, catch,
                                     executor, deadline)
            else:
                self._optimize_sliding(objective, remaining, catch, executor,
                                       order, win, deadline)
        finally:
            executor.shutdown()

    # -- batch scheduler (legacy) ----------------------------------------------

    def _optimize_batch(self, objective, remaining, workers, catch, executor,
                        deadline) -> None:
        while remaining > 0:
            if deadline is not None and _monotonic() >= deadline:
                return
            batch = [self.ask() for _ in range(min(workers, remaining))]
            # The executor drains the whole batch before surfacing any
            # uncaught objective exception: the sibling evaluations
            # already ran, so their results must be told (and persisted)
            # rather than silently discarded, leaving trials stranded as
            # RUNNING.
            outcomes = executor.run_batch(self, objective, batch, catch)
            # tell in trial order — outcomes are ordered like the batch,
            # so storage appends and sampler population updates are
            # deterministic even when evaluations finish out of order
            error: Optional[BaseException] = None
            for trial, outcome in zip(batch, outcomes):
                if isinstance(outcome, BaseException):
                    error = error or outcome
                self._tell_outcome(trial, outcome)
            if error is not None:
                raise error
            remaining -= len(batch)

    # -- sliding-window scheduler ----------------------------------------------

    def _optimize_sliding(self, objective, remaining, catch, executor,
                          tell_order, window, deadline) -> None:
        pending_tells = {}  # number -> (trial, outcome), tell_order="trial" only
        tell_cursor: Optional[int] = None  # next trial number owed a tell
        error: Optional[BaseException] = None
        stop_submitting = False

        def flush_tells():
            nonlocal tell_cursor
            while tell_cursor in pending_tells:
                trial, outcome = pending_tells.pop(tell_cursor)
                self._tell_outcome(trial, outcome)
                tell_cursor += 1

        def handle(trial, outcome):
            nonlocal error
            if isinstance(outcome, BaseException):
                error = error or outcome
            if tell_order == "trial":
                pending_tells[trial.number] = (trial, outcome)
                flush_tells()
            else:
                self._tell_outcome(trial, outcome)

        while True:
            # fill the window — the deadline is checked before EVERY
            # submission, so a timeout can never overshoot by a batch
            while (error is None and not stop_submitting and remaining > 0
                   and executor.pending_count() < window):
                if deadline is not None and _monotonic() >= deadline:
                    stop_submitting = True
                    break
                trial = self.ask()
                if tell_cursor is None:
                    tell_cursor = trial.number
                executor.submit(self, objective, trial, catch)
                remaining -= 1
            if executor.pending_count() == 0:
                break
            trial, outcome = executor.next_completed()
            handle(trial, outcome)
            if error is not None:
                # pull back whatever hasn't started; running trials keep
                # draining through next_completed above
                for cancelled in executor.cancel_pending():
                    cancelled.set_user_attr(
                        "cancelled",
                        f"submission cancelled: trial {trial.number} raised "
                        f"{type(error).__name__}")
                    handle(cancelled, (None, TrialState.FAIL))
        # every submission completed or was cancelled, so with
        # tell_order="trial" the buffer has flushed; sweep defensively in
        # number order in case a gap ever slipped through
        for number in sorted(pending_tells):
            trial, outcome = pending_tells.pop(number)
            self._tell_outcome(trial, outcome)
        if error is not None:
            raise error
