"""ParallelStudy: batch-synchronous concurrent trial evaluation.

Hardware-in-the-loop NAS is embarrassingly parallel across candidates —
each objective call is dominated by XLA compilation and benchmark I/O,
both of which release the GIL — yet the base :class:`Study` evaluates
strictly serially.  ``ParallelStudy`` keeps the exact ask/tell surface
and storage format but overlaps objective evaluation with a thread pool:

  * trials are **batch-asked** serially under the study lock (sampler
    ``on_trial_start`` hooks — population snapshots, grid bookkeeping —
    never run concurrently);
  * objectives run concurrently on the pool, drawing suggestions from
    per-trial RNG streams (``BaseSampler.trial_rng``), so the sampled
    parameters for trial *n* are identical no matter how many workers
    run or how their suggestions interleave;
  * results are **told in trial order** once the batch completes, so the
    JSONL storage and the pruner/population state evolve exactly as a
    serial run with the same batch boundaries would.

Determinism: with a stateless sampler (Random/Grid) and a deterministic
objective, ``n_workers=1`` and ``n_workers=k`` produce identical trial
parameters and identical best values.  The first trial runs
synchronously so GridSampler's distribution registry is complete before
workers fan out (spaces whose parameter set varies per trial — deeply
conditional DSL spaces — can still register parameters late, in which
case Grid's sweep order is best-effort, exactly as in a resumed serial
study).  Population-based samplers (TPE/evolution/NSGA-II) see
population snapshots at batch granularity, so their trajectory depends
on ``n_workers`` (like any batched ask/tell optimizer) but is
reproducible for a fixed ``n_workers`` and seed.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Optional, Tuple

from repro.search.study import HardConstraintViolated, Study, TrialPruned
from repro.search.trial import Trial, TrialState


class ParallelStudy(Study):
    """A Study whose ``optimize`` evaluates objectives concurrently."""

    def __init__(self, *args, n_workers: int = 4, **kwargs):
        super().__init__(*args, **kwargs)
        self.default_n_workers = max(1, int(n_workers))

    # one objective call -> (values, state, user_attr updates are on the trial)
    def _evaluate_one(self, objective: Callable[[Trial], object], trial: Trial,
                      catch: Tuple) -> Tuple[Optional[object], TrialState]:
        try:
            return objective(trial), TrialState.COMPLETE
        except TrialPruned:
            return None, TrialState.PRUNED
        except HardConstraintViolated as e:
            trial.set_user_attr("violated", {"name": e.name, "value": e.value, "limit": e.limit})
            return None, TrialState.INFEASIBLE
        except catch as e:  # noqa: B030 — user-supplied exception classes
            trial.set_user_attr("error", repr(e))
            return None, TrialState.FAIL

    def optimize(self, objective: Callable[[Trial], object], n_trials: int,
                 n_workers: Optional[int] = None, catch: Tuple = ()) -> None:
        workers = max(1, int(n_workers if n_workers is not None else self.default_n_workers))
        remaining = int(n_trials)

        # Evaluate the first trial synchronously: it registers the space's
        # distributions (GridSampler's mixed-radix bookkeeping) and warms
        # shared caches before workers fan out, so concurrent trials in
        # the first real batch see a complete registry regardless of
        # scheduling order.
        if remaining > 0 and not self.trials:
            trial = self.ask()
            values, state = self._evaluate_one(objective, trial, catch)
            self.tell(trial, values, state)
            remaining -= 1

        with ThreadPoolExecutor(max_workers=workers) as pool:
            while remaining > 0:
                batch = [self.ask() for _ in range(min(workers, remaining))]
                futures = [pool.submit(self._evaluate_one, objective, t, catch) for t in batch]
                # Drain the whole batch before surfacing any uncaught
                # objective exception: the sibling evaluations already ran,
                # so their results must be told (and persisted) rather than
                # silently discarded, leaving trials stranded as RUNNING.
                outcomes = []
                for fut in futures:
                    try:
                        outcomes.append(fut.result())
                    except BaseException as e:  # uncaught objective error
                        outcomes.append(e)
                # tell in trial order — futures are ordered like the batch,
                # so storage appends and sampler population updates are
                # deterministic even when evaluations finish out of order
                error: Optional[BaseException] = None
                for trial, outcome in zip(batch, outcomes):
                    if isinstance(outcome, BaseException):
                        error = error or outcome
                        trial.set_user_attr("error", repr(outcome))
                        self.tell(trial, None, TrialState.FAIL)
                    else:
                        values, state = outcome
                        self.tell(trial, values, state)
                if error is not None:
                    raise error
                remaining -= len(batch)
