"""ParallelStudy: concurrent trial evaluation — sliding-window or batch.

Hardware-in-the-loop NAS is embarrassingly parallel across candidates —
each objective call is dominated by XLA compilation and benchmark I/O —
and that cost is highly *skewed*: one architecture compiles in 100 ms,
its batch sibling in 10 s.  ``ParallelStudy`` keeps the exact ask/tell
surface and storage format of :class:`Study` but overlaps objective
evaluation on a pluggable executor backend
(:mod:`repro.search.executors`) under one of two schedulers:

``schedule="sliding_window"`` (the fast path)
    Completion-driven: a new trial is asked the moment a slot frees and
    results are told as evaluations finish — no barrier, so workers
    never idle behind a straggler.  ``tell_order`` controls the tell
    stream:

      * ``"trial"`` (default) — a small reorder buffer defers each tell
        until every earlier trial has finished, so the JSONL storage and
        the study's completed-set evolve in exactly trial order (what
        the batch scheduler and a serial study produce);
      * ``"completion"`` — tell immediately.  Fastest and freshest (the
        pruner/history view lags nothing), at the price of a
        run-dependent storage order.  ``study.trials`` stays in trial
        order either way, and with a stateless sampler the sampled
        parameters and values are identical under both.

    ``window`` bounds in-flight submissions (default: ``n_workers``); a
    larger window keeps pool queues fed at the cost of asking further
    ahead of the tells.

``schedule="batch"`` (the legacy scheduler)
    Trials are asked ``n_workers`` at a time and every batch waits on
    its slowest member before any new trial is asked.  Population-based
    samplers see population snapshots at deterministic batch boundaries,
    so their trajectory is reproducible for a fixed ``n_workers`` and
    seed on every backend.

``schedule="auto"`` (the default) picks per sampler:
``sliding_window`` when the sampler declares itself
``order_independent`` (Random, Grid — suggestions derive from per-trial
RNG streams / the trial number alone, so a fixed seed yields identical
trials under either scheduler, any backend, any worker count), and
``batch`` for history-consulting samplers (TPE/evolution/NSGA-II),
whose sliding-window trajectory would depend on completion timing.

Determinism: with a stateless sampler (Random/Grid) and a deterministic
objective, every scheduler, backend and ``n_workers`` produce identical
trial parameters and identical best values.  The first trial of an
empty study runs synchronously so GridSampler's distribution registry
is complete before workers fan out (spaces whose parameter set varies
per trial — deeply conditional DSL spaces — can still register
parameters late, in which case Grid's sweep order is best-effort,
exactly as in a resumed serial study).

Timeouts: ``optimize(..., timeout_s=...)`` enforces the budget
per-submission under the sliding window (no new trial is submitted past
the deadline; in-flight ones drain) and per-batch under the batch
scheduler.

Error path: an uncaught objective exception stops new submissions,
**cancels** queued-but-not-started submissions (told FAIL with the
cancellation recorded in ``user_attrs["cancelled"]``), drains the
already-running evaluations (their results are told and persisted), and
then re-raises — no trial is ever left RUNNING.

Backend choice: ``thread`` (default) when the objective blocks without
holding the GIL (wall-clock benchmarking, remote devices); ``process``
when the objective is compile-bound — each worker process owns its own
XLA compiler, which is the only way to get real compile concurrency
(the in-process admission gate serializes sibling threads).
``process`` requires a picklable objective; with a picklable pruner it
prunes *worker-side* from submit-time snapshots (see
:mod:`repro.search.detached`).

Generation-ring screening (``optimize(..., screen=..., cohort=N)``)
    The fidelity-cascade scheduling mode: trials are asked a *cohort* at
    a time and handed — still RUNNING, parameters sampled in-parent — to
    the ``screen`` callable, which ranks them with cheap zero-cost /
    analytic stages and returns a :class:`ScreenDecision`.  Trials cut by
    a keep rule are told :attr:`TrialState.SCREENED` immediately (with
    ``user_attrs["fidelity_stage"]`` naming the cutting stage) and
    **never reach a worker**; hard-constraint casualties are told
    INFEASIBLE the same way; survivors are promoted to the executor under
    the selected schedule (batch or sliding window).  Because screening
    samples every parameter in the parent, the usual synchronous first
    trial is unnecessary — the distribution registry is complete before
    any worker sees a trial.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, List, Optional, Tuple, Union

from repro.search.executors import BaseExecutor, evaluate_trial, make_executor
from repro.search.study import Study
from repro.search.trial import Trial, TrialState

SCHEDULE_MODES = ("auto", "batch", "sliding_window")
TELL_ORDERS = ("trial", "completion")
DEFAULT_COHORT = 16  # generation size when screening without an explicit cohort

# Clock used for timeout enforcement; module-level so tests can stub it.
_monotonic = time.monotonic


def _check_choice(value: str, allowed: Tuple[str, ...], what: str) -> str:
    if value not in allowed:
        raise ValueError(f"unknown {what} {value!r}; expected one of {allowed}")
    return value


@dataclasses.dataclass
class ScreenDecision:
    """What a ``screen`` callable decided about one cohort of RUNNING
    trials: ``promoted`` go to the executor; ``screened`` are told
    SCREENED (with the stage that cut them); ``infeasible`` are told
    INFEASIBLE (a screening-stage hard constraint, carried as the
    :class:`~repro.search.study.HardConstraintViolated` it raised)."""

    promoted: List[Trial]
    screened: List[Tuple[Trial, str]] = dataclasses.field(default_factory=list)
    infeasible: List[Tuple[Trial, str, BaseException]] = dataclasses.field(default_factory=list)


class ParallelStudy(Study):
    """A Study whose ``optimize`` evaluates objectives concurrently."""

    def __init__(self, *args, n_workers: int = 4,
                 backend: Union[str, BaseExecutor] = "thread",
                 schedule: str = "auto", tell_order: str = "trial",
                 window: Optional[int] = None, **kwargs):
        super().__init__(*args, **kwargs)
        self.default_n_workers = max(1, int(n_workers))
        self.default_backend = backend
        self.default_schedule = _check_choice(schedule, SCHEDULE_MODES, "schedule")
        self.default_tell_order = _check_choice(tell_order, TELL_ORDERS, "tell_order")
        self.default_window = None if window is None else max(1, int(window))

    # -- scheduling helpers ----------------------------------------------------

    def _resolve_schedule(self, schedule: Optional[str]) -> str:
        mode = _check_choice(schedule if schedule is not None else self.default_schedule,
                             SCHEDULE_MODES, "schedule")
        if mode == "auto":
            return ("sliding_window"
                    if getattr(self.sampler, "order_independent", False) else "batch")
        return mode

    def _tell_outcome(self, trial: Trial, outcome) -> None:
        if isinstance(outcome, BaseException):
            trial.set_user_attr("error", repr(outcome))
            self.tell(trial, None, TrialState.FAIL)
        else:
            values, state = outcome
            self.tell(trial, values, state)

    # -- optimize --------------------------------------------------------------

    def optimize(self, objective: Callable[[Trial], object], n_trials: int,
                 n_workers: Optional[int] = None, catch: Tuple = (),
                 backend: Optional[Union[str, BaseExecutor]] = None,
                 schedule: Optional[str] = None,
                 tell_order: Optional[str] = None,
                 window: Optional[int] = None,
                 timeout_s: Optional[float] = None,
                 screen: Optional[Callable[[List[Trial]], ScreenDecision]] = None,
                 cohort: Optional[int] = None) -> None:
        workers = max(1, int(n_workers if n_workers is not None else self.default_n_workers))
        executor = make_executor(backend if backend is not None else self.default_backend)
        mode = self._resolve_schedule(schedule)
        order = _check_choice(tell_order if tell_order is not None else self.default_tell_order,
                              TELL_ORDERS, "tell_order")
        win = window if window is not None else self.default_window
        win = max(1, int(win)) if win is not None else workers
        deadline = None if timeout_s is None else _monotonic() + float(timeout_s)
        remaining = int(n_trials)
        coh = max(1, int(cohort)) if cohort is not None else DEFAULT_COHORT

        if screen is None:
            # Evaluate the first trial synchronously: it registers the
            # space's distributions (GridSampler's mixed-radix bookkeeping)
            # and warms shared caches before workers fan out, so concurrent
            # trials see a complete registry regardless of scheduling order.
            # (The ring path skips this — screening samples every parameter
            # in the parent before anything is submitted.)
            if remaining > 0 and not self.trials:
                trial = self.ask()
                values, state = evaluate_trial(objective, trial, catch)
                self.tell(trial, values, state)
                remaining -= 1

        if remaining <= 0 or (deadline is not None and _monotonic() >= deadline):
            return
        executor.start(workers)
        try:
            if screen is not None:
                if mode == "batch":
                    self._ring_batch(objective, remaining, catch, executor,
                                     deadline, screen, coh)
                else:
                    self._ring_sliding(objective, remaining, catch, executor,
                                       order, win, deadline, screen, coh)
            elif mode == "batch":
                self._optimize_batch(objective, remaining, workers, catch,
                                     executor, deadline)
            else:
                self._optimize_sliding(objective, remaining, catch, executor,
                                       order, win, deadline)
        finally:
            executor.shutdown()

    # -- batch scheduler (legacy) ----------------------------------------------

    def _optimize_batch(self, objective, remaining, workers, catch, executor,
                        deadline) -> None:
        while remaining > 0:
            if deadline is not None and _monotonic() >= deadline:
                return
            batch = [self.ask() for _ in range(min(workers, remaining))]
            # The executor drains the whole batch before surfacing any
            # uncaught objective exception: the sibling evaluations
            # already ran, so their results must be told (and persisted)
            # rather than silently discarded, leaving trials stranded as
            # RUNNING.
            outcomes = executor.run_batch(self, objective, batch, catch)
            # tell in trial order — outcomes are ordered like the batch,
            # so storage appends and sampler population updates are
            # deterministic even when evaluations finish out of order
            error: Optional[BaseException] = None
            for trial, outcome in zip(batch, outcomes):
                if isinstance(outcome, BaseException):
                    error = error or outcome
                self._tell_outcome(trial, outcome)
            if error is not None:
                raise error
            remaining -= len(batch)

    # -- sliding-window scheduler ----------------------------------------------

    def _optimize_sliding(self, objective, remaining, catch, executor,
                          tell_order, window, deadline) -> None:
        pending_tells = {}  # number -> (trial, outcome), tell_order="trial" only
        tell_cursor: Optional[int] = None  # next trial number owed a tell
        error: Optional[BaseException] = None
        stop_submitting = False

        def flush_tells():
            nonlocal tell_cursor
            while tell_cursor in pending_tells:
                trial, outcome = pending_tells.pop(tell_cursor)
                self._tell_outcome(trial, outcome)
                tell_cursor += 1

        def handle(trial, outcome):
            nonlocal error
            if isinstance(outcome, BaseException):
                error = error or outcome
            if tell_order == "trial":
                pending_tells[trial.number] = (trial, outcome)
                flush_tells()
            else:
                self._tell_outcome(trial, outcome)

        while True:
            # fill the window — the deadline is checked before EVERY
            # submission, so a timeout can never overshoot by a batch
            while (error is None and not stop_submitting and remaining > 0
                   and executor.pending_count() < window):
                if deadline is not None and _monotonic() >= deadline:
                    stop_submitting = True
                    break
                trial = self.ask()
                if tell_cursor is None:
                    tell_cursor = trial.number
                executor.submit(self, objective, trial, catch)
                remaining -= 1
            if executor.pending_count() == 0:
                break
            trial, outcome = executor.next_completed()
            handle(trial, outcome)
            if error is not None:
                # pull back whatever hasn't started; running trials keep
                # draining through next_completed above
                for cancelled in executor.cancel_pending():
                    cancelled.set_user_attr(
                        "cancelled",
                        f"submission cancelled: trial {trial.number} raised "
                        f"{type(error).__name__}")
                    handle(cancelled, (None, TrialState.FAIL))
        # every submission completed or was cancelled, so with
        # tell_order="trial" the buffer has flushed; sweep defensively in
        # number order in case a gap ever slipped through
        for number in sorted(pending_tells):
            trial, outcome = pending_tells.pop(number)
            self._tell_outcome(trial, outcome)
        if error is not None:
            raise error

    # -- generation-ring schedulers (fidelity cascade) ---------------------------

    def _screen_and_tell(self, screen, trials: List[Trial]) -> List[Trial]:
        """Run ``screen`` over one asked cohort and resolve everything it
        rejected: screened trials are told SCREENED, screening-stage hard
        constraint casualties INFEASIBLE (mirroring
        :func:`~repro.search.study.evaluate_trial`'s ``violated`` attr),
        both carrying ``fidelity_stage``.  Survivors come back still
        RUNNING, tagged ``fidelity_stage="promoted"``, for the executor.
        A screen that *raises* fails the whole cohort (no trial may stay
        RUNNING) and re-raises."""
        try:
            decision = screen(trials)
        except BaseException as e:
            for t in trials:
                if t.state == TrialState.RUNNING:
                    t.set_user_attr("error", f"screen raised: {e!r}")
                    self.tell(t, None, TrialState.FAIL)
            raise
        for t, stage in decision.screened:
            t.set_user_attr("fidelity_stage", stage)
            self.tell(t, None, TrialState.SCREENED)
        for t, stage, exc in decision.infeasible:
            t.set_user_attr("fidelity_stage", stage)
            t.set_user_attr("violated", {
                "name": getattr(exc, "name", None),
                "value": getattr(exc, "value", None),
                "limit": getattr(exc, "limit", None)})
            self.tell(t, None, TrialState.INFEASIBLE)
        for t in decision.promoted:
            t.set_user_attr("fidelity_stage", "promoted")
        return list(decision.promoted)

    def _fail_unsubmitted(self, queued, reason: str) -> None:
        """Trials that survived screening but never reached the executor
        (deadline hit, or a sibling error stopped submissions) must not
        stay RUNNING — tell them FAIL with the cancellation recorded,
        exactly like cancelled executor submissions."""
        for t in queued:
            t.set_user_attr("cancelled", reason)
            self._tell_outcome(t, (None, TrialState.FAIL))

    def _ring_batch(self, objective, remaining, catch, executor, deadline,
                    screen, cohort) -> None:
        while remaining > 0:
            if deadline is not None and _monotonic() >= deadline:
                return
            trials = [self.ask() for _ in range(min(cohort, remaining))]
            remaining -= len(trials)
            promoted = self._screen_and_tell(screen, trials)
            if not promoted:
                continue  # whole cohort screened out — ask the next one
            outcomes = executor.run_batch(self, objective, promoted, catch)
            error: Optional[BaseException] = None
            for trial, outcome in zip(promoted, outcomes):
                if isinstance(outcome, BaseException):
                    error = error or outcome
                self._tell_outcome(trial, outcome)
            if error is not None:
                raise error

    def _ring_sliding(self, objective, remaining, catch, executor, tell_order,
                      window, deadline, screen, cohort) -> None:
        """Sliding window over screened survivors: refill by asking +
        screening a cohort whenever the survivor queue runs dry, submit up
        to ``window`` in flight.  With ``tell_order="trial"`` the reorder
        buffer keys by *submission sequence* (trial numbers have gaps
        where cohort-mates were screened out), so storage appends evolve
        in promotion order."""
        queue: "collections.deque[Trial]" = collections.deque()
        pending_tells = {}  # submission seq -> (trial, outcome)
        seq_of = {}         # trial number -> submission seq
        next_seq = 0
        tell_cursor = 0
        error: Optional[BaseException] = None
        stop_submitting = False

        def flush_tells():
            nonlocal tell_cursor
            while tell_cursor in pending_tells:
                trial, outcome = pending_tells.pop(tell_cursor)
                self._tell_outcome(trial, outcome)
                tell_cursor += 1

        def handle(trial, outcome):
            nonlocal error
            if isinstance(outcome, BaseException):
                error = error or outcome
            if tell_order == "trial":
                pending_tells[seq_of[trial.number]] = (trial, outcome)
                flush_tells()
            else:
                self._tell_outcome(trial, outcome)

        while True:
            # refill the survivor queue — a cohort can be screened out
            # entirely, so keep asking until survivors appear or the
            # budget/deadline runs out
            while (error is None and not stop_submitting and remaining > 0
                   and not queue):
                if deadline is not None and _monotonic() >= deadline:
                    stop_submitting = True
                    break
                trials = [self.ask() for _ in range(min(cohort, remaining))]
                remaining -= len(trials)
                try:
                    queue.extend(self._screen_and_tell(screen, trials))
                except BaseException as e:
                    error = error or e
            # fill the window from the survivor queue
            while (error is None and not stop_submitting and queue
                   and executor.pending_count() < window):
                if deadline is not None and _monotonic() >= deadline:
                    stop_submitting = True
                    break
                trial = queue.popleft()
                seq_of[trial.number] = next_seq
                next_seq += 1
                executor.submit(self, objective, trial, catch)
            if error is not None:
                for cancelled in executor.cancel_pending():
                    cancelled.set_user_attr(
                        "cancelled",
                        f"submission cancelled: a sibling raised "
                        f"{type(error).__name__}")
                    handle(cancelled, (None, TrialState.FAIL))
            if executor.pending_count() == 0:
                break
            trial, outcome = executor.next_completed()
            handle(trial, outcome)
        for seq in sorted(pending_tells):
            trial, outcome = pending_tells.pop(seq)
            self._tell_outcome(trial, outcome)
        if queue:
            self._fail_unsubmitted(
                queue, "submission cancelled: "
                + ("deadline reached before submission" if error is None
                   else f"a sibling raised {type(error).__name__}"))
        if error is not None:
            raise error
