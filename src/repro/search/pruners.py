"""Pruners: median stopping and asynchronous successive halving (ASHA).

Worker-side contract: on the process backend a pruner instance is
pickled into each submission's :class:`~repro.search.detached.PrunerContext`
and its ``prune(study, trial)`` runs *inside the worker* against a
:class:`~repro.search.detached.StudyView` — a snapshot exposing only
``study.directions`` and ``study.trials`` records with ``state``,
``intermediate`` and ``values``.  Both shipped pruners read nothing
else, so they run unchanged in workers; a custom pruner that touches
more study state still works on the serial/thread backends, and on the
process backend degrades to "don't prune" (the context swallows its
errors) — or to no worker-side pruning at all if it doesn't pickle.
ASHA is the natural fit for the sliding-window scheduler: its rungs are
explicitly asynchronous, so deciding from a slightly stale rung
population (the submit-time snapshot plus streamed sibling reports) is
the algorithm working as designed, not an approximation.
"""
from __future__ import annotations

import math
from typing import Optional

from repro.explorer.registry import PRUNERS
from repro.search.trial import TrialState


@PRUNERS.register("median")
class MedianPruner:
    def __init__(self, n_startup_trials: int = 4, n_warmup_steps: int = 0):
        self.n_startup_trials = n_startup_trials
        self.n_warmup_steps = n_warmup_steps

    def prune(self, study, trial) -> bool:
        step = max(trial.intermediate)
        if step < self.n_warmup_steps:
            return False
        done = [t for t in study.trials if t.state == TrialState.COMPLETE and t.intermediate]
        if len(done) < self.n_startup_trials:
            return False
        sign = 1.0 if study.directions[0] == "minimize" else -1.0
        peers = []
        for t in done:
            steps = [s for s in t.intermediate if s <= step]
            if steps:
                peers.append(sign * t.intermediate[max(steps)])
        if not peers:
            return False
        peers.sort()
        median = peers[len(peers) // 2]
        return sign * trial.intermediate[step] > median


@PRUNERS.register("asha")
@PRUNERS.register("successive_halving")
class SuccessiveHalvingPruner:
    """ASHA: rungs at ``min_resource * reduction_factor**k``; a trial is
    pruned at a rung unless it is in the top ``1/reduction_factor`` of all
    values reported at that rung so far (asynchronous — no waiting)."""

    def __init__(self, min_resource: int = 1, reduction_factor: int = 3, min_early_stopping_rate: int = 0):
        self.min_resource = min_resource
        self.rf = reduction_factor
        self.rate = min_early_stopping_rate

    def _rung(self, step: int) -> Optional[int]:
        k = self.rate
        while True:
            r = self.min_resource * self.rf ** k
            if r > step:
                return None
            if self.min_resource * self.rf ** (k + 1) > step:
                return k
            k += 1

    def prune(self, study, trial) -> bool:
        step = max(trial.intermediate)
        rung = self._rung(step)
        if rung is None:
            return False
        resource = self.min_resource * self.rf ** rung
        sign = 1.0 if study.directions[0] == "minimize" else -1.0
        rung_vals = []
        for t in study.trials:
            if t.intermediate:
                steps = [s for s in t.intermediate if s >= resource]
                if steps:
                    rung_vals.append(sign * t.intermediate[min(steps)])
        me_steps = [s for s in trial.intermediate if s >= resource]
        me = sign * trial.intermediate[min(me_steps)]
        if len(rung_vals) < self.rf:
            return False
        rung_vals.sort()
        cutoff = rung_vals[max(0, int(math.ceil(len(rung_vals) / self.rf)) - 1)]
        return me > cutoff
