"""Detached sampling: picklable per-trial plans for out-of-process trials.

A ``ProcessExecutor`` worker cannot share the live :class:`Study` with
the parent, so the sampler hands each trial a *detached plan* — a small
picklable object holding everything the worker needs to reproduce the
exact suggestions the in-process sampler would have made:

  * the sampler's base seed, from which the per-trial RNG stream is
    re-derived as ``random.Random(f"{base_seed}/{trial.number}")`` —
    byte-identical to :meth:`BaseSampler.trial_rng`, so a fixed seed
    yields the same parameters at any worker count and on any backend;
  * sampler-specific snapshots taken at ask time under the study lock
    (grid registry, TPE trial records, evolution/NSGA-II parents) —
    exactly the state the threaded path would read during the batch,
    because results are only told *between* batches.

The pure sampling math (grid position, TPE split/pick) lives here and is
called by both the live samplers and the detached plans, so the two
paths cannot drift apart numerically.

``DetachedTrial`` is the worker-side stand-in for :class:`Trial`: same
suggest/report/user-attr surface, no study.  Pruning works through a
:class:`PrunerContext` — a picklable snapshot of the study pruner plus
the intermediate-value history visible at submit time — so
MedianPruner/ASHA terminate doomed trials *inside* the worker instead of
after a full evaluation.  Without a context (no study pruner, or an
unpicklable one) ``should_prune`` returns ``False``.
"""
from __future__ import annotations

import dataclasses
import math
import os
import random
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.search.trial import Distribution, TrialState


# ---------------------------------------------------------------------------
# shared sampling math (used by live samplers AND detached plans)
# ---------------------------------------------------------------------------

def grid_value(registry: Dict[str, Distribution], name: str,
               dist: Distribution, number: int) -> Any:
    """Mixed-radix grid position for trial ``number`` (GridSampler's core):
    the cartesian product over the registry's non-float grids is swept in
    sorted-name order.  Registers ``name`` in ``registry`` if unseen."""
    grid = dist.grid()
    registry.setdefault(name, dist)
    radix = 1
    for n in sorted(registry):
        if n == name:
            break
        d = registry[n]
        if d.kind != "float":
            radix *= max(1, len(d.grid()))
    return grid[(number // radix) % len(grid)]


def tpe_split(records: Sequence[Tuple[Dict[str, Any], float]], name: str,
              n_startup: int, gamma: float, sign: float):
    """Split completed-trial ``(params, value)`` records into good/bad
    value lists for ``name`` by the gamma-quantile of ``sign * value``.
    Returns ``(None, None)`` below the startup threshold."""
    done = [(p, v) for p, v in records if name in p]
    if len(done) < n_startup:
        return None, None
    done.sort(key=lambda pv: sign * pv[1])
    n_good = max(1, int(gamma * len(done)))
    gvals = [p[name] for p, _ in done[:n_good]]
    bvals = [p[name] for p, _ in done[n_good:]] or gvals
    return gvals, bvals


def tpe_pick(rng: random.Random, dist: Distribution, gvals: List[Any],
             bvals: List[Any], n_candidates: int) -> Any:
    """Pick the candidate maximizing l(x)/g(x) (kernel density for
    continuous, smoothed counts for categorical)."""
    if dist.kind == "categorical":
        def score(c):
            lg = (gvals.count(c) + 0.5) / (len(gvals) + 0.5 * len(dist.choices))
            lb = (bvals.count(c) + 0.5) / (len(bvals) + 0.5 * len(dist.choices))
            return lg / lb
        return max(dist.choices, key=score)
    # continuous / int: KDE with Scott bandwidth over candidates
    lo, hi = float(dist.low), float(dist.high)
    width = max(hi - lo, 1e-12)

    def kde(vals, x):
        bw = max(1.06 * width * len(vals) ** -0.2, width / 50)
        return sum(math.exp(-0.5 * ((x - v) / bw) ** 2) for v in vals) / (len(vals) * bw)

    cands = [dist.random(rng) for _ in range(n_candidates)]
    best = max(cands, key=lambda x: (kde(gvals, x) + 1e-12) / (kde(bvals, x) + 1e-12))
    if dist.kind == "int":
        best = dist.snap_int(best)
    return best


# ---------------------------------------------------------------------------
# detached plans
# ---------------------------------------------------------------------------

class DetachedSampler:
    """Base plan: pure random from the per-trial RNG stream.  This is the
    correct detachment for ``RandomSampler`` and the fallback any sampler
    inherits; samplers that consult study state must override
    :meth:`BaseSampler.detached` to snapshot what they need."""

    def __init__(self, base_seed: int):
        self.base_seed = base_seed

    def rng(self, trial) -> random.Random:
        r = getattr(trial, "_sampler_rng", None)
        if r is None:
            r = random.Random(f"{self.base_seed}/{trial.number}")
            trial._sampler_rng = r
        return r

    def sample(self, trial, name: str, dist: Distribution) -> Any:
        return dist.random(self.rng(trial))


class DetachedGrid(DetachedSampler):
    """Grid plan: a snapshot of the distribution registry at ask time.
    Parameters registered only inside the worker extend the local copy
    (best-effort sweep order, exactly like a resumed serial study)."""

    def __init__(self, base_seed: int, registry: Dict[str, Distribution]):
        super().__init__(base_seed)
        self.registry = dict(registry)

    def sample(self, trial, name, dist):
        if dist.kind == "float":
            return dist.random(self.rng(trial))
        return grid_value(self.registry, name, dist, trial.number)


class DetachedTPE(DetachedSampler):
    """TPE plan: the completed-trial records visible at ask time (the
    threaded path sees the same set — tells only happen between batches)."""

    def __init__(self, base_seed: int, records, gamma: float,
                 n_candidates: int, n_startup: int, sign: float):
        super().__init__(base_seed)
        self.records = records  # shared, read-only batch snapshot
        self.gamma = gamma
        self.n_candidates = n_candidates
        self.n_startup = n_startup
        self.sign = sign

    def sample(self, trial, name, dist):
        rng = self.rng(trial)
        gvals, bvals = tpe_split(self.records, name, self.n_startup, self.gamma, self.sign)
        if gvals is None:
            return dist.random(rng)
        return tpe_pick(rng, dist, gvals, bvals, self.n_candidates)


class DetachedEvolution(DetachedSampler):
    """Regularized-evolution plan: the parent configuration and mutation
    set precomputed for this trial at ``on_trial_start``."""

    def __init__(self, base_seed: int, parent: Optional[Dict[str, Any]], mutated):
        super().__init__(base_seed)
        self.parent = dict(parent) if parent is not None else None
        self.mutated = set(mutated)

    def sample(self, trial, name, dist):
        if self.parent is None or name not in self.parent or name in self.mutated:
            return dist.random(self.rng(trial))
        return self.parent[name]


class DetachedNSGA2(DetachedSampler):
    """NSGA-II plan: the crossover child precomputed for this trial plus
    the per-parameter mutation probability."""

    def __init__(self, base_seed: int, parent: Optional[Dict[str, Any]], mutation_p: float):
        super().__init__(base_seed)
        self.parent = dict(parent) if parent is not None else None
        self.mutation_p = mutation_p

    def sample(self, trial, name, dist):
        rng = self.rng(trial)
        if self.parent is None or name not in self.parent or self.parent[name] is None:
            return dist.random(rng)
        if rng.random() < self.mutation_p:
            return dist.perturb(rng, self.parent[name])
        return self.parent[name]


# ---------------------------------------------------------------------------
# worker-side pruning
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrialRecord:
    """One study trial's pruning-relevant history, picklable: exactly the
    attributes the shipped pruners read (``state``, ``intermediate``,
    ``values``).  A custom pruner touching anything else on a study trial
    raises inside :meth:`PrunerContext.should_prune`, which degrades to
    "don't prune" rather than killing the worker trial."""

    state: TrialState
    intermediate: Dict[int, float]
    values: Optional[Tuple[float, ...]] = None


class StudyView:
    """Minimal study stand-in handed to a pruner inside a worker: the
    ``directions`` tuple plus ``trials`` as :class:`TrialRecord`s."""

    def __init__(self, directions: Tuple[str, ...], trials: List[TrialRecord]):
        self.directions = directions
        self.trials = trials


# Per-worker-process pruning history, keyed by the parent's context id:
# ``context_id -> (applied_len, {trial_number: TrialRecord})``.  Each
# PrunerContext ships only the delta-log *tail* the parent hasn't seen
# this process acknowledge yet; :meth:`PrunerContext.apply` folds it in
# idempotently, so a worker evaluating its 50th trial re-applies nothing
# it already holds.  One entry at a time — a new context id (new study /
# restarted executor) evicts the old history.
_DELTA_HISTORY: Dict[str, Tuple[int, Dict[int, TrialRecord]]] = {}


def _fold_deltas(records: Dict[int, TrialRecord], deltas) -> None:
    """Fold delta-log entries into ``records`` **in place** — every live
    :class:`PrunerContext` holding a reference to the dict sees the new
    history on its next ``should_prune`` call."""
    for delta in deltas:
        if delta[0] == "report":
            _, number, step, value = delta
            rec = records.get(number)
            if rec is None:
                rec = records[number] = TrialRecord(TrialState.RUNNING, {})
            rec.intermediate[int(step)] = float(value)
        else:  # "final" — terminal record supersedes streamed reports
            _, number, state, values, intermediate = delta
            records[number] = TrialRecord(state, dict(intermediate), values)


def apply_pruner_deltas(context_id: str, base: int, deltas) -> int:
    """Mid-trial refresh entry point: fold a delta-log tail starting at
    log offset ``base`` into this process's history for ``context_id``
    and return the resulting ``applied_len`` (the refresh ack).

    Because the fold mutates the shared records dict in place, a trial
    *already running* in this process — whose :class:`PrunerContext`
    applied an earlier slice of the same context — sees the refreshed
    sibling population on its very next ``should_prune`` call, letting
    long trials prune against history that did not exist when they were
    submitted.  Entries before the stored ``applied_len`` are skipped
    (idempotent, same as :meth:`PrunerContext.apply`); a tail starting
    past what this process holds is ignored — the gap cannot be
    reconstructed, so the stale ack tells the sender to stop truncating
    past us.

    Thread-safety: the folding thread (a worker's receive loop) races
    benignly with trial threads reading the dict — CPython dict ops are
    atomic, records are never deleted, and a pruner that trips over a
    concurrently-growing ``intermediate`` dict is caught by
    ``should_prune``'s degrade-to-no-prune guard."""
    applied, records = _DELTA_HISTORY.get(context_id, (0, {}))
    deltas = list(deltas or ())
    if applied < base:
        return applied  # missed prefix: unusable, report what we hold
    _fold_deltas(records, deltas[applied - base:])
    applied = max(applied, base + len(deltas))
    _DELTA_HISTORY[context_id] = (applied, records)
    return applied


class PrunerContext:
    """Picklable pruning snapshot shipped with a detached plan.

    Holds the study's pruner instance, its directions, and the
    intermediate-value history visible when the trial was submitted —
    completed trials plus whatever sibling workers have streamed back so
    far.  The decision is therefore *asynchronous* in the ASHA sense:
    based on a slightly stale rung population, never waiting on the
    parent.  MedianPruner and SuccessiveHalvingPruner read only what
    :class:`TrialRecord` carries, so they run unchanged.

    Two wire formats:

    * ``records`` — a full history snapshot.  Simple, but re-serializes
      every intermediate value of every trial on every submission
      (O(n²) over a study).  Kept for direct construction in tests and
      third-party executors.
    * ``deltas`` + ``base`` + ``context_id`` — an incremental slice of
      the parent's append-only delta log, starting at log offset
      ``base``.  Entries are ``("report", number, step, value)`` for a
      streamed intermediate report and ``("final", number, state,
      values, intermediate)`` for a merged-back terminal record (which
      supersedes that trial's streamed reports).  Workers accumulate the
      log in process-local :data:`_DELTA_HISTORY` and acknowledge how
      much they hold via :meth:`ack`, letting the parent truncate the
      prefix every worker has applied and ship only tails.  A worker
      that missed a truncated prefix (e.g. a replacement process joining
      mid-study) cannot reconstruct the population, so it degrades to
      "don't prune" rather than decide on partial history."""

    def __init__(self, pruner: Any, directions: Tuple[str, ...],
                 records: Optional[List[TrialRecord]] = None, *,
                 deltas: Optional[List[Tuple]] = None, base: int = 0,
                 context_id: Optional[str] = None):
        self.pruner = pruner
        self.directions = tuple(directions)
        self.records = records
        self.deltas = deltas
        self.base = int(base)
        self.context_id = context_id
        self._applied: Optional[Tuple[int, Optional[Dict[int, TrialRecord]]]] = None

    def apply(self) -> None:
        """Worker-side: fold this context's delta slice into the
        process-local history.  Idempotent — entries this process already
        applied (per the stored ``applied_len``) are skipped."""
        if self._applied is not None or self.context_id is None:
            return
        for stale in [k for k in _DELTA_HISTORY if k != self.context_id]:
            del _DELTA_HISTORY[stale]
        applied, records = _DELTA_HISTORY.get(self.context_id, (0, {}))
        if applied < self.base:
            # this process missed a truncated log prefix: the sibling
            # population can't be reconstructed, so degrade to no-prune
            # (ack the stale applied_len — the parent's min() over acks
            # then stops truncating past what this process holds)
            self._applied = (applied, None)
            return
        _fold_deltas(records, (self.deltas or [])[applied - self.base:])
        applied = max(applied, self.base + len(self.deltas or ()))
        _DELTA_HISTORY[self.context_id] = (applied, records)
        self._applied = (applied, records)

    def ack(self) -> Optional[Tuple[str, int, int]]:
        """``(context_id, pid, applied_len)`` for the worker result —
        tells the parent which log prefix this worker process durably
        holds, so it can truncate what *every* worker has applied.
        ``None`` for a legacy full-snapshot context."""
        if self.context_id is None:
            return None
        self.apply()
        return (self.context_id, os.getpid(), self._applied[0])

    def _history(self) -> Optional[List[TrialRecord]]:
        if self.context_id is None:
            return list(self.records or [])
        self.apply()
        records = self._applied[1]
        if records is None:  # degraded: missed a truncated prefix
            return None
        return [records[n] for n in sorted(records) if records[n].intermediate]

    def should_prune(self, trial: "DetachedTrial") -> bool:
        if not trial.intermediate:
            return False
        history = self._history()
        if history is None:
            return False
        # the live path sees the asking trial inside study.trials too
        # (ASHA counts its own rung value), so mirror that here
        view = StudyView(
            self.directions,
            history + [TrialRecord(TrialState.RUNNING, trial.intermediate)],
        )
        try:
            return bool(self.pruner.prune(view, trial))
        except Exception:
            # a pruner that needs more study state than the snapshot
            # carries must not crash the trial — run it to completion
            return False


# ---------------------------------------------------------------------------
# worker-side trial
# ---------------------------------------------------------------------------

class DetachedTrial:
    """Worker-side stand-in for :class:`Trial`: the same suggestion
    surface, backed by a :class:`DetachedSampler` plan instead of a live
    study.  Everything it accumulates (params, distributions, attrs,
    intermediate reports) is merged back into the real trial by the
    executor when the worker returns.  ``report`` additionally streams
    each intermediate value to ``report_queue`` (when the executor
    provides one) so the parent — and through it, later submissions'
    pruner snapshots — see sibling progress before the trial finishes.

    ``params`` pre-seeds suggestions the parent already sampled (the
    fidelity cascade samples in-parent to screen a cohort before
    promoting survivors to workers): ``_suggest`` returns a seeded value
    instead of re-deriving it, so the worker reuses the exact screened
    configuration."""

    def __init__(self, number: int, sampler: DetachedSampler,
                 pruner: Optional[PrunerContext] = None,
                 report_queue: Any = None,
                 params: Optional[Dict[str, Any]] = None):
        self.number = number
        self.params: Dict[str, Any] = dict(params) if params else {}
        self.distributions: Dict[str, Distribution] = {}
        self.intermediate: Dict[int, float] = {}
        self.user_attrs: Dict[str, Any] = {}
        self.system_attrs: Dict[str, Any] = {}
        self._sampler = sampler
        self._pruner = pruner
        self._report_queue = report_queue

    def _suggest(self, name: str, dist: Distribution) -> Any:
        if name in self.params:
            return self.params[name]
        value = self._sampler.sample(self, name, dist)
        self.params[name] = value
        self.distributions[name] = dist
        return value

    def suggest_categorical(self, name: str, choices: Sequence[Any]) -> Any:
        return self._suggest(name, Distribution("categorical", choices=tuple(choices)))

    def suggest_int(self, name: str, low: int, high: int, step: int = 1, log: bool = False) -> int:
        return int(self._suggest(name, Distribution("int", low=low, high=high, step=step, log=log)))

    def suggest_float(self, name: str, low: float, high: float, log: bool = False) -> float:
        return float(self._suggest(name, Distribution("float", low=low, high=high, log=log)))

    def report(self, step: int, value: float) -> None:
        self.intermediate[int(step)] = float(value)
        if self._report_queue is not None:
            try:
                self._report_queue.put_nowait((self.number, int(step), float(value)))
            except Exception:
                # best-effort streaming: a full/closed channel only makes
                # sibling snapshots staler, it must not fail the trial
                pass

    def should_prune(self) -> bool:
        if self._pruner is None:
            # no pruner shipped (study has none, or it didn't pickle)
            return False
        return self._pruner.should_prune(self)

    def set_user_attr(self, key: str, value: Any) -> None:
        self.user_attrs[key] = value

    @property
    def value(self) -> Optional[float]:
        return None
