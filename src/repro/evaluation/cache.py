"""Shared, thread-safe evaluation cache for NAS candidate costs.

Hardware-in-the-loop NAS is dominated by repeated compilation of
identical architectures: samplers revisit points (grid wrap-around,
evolution inheriting whole configurations, TPE exploitation), and every
compiled-cost estimator used to re-generate its own artifact.  This
module centralizes the memoization:

  * keys are built from the candidate's *full* architecture signature
    (layers AND pre-processing — see ``ArchitectureIR.signature``) plus
    the estimator-specific context (target, batch), so distinct programs
    never collide;
  * one :class:`EvaluationCache` can be shared by several estimators —
    ``CompiledLatencyEstimator`` and ``CompiledMemoryEstimator`` reuse
    the same generated ``Artifact`` instead of compiling twice;
  * lookups are single-flight: when several ``ParallelStudy`` workers
    race on the same key, exactly one computes while the rest wait for
    the result instead of duplicating an XLA compile.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, Hashable, Optional, Tuple


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {"hits": self.hits, "misses": self.misses, "hit_rate": self.hit_rate}


class EvaluationCache:
    """Thread-safe, single-flight memoization keyed by hashable tuples."""

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: Dict[Hashable, Any] = {}
        self._inflight: Dict[Hashable, threading.Event] = {}
        self.stats = CacheStats()

    # -- key construction ------------------------------------------------------

    @staticmethod
    def candidate_key(candidate: Any) -> Optional[str]:
        """Identity of a candidate: the full architecture signature, or
        None when the candidate has no arch.  None means "don't cache":
        an object-id fallback would be unsound in a long-lived shared
        cache (a freed candidate's address can be reused by a different
        model, silently returning the wrong cost)."""
        arch = getattr(candidate, "arch", None)
        if arch is not None:
            return arch.signature()
        return None

    # -- core ------------------------------------------------------------------

    def get_or_compute(self, key: Hashable, compute: Callable[[], Any]) -> Any:
        """Return the cached value for ``key``, computing it at most once
        across concurrent callers (single-flight).  A key of None (or a
        tuple containing None, as produced for uncacheable candidates)
        bypasses the cache entirely."""
        if key is None or (isinstance(key, tuple) and any(k is None for k in key)):
            return compute()
        while True:
            with self._lock:
                if key in self._entries:
                    self.stats.hits += 1
                    return self._entries[key]
                event = self._inflight.get(key)
                if event is None:
                    event = threading.Event()
                    self._inflight[key] = event
                    self.stats.misses += 1
                    break  # we own the computation
            # another worker is computing this key: wait, then re-check
            # (re-loop handles the owner failing with an exception)
            event.wait()
        try:
            value = compute()
        except BaseException:
            with self._lock:
                self._inflight.pop(key, None)
            event.set()
            raise
        with self._lock:
            self._entries[key] = value
            self._inflight.pop(key, None)
        event.set()
        return value

    def get(self, key: Hashable, default: Any = None) -> Any:
        with self._lock:
            return self._entries.get(key, default)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.stats = CacheStats()
