"""Shared, thread-safe evaluation cache for NAS candidate costs.

Hardware-in-the-loop NAS is dominated by repeated compilation of
identical architectures: samplers revisit points (grid wrap-around,
evolution inheriting whole configurations, TPE exploitation), and every
compiled-cost estimator used to re-generate its own artifact.  This
module centralizes the memoization:

  * keys are built from the candidate's *full* architecture signature
    (layers AND pre-processing — see ``ArchitectureIR.signature``) plus
    the estimator-specific context (target, batch), so distinct programs
    never collide;
  * one :class:`EvaluationCache` can be shared by several estimators —
    ``CompiledLatencyEstimator`` and ``CompiledMemoryEstimator`` reuse
    the same generated ``Artifact`` instead of compiling twice;
  * lookups are single-flight: when several ``ParallelStudy`` workers
    race on the same key, exactly one computes while the rest wait for
    the result instead of duplicating an XLA compile;
  * an optional **disk tier** (:class:`DiskEvaluationCache`) persists the
    JSON-serializable values (estimator scalars, not compiled
    executables) across process restarts and between process-pool
    workers sharing the store directory, so a warm-restarted study
    performs zero XLA compiles for architectures the host has already
    paid for.  Owners check the disk tier before computing and write
    computed values through to it.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Any, Callable, Dict, Hashable, Optional, Union

from repro.evaluation.disk_cache import DiskEvaluationCache


@dataclasses.dataclass
class CacheStats:
    hits: int = 0        # served from the in-memory tier
    disk_hits: int = 0   # served from the disk tier (no compute, no compile)
    misses: int = 0      # actually computed

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.disk_hits + self.misses
        return (self.hits + self.disk_hits) / total if total else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {"hits": self.hits, "disk_hits": self.disk_hits,
                "misses": self.misses, "hit_rate": self.hit_rate}


class EvaluationCache:
    """Thread-safe, single-flight memoization keyed by hashable tuples.

    ``disk`` may be a :class:`DiskEvaluationCache`, a path (store
    directory, created if needed), or ``True`` for the default
    ``results/cache/`` store.  Without it the cache is memory-only.
    """

    def __init__(self, disk: Union[DiskEvaluationCache, str, os.PathLike, bool, None] = None):
        self._lock = threading.Lock()
        self._entries: Dict[Hashable, Any] = {}
        self._inflight: Dict[Hashable, threading.Event] = {}
        # bumped by clear(); an owner whose computation started before a
        # clear() must not resurrect its (now stale) entry afterwards
        self._generation = 0
        self.stats = CacheStats()
        # identity/type checks, NOT truthiness: an empty DiskEvaluationCache
        # is falsy via __len__ but is still a live tier
        if isinstance(disk, DiskEvaluationCache):
            pass
        elif disk is True:
            disk = DiskEvaluationCache()
        elif isinstance(disk, (str, os.PathLike)) and str(disk):
            disk = DiskEvaluationCache(str(disk))
        else:  # None / False / "": memory-only
            disk = None
        self.disk: Optional[DiskEvaluationCache] = disk

    # -- key construction ------------------------------------------------------

    @staticmethod
    def candidate_key(candidate: Any) -> Optional[str]:
        """Identity of a candidate: the full architecture signature, or
        None when the candidate has no arch.  None means "don't cache":
        an object-id fallback would be unsound in a long-lived shared
        cache (a freed candidate's address can be reused by a different
        model, silently returning the wrong cost)."""
        arch = getattr(candidate, "arch", None)
        if arch is not None:
            return arch.signature()
        return None

    # -- core ------------------------------------------------------------------

    def get_or_compute(self, key: Hashable, compute: Callable[[], Any]) -> Any:
        """Return the cached value for ``key``, computing it at most once
        across concurrent callers (single-flight).  A key of None (or a
        tuple containing None, as produced for uncacheable candidates)
        bypasses the cache entirely.  Owners consult the disk tier before
        computing and write computed values through to it."""
        if key is None or (isinstance(key, tuple) and any(k is None for k in key)):
            return compute()
        while True:
            with self._lock:
                if key in self._entries:
                    self.stats.hits += 1
                    return self._entries[key]
                event = self._inflight.get(key)
                if event is None:
                    event = threading.Event()
                    self._inflight[key] = event
                    generation = self._generation
                    break  # we own the computation
            # another worker is computing this key: wait, then re-check
            # (re-loop handles the owner failing with an exception)
            event.wait()
        # We own the key.  Whatever happens below — disk I/O error,
        # compute failure, success — the finally releases ownership and
        # wakes waiters, so a failure can never strand them in wait().
        try:
            # disk read-through (file I/O outside the lock): a value
            # persisted by an earlier run — or a sibling process — costs
            # no compute
            if self.disk is not None:
                found, value = self.disk.lookup(key)
                if found:
                    with self._lock:
                        if generation == self._generation:
                            self._entries[key] = value
                            self.stats.disk_hits += 1
                    return value
            with self._lock:
                self.stats.misses += 1
            value = compute()
            with self._lock:
                persist = generation == self._generation
                if persist:
                    self._entries[key] = value
            # Write-through outside the cache lock: the flock+fsync must
            # not stall sibling memory hits.  The persist *decision* is
            # generation-checked above, so a completed clear() is always
            # respected; only a clear(disk=True) racing this very append
            # can leave one stale record on disk — the same exposure as a
            # sibling process appending after the truncate.  Cross-process
            # invalidation is best-effort by design: delete the store
            # directory for a guaranteed rebuild.
            if persist and self.disk is not None:
                self.disk.store(key, value)
            return value
        finally:
            with self._lock:
                if self._inflight.get(key) is event:
                    del self._inflight[key]
            event.set()

    def get(self, key: Hashable, default: Any = None) -> Any:
        with self._lock:
            return self._entries.get(key, default)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def clear(self, disk: bool = False) -> None:
        """Drop every entry and reset stats.  In-flight computations lose
        ownership atomically: their callers still receive the value they
        computed, but it is neither cached nor written to disk, so a
        compute finishing after ``clear()`` can never resurrect a stale
        entry.  Waiters are woken and recompute fresh.  The disk tier is
        kept unless ``disk=True``."""
        with self._lock:
            self._generation += 1
            self._entries.clear()
            inflight, self._inflight = self._inflight, {}
            self.stats = CacheStats()
            if disk and self.disk is not None:
                # truncate under the cache lock: an owner doing a disk
                # read-through after the generation bump must find the
                # store already wiped, or it would cache the stale value
                # under the new generation (lock order cache -> disk
                # matches the store path)
                self.disk.clear()
        for event in inflight.values():
            event.set()
