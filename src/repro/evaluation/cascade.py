"""Fidelity cascade: staged candidate screening before compiled evaluation.

The flat :class:`~repro.evaluation.api.CriteriaRunner` pays a full XLA
compile per candidate before any criterion can reject it — the binding
scale ceiling for hardware-in-the-loop NAS.  A cascade restructures the
evaluation layer as an ordered list of :class:`FidelityStage`\\ s, from
cheap to expensive::

    CascadeRunner([
        FidelityStage("zero_cost",                    # tier 0: ~ms/candidate
                      [OptimizationCriteria(SynFlowEstimator(),
                                            direction="maximize")],
                      keep=KeepRule(top_frac=0.25)),
        FidelityStage("analytic",                     # tier 1: analytic/roofline
                      [OptimizationCriteria(FlopsEstimator())],
                      keep=KeepRule(top_k=8)),
        FidelityStage("compiled",                     # tier 2: the old flat pass
                      [OptimizationCriteria(latency), ...]),
    ])

Every stage but the last carries a **keep rule** — ``top_k`` / ``top_frac``
(rank the cohort by the stage's scalarized score, lower = better, and
keep the best) or ``threshold`` (keep candidates whose stage score is
<= the threshold; per-candidate, no cohort needed).  ``screen_cohort``
runs the screening stages over a cohort of candidates in-process;
survivors are *promoted* to the final stage, which is evaluated by the
inherited :meth:`~repro.evaluation.api.CriteriaRunner.evaluate` /
``evaluate_multi`` — a ``CascadeRunner`` **is** a ``CriteriaRunner``
over its final stage, and a cascade with no screening stages is exactly
the old flat runner (the degenerate one-stage case).

Stage scores scalarize through the same aggregator as the final score
(maximize objectives fold in by sign), so "keep the best" always means
"keep the lowest stage score"; a hard constraint inside a screening
stage marks the candidate infeasible right there, before anything
compiles.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.evaluation.api import (
    CriteriaRunner,
    OptimizationCriteria,
    check_distinct_names,
    weighted_sum,
)
from repro.search.study import HardConstraintViolated

KEEP_RULES = ("top_k", "top_frac", "threshold")


@dataclasses.dataclass(frozen=True)
class KeepRule:
    """Which candidates survive a screening stage.  Exactly one of the
    three fields must be set: ``top_k`` / ``top_frac`` rank the cohort by
    stage score (lower = better, ties broken by ask order) and keep the
    best k / fraction (at least one); ``threshold`` keeps candidates
    whose stage score is <= the threshold, independent of the cohort."""

    top_k: Optional[int] = None
    top_frac: Optional[float] = None
    threshold: Optional[float] = None

    def __post_init__(self):
        set_fields = [name for name in KEEP_RULES
                      if getattr(self, name) is not None]
        if len(set_fields) != 1:
            raise ValueError(
                f"a keep rule needs exactly one of {KEEP_RULES}, "
                f"got {set_fields or 'none'}")
        if self.top_k is not None and int(self.top_k) < 1:
            raise ValueError(f"top_k must be >= 1, got {self.top_k}")
        if self.top_frac is not None and not 0.0 < float(self.top_frac) <= 1.0:
            raise ValueError(
                f"top_frac must be in (0, 1], got {self.top_frac}")

    def survivors(self, scored: Sequence[Tuple[int, float]]) -> List[int]:
        """Indices surviving this rule.  ``scored`` is ``(index, score)``
        with lower scores better; ranking rules sort by ``(score, index)``
        so ties keep ask order and the selection is deterministic."""
        if self.threshold is not None:
            return [i for i, s in scored if s <= float(self.threshold)]
        ranked = sorted(scored, key=lambda pair: (pair[1], pair[0]))
        if self.top_k is not None:
            n = int(self.top_k)
        else:
            n = max(1, math.ceil(float(self.top_frac) * len(ranked)))
        return sorted(i for i, _ in ranked[:n])

    def to_dict(self) -> Dict[str, Any]:
        return {name: getattr(self, name) for name in KEEP_RULES
                if getattr(self, name) is not None}


@dataclasses.dataclass
class FidelityStage:
    """One rung of the cascade: a named criteria list plus the keep rule
    that decides who climbs to the next rung (``None`` marks the final,
    fully-evaluated stage)."""

    name: str
    criteria: List[OptimizationCriteria]
    keep: Optional[KeepRule] = None

    def __post_init__(self):
        if not self.name:
            raise ValueError("a fidelity stage needs a non-empty name")
        if not self.criteria:
            raise ValueError(
                f"fidelity stage {self.name!r} needs at least one criterion")


@dataclasses.dataclass
class CohortResult:
    """What screening one cohort decided, by candidate index:
    ``promoted`` survived every screening stage; ``screened`` were cut by
    a ranking/threshold rule (index -> stage name); ``infeasible`` hit a
    hard constraint inside a screening stage (index -> (stage name,
    exception))."""

    promoted: List[int]
    screened: Dict[int, str]
    infeasible: Dict[int, Tuple[str, HardConstraintViolated]]

    @property
    def counts(self) -> Dict[str, int]:
        return {"promoted": len(self.promoted),
                "screened": len(self.screened),
                "infeasible": len(self.infeasible)}


# user-attr prefix for per-stage scalarized scores (the report's
# proxy-vs-final Spearman reads these back)
STAGE_SCORE_ATTR = "fidelity_score:"


class CascadeRunner(CriteriaRunner):
    """A :class:`CriteriaRunner` over the final stage, plus in-process
    screening stages.  ``evaluate`` / ``evaluate_multi`` run the final
    stage only (identical to the flat runner — existing callers see no
    difference); :meth:`screen_cohort` runs the screening stages over a
    cohort and says who gets promoted to them."""

    def __init__(self, stages: Sequence[FidelityStage],
                 aggregator: Callable[[Dict[str, float], List[OptimizationCriteria]], float] = weighted_sum,
                 cache=None):
        stages = list(stages)
        if not stages:
            raise ValueError("a cascade needs at least one stage")
        names = [s.name for s in stages]
        dupes = sorted({n for n in names if names.count(n) > 1})
        if dupes:
            raise ValueError(f"duplicate fidelity stage name(s) {dupes}")
        for s in stages[:-1]:
            if s.keep is None:
                raise ValueError(
                    f"screening stage {s.name!r} needs a keep rule "
                    f"(only the final stage evaluates everything it is given)")
        if stages[-1].keep is not None:
            raise ValueError(
                f"final stage {stages[-1].name!r} must not have a keep rule — "
                f"it evaluates every promoted candidate")
        # estimator names must be distinct across the WHOLE cascade, not
        # just within one stage: trials record values by estimator name
        check_distinct_names([c for s in stages for c in s.criteria])
        super().__init__(stages[-1].criteria, aggregator=aggregator, cache=cache)
        self.stages = stages
        self.screening = stages[:-1]
        # per-stage flat runners score cohorts with the same staged
        # iteration (hard constraints first) and the same aggregator as
        # the final score; the shared cache wires onto every estimator
        self._stage_runners = {
            s.name: CriteriaRunner(s.criteria, aggregator=aggregator, cache=cache)
            for s in self.screening
        }

    @property
    def all_criteria(self) -> List[OptimizationCriteria]:
        """Every criterion in cascade order (screening stages first)."""
        return [c for s in self.stages for c in s.criteria]

    def screen_cohort(self, candidates: Sequence[Any], trials: Optional[Sequence[Any]] = None,
                      context: Optional[Dict] = None) -> CohortResult:
        """Run the screening stages over a cohort of built candidates.

        ``trials`` (optional, parallel to ``candidates``) receives the
        per-criterion values and the scalarized stage score
        (``fidelity_score:<stage>``) as user attrs, so reports can
        correlate proxy rankings with final outcomes.  Candidates
        eliminated at stage *i* never run stage *i+1* — and never reach
        the compiled final stage at all.
        """
        alive = list(range(len(candidates)))
        screened: Dict[int, str] = {}
        infeasible: Dict[int, Tuple[str, HardConstraintViolated]] = {}
        for stage in self.screening:
            runner = self._stage_runners[stage.name]
            scored: List[Tuple[int, float]] = []
            for i in alive:
                trial = trials[i] if trials is not None else None
                try:
                    score = runner.evaluate(candidates[i], context, trial=trial)
                except HardConstraintViolated as e:
                    infeasible[i] = (stage.name, e)
                    continue
                if trial is not None:
                    trial.set_user_attr(STAGE_SCORE_ATTR + stage.name, score)
                scored.append((i, score))
            kept = set(stage.keep.survivors(scored))
            for i, _ in scored:
                if i not in kept:
                    screened[i] = stage.name
            alive = [i for i, _ in scored if i in kept]
        return CohortResult(promoted=alive, screened=screened,
                            infeasible=infeasible)
