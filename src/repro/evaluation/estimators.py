"""Cost + performance estimators (paper §V).

Cost estimators:
  * ParamCountEstimator / FlopsEstimator / ActivationMemoryEstimator —
    analytical, from BuiltModel metadata (fast, no compilation)
  * CompiledLatencyEstimator — hardware-in-the-loop: generates the
    artifact for a TargetSpec via the XLA generator and returns measured
    wall-clock (host backend) or roofline-modelled latency (TPU targets)
  * CompiledMemoryEstimator — per-device peak bytes from memory_analysis

Performance estimators:
  * TrainedAccuracyEstimator — trains the candidate briefly on a provided
    dataset and returns validation accuracy (supports trial.report/pruning)
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.builder import BuiltModel
from repro.evaluation.api import Estimator
from repro.hwgen.generator import HardwareManager, XLAGenerator
from repro.hwgen.targets import TargetSpec


class ParamCountEstimator(Estimator):
    name = "n_params"

    def estimate(self, candidate: BuiltModel, context=None) -> float:
        return float(candidate.n_params)


class FlopsEstimator(Estimator):
    name = "flops"

    def estimate(self, candidate: BuiltModel, context=None) -> float:
        return float(candidate.flops)


class ActivationMemoryEstimator(Estimator):
    """Analytical activation footprint: max layer output size (batch 1)."""

    name = "activation_bytes"
    bytes_per_el = 4

    def estimate(self, candidate: BuiltModel, context=None) -> float:
        peak = max((math.prod(l.out_shape) for l in candidate.layers), default=0)
        return float(peak * self.bytes_per_el)


class CompiledLatencyEstimator(Estimator):
    """Hardware-in-the-loop latency via the generator pipeline (paper §VI
    mode 2).  Results are cached by architecture signature."""

    name = "latency_s"

    def __init__(self, target: TargetSpec | str, batch: int = 1, manager: Optional[HardwareManager] = None):
        self.generator = XLAGenerator(target)
        self.manager = manager or HardwareManager()
        self.batch = batch
        self._cache: Dict[str, float] = {}

    def estimate(self, candidate: BuiltModel, context=None) -> float:
        sig = candidate.arch.signature() if candidate.arch else str(id(candidate))
        if sig in self._cache:
            return self._cache[sig]
        l, c = candidate.input_shape[-1], candidate.input_shape[0]
        x = jnp.zeros((self.batch, l, c), jnp.float32)
        params = candidate.init(jax.random.PRNGKey(0))
        artifact = self.generator.generate(candidate.apply, (params, x))
        result = self.manager.benchmark(artifact, (params, x))
        latency = result["latency_s"]
        self._cache[sig] = latency
        return latency


class CompiledMemoryEstimator(Estimator):
    name = "peak_bytes"

    def __init__(self, target: TargetSpec | str, batch: int = 1):
        self.generator = XLAGenerator(target)
        self.batch = batch

    def estimate(self, candidate: BuiltModel, context=None) -> float:
        l, c = candidate.input_shape[-1], candidate.input_shape[0]
        x = jnp.zeros((self.batch, l, c), jnp.float32)
        params = candidate.init(jax.random.PRNGKey(0))
        artifact = self.generator.generate(candidate.apply, (params, x))
        return float(artifact.memory.get("peak_bytes_per_device", 0))


class TrainedAccuracyEstimator(Estimator):
    """Short-budget training + validation accuracy (maximize).

    context/data: {"x_train", "y_train", "x_val", "y_val"}.  Reports
    intermediate accuracy to the trial for pruning when provided.
    """

    name = "val_accuracy"

    def __init__(self, steps: int = 60, batch: int = 32, lr: float = 1e-3,
                 report_every: int = 20):
        self.steps = steps
        self.batch = batch
        self.lr = lr
        self.report_every = report_every

    def estimate(self, candidate: BuiltModel, context=None) -> float:
        data = (context or {}).get("data")
        assert data is not None, "TrainedAccuracyEstimator needs context['data']"
        trial = (context or {}).get("trial")
        x_train, y_train = data["x_train"], data["y_train"]
        x_val, y_val = data["x_val"], data["y_val"]
        params = candidate.init(jax.random.PRNGKey(0))

        def loss_fn(p, xb, yb):
            logits = candidate.apply(p, xb)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, yb[:, None], axis=-1)[:, 0]
            return jnp.mean(logz - gold)

        @jax.jit
        def step(p, xb, yb):
            loss, g = jax.value_and_grad(loss_fn)(p, xb, yb)
            p = jax.tree_util.tree_map(lambda w, gw: w - self.lr * gw, p, g)
            return p, loss

        @jax.jit
        def accuracy(p, xb, yb):
            pred = jnp.argmax(candidate.apply(p, xb), axis=-1)
            return jnp.mean((pred == yb).astype(jnp.float32))

        rng = np.random.default_rng(0)
        n = x_train.shape[0]
        for i in range(self.steps):
            idx = rng.integers(0, n, self.batch)
            params, _ = step(params, x_train[idx], y_train[idx])
            if trial is not None and (i + 1) % self.report_every == 0:
                acc = float(accuracy(params, x_val, y_val))
                trial.report(i + 1, -acc)  # studies minimize by default
                if trial.should_prune():
                    from repro.search.study import TrialPruned

                    raise TrialPruned()
        return float(accuracy(params, x_val, y_val))
