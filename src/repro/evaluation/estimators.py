"""Cost + performance estimators (paper §V).

Cost estimators:
  * ParamCountEstimator / FlopsEstimator / ActivationMemoryEstimator —
    analytical, from BuiltModel metadata (fast, no compilation)
  * CompiledLatencyEstimator — hardware-in-the-loop: generates the
    artifact for a TargetSpec via the XLA generator and returns measured
    wall-clock (host backend) or roofline-modelled latency (TPU targets)
  * CompiledMemoryEstimator — per-device peak bytes from memory_analysis

Performance estimators:
  * TrainedAccuracyEstimator — trains the candidate briefly on a provided
    dataset and returns validation accuracy (supports trial.report/pruning)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.builder import BuiltModel
from repro.evaluation.api import Estimator
from repro.evaluation.cache import EvaluationCache
from repro.explorer.registry import ESTIMATORS
from repro.hwgen.autotune import ScheduleTuner, discover_kernel_calls
from repro.hwgen.generator import HardwareManager, XLAGenerator
from repro.hwgen.roofline import roofline_terms
from repro.hwgen.targets import TargetSpec
from repro.kernels import schedule as ksched


@ESTIMATORS.register("n_params")
class ParamCountEstimator(Estimator):
    name = "n_params"

    def estimate(self, candidate: BuiltModel, context=None) -> float:
        return float(candidate.n_params)


@ESTIMATORS.register("flops")
class FlopsEstimator(Estimator):
    name = "flops"

    def estimate(self, candidate: BuiltModel, context=None) -> float:
        return float(candidate.flops)


@ESTIMATORS.register("activation_bytes")
class ActivationMemoryEstimator(Estimator):
    """Analytical activation footprint: max layer output size (batch 1)."""

    name = "activation_bytes"
    bytes_per_el = 4

    def estimate(self, candidate: BuiltModel, context=None) -> float:
        peak = max((math.prod(l.out_shape) for l in candidate.layers), default=0)
        return float(peak * self.bytes_per_el)


class _CompiledEstimator(Estimator):
    """Shared machinery for estimators that need a compiled artifact.

    The generated artifact and the derived values are memoized in an
    :class:`EvaluationCache` keyed by the candidate's *full* architecture
    signature (layers + pre-processing) plus the batch size and a cache
    scope.  Passing the same cache instance to several estimators makes
    them share artifacts: latency and memory for one candidate cost one
    compile.  ``cache`` may also be a store-directory path (or ``True``
    for the default ``results/cache/``), which wraps a fresh cache around
    the disk-persistent tier so values survive restarts.

    **Cache scoping (cross-target reuse).**  A compiled XLA program
    depends only on the target's mesh topology — chip constants enter
    the roofline arithmetic *after* compilation — so compile-derived
    entries (the artifact, peak bytes, the roofline terms behind
    ``metric="modelled"``) are scoped by ``TargetSpec.mesh_scope``
    rather than the target name.  Two targets sharing a topology (e.g.
    the single-chip ``host_cpu`` and ``edge_npu``) therefore reuse each
    other's compiles: a sweep's second target recompiles nothing for
    candidates its first target already paid for.  Host-specific
    *measurements* (``metric="measured"`` wall clock) stay scoped by
    target name — they are properties of the deployment, not of the
    program.  (Scope strings changed when this landed, so older disk
    stores structurally miss and recompute once — same migration
    behaviour as a toolchain upgrade.)
    """

    def __init__(self, target: TargetSpec | str, batch: int = 1,
                 cache: Optional[EvaluationCache | str] = None,
                 tuner: Optional[ScheduleTuner] = None):
        self.generator = XLAGenerator(target)
        self.batch = batch
        if cache is None:
            cache = EvaluationCache()
        elif not isinstance(cache, EvaluationCache):
            cache = EvaluationCache(disk=cache)
        self.cache = cache
        self.tuner = tuner
        # a disk-tiered cache also gets the content-addressed executable
        # store: compiled programs persist next to the scalar values, so
        # a server booting --from-report after this exploration performs
        # zero XLA compiles (REPRO_ARTIFACTS=0 opts out)
        self.artifacts = None
        if cache.disk is not None:
            from repro.evaluation.artifact_store import (
                ArtifactStore, store_enabled)

            if store_enabled():
                self.artifacts = ArtifactStore(cache.disk.path)

    def _program_key(self, name: str, candidate: BuiltModel, sig=None):
        """Key for chip-independent, compile-derived values: scoped by
        mesh topology so targets sharing one reuse each other's entries.
        ``sig`` is the *effective* kernel-schedule signature — requested
        schedules that clamp to the same launch share one entry, and two
        that clamp apart never collide.  ``None`` (no tuning, no context
        schedules) keeps the legacy key shape byte-for-byte."""
        key = (name, self.generator.target.mesh_scope, self.batch,
               EvaluationCache.candidate_key(candidate))
        return key if sig is None else key + (("sched", sig),)

    def _target_key(self, name: str, candidate: BuiltModel, sig=None):
        """Key for deployment-specific values (wall-clock measurements)."""
        key = (name, self.generator.target.name, self.batch,
               EvaluationCache.candidate_key(candidate))
        return key if sig is None else key + (("sched", sig),)

    def _schedule_plan(self, candidate: BuiltModel, context=None):
        """(schedules, effective-signature) for this candidate.

        ``(None, None)`` — the common untuned path — when no schedules
        arrived via context (``kernel_tuning.mode: search`` trial params)
        and no tuner is attached, or when an abstract trace shows the
        candidate reaches no schedulable kernel: cache keys then stay
        exactly the legacy shape.  Otherwise the plan is: per discovered
        kernel, context schedule > tuner override > tuned winner, and the
        signature is taken from a second recording ``eval_shape`` pass so
        it reflects the *effective* (shape-clamped) launches."""
        from_context = (context or {}).get("schedules")
        if from_context is None and self.tuner is None:
            return None, None
        l, c = candidate.input_shape[-1], candidate.input_shape[0]
        x = jax.ShapeDtypeStruct((self.batch, l, c), jnp.float32)
        params = jax.eval_shape(candidate.init, jax.random.PRNGKey(0))
        calls = discover_kernel_calls(candidate.apply, (params, x))
        if not calls:
            return None, None
        plan: Dict[str, ksched.KernelSchedule] = {}
        for entry in calls.values():
            kernel = entry["kernel"]
            if kernel in plan:
                continue
            if from_context and kernel in from_context:
                plan[kernel] = ksched.as_schedule(kernel, from_context[kernel])
            elif self.tuner is not None:
                if kernel in self.tuner.overrides:
                    plan[kernel] = self.tuner.overrides[kernel]
                else:
                    record = self.tuner.tune(kernel, entry["shapes"],
                                             entry["meta"])
                    plan[kernel] = ksched.as_schedule(kernel,
                                                      record["schedule"])
            else:
                plan[kernel] = ksched.default_schedule(kernel)
        sink: Dict = {}
        with ksched.use_schedules(plan), ksched.record_kernel_calls(sink):
            jax.eval_shape(candidate.apply, params, x)
        sig = ksched.effective_signature(sink)
        trial = (context or {}).get("trial")
        set_attr = getattr(trial, "set_user_attr", None)
        if set_attr is not None:
            set_attr("kernel_schedules",
                     {k: s.to_dict() for k, s in sorted(plan.items())})
        return plan, sig

    def _artifact(self, candidate: BuiltModel, plan=None):
        schedules, sig = plan if plan is not None else (None, None)
        l, c = candidate.input_shape[-1], candidate.input_shape[0]
        x = jnp.zeros((self.batch, l, c), jnp.float32)
        params = candidate.init(jax.random.PRNGKey(0))
        key = self._program_key("artifact", candidate, sig)

        def produce():
            # store read-through first: a previous process's compile (or
            # a sibling worker's) loads as a deserialized executable and
            # never touches the XLA compiler; writes go through so the
            # next process warm-loads what this one paid for
            if self.artifacts is not None:
                loaded = self.artifacts.get(key, target=self.generator.target)
                if loaded is not None:
                    return loaded
            generated = self.generator.generate(
                candidate.apply, (params, x), schedules=schedules)
            if self.artifacts is not None:
                self.artifacts.put(key, generated)
            return generated

        artifact = self.cache.get_or_compute(key, produce)
        target = self.generator.target
        if artifact.target is not target:
            # the cached artifact was compiled by a sibling target sharing
            # this mesh topology: the program is identical, but its
            # target-dependent view (TargetSpec, roofline) is theirs —
            # rebind to OURS so measurement dispatch, chip constants, and
            # fits_memory are correct for this estimator's target
            artifact = dataclasses.replace(
                artifact, target=target,
                roofline=roofline_terms(
                    hlo_flops=artifact.flops,
                    hlo_bytes=artifact.bytes_accessed,
                    collective_bytes=artifact.collective_bytes,
                    n_chips=1, chip=target.chip))
        return artifact, (params, x)


@ESTIMATORS.register("latency_s")
class CompiledLatencyEstimator(_CompiledEstimator):
    """Hardware-in-the-loop latency via the generator pipeline (paper §VI
    mode 2).  Results are cached by full architecture signature.

    ``metric="measured"`` returns the HardwareManager result (wall-clock
    on host targets); ``metric="modelled"`` returns the roofline bound of
    the compiled program — deterministic across runs, which is what
    reproducible serial-vs-parallel comparisons need.
    """

    name = "latency_s"

    def __init__(self, target: TargetSpec | str, batch: int = 1,
                 manager: Optional[HardwareManager] = None,
                 cache: Optional[EvaluationCache | str] = None,
                 metric: str = "measured",
                 tuner: Optional[ScheduleTuner] = None):
        super().__init__(target, batch=batch, cache=cache, tuner=tuner)
        if metric not in ("measured", "modelled"):
            # a real raise, not an assert: metric is reachable from YAML
            # experiment specs, and asserts vanish under ``python -O``
            raise ValueError(
                f"unknown latency metric {metric!r}; expected 'measured' or 'modelled'"
            )
        self.manager = manager or HardwareManager()
        self.metric = metric

    def estimate(self, candidate: BuiltModel, context=None) -> float:
        plan = self._schedule_plan(candidate, context)
        sig = plan[1]
        if self.metric == "modelled":
            # cache the chip-independent program quantities and apply the
            # target's chip constants afterwards: a second target with
            # the same mesh topology gets its modelled latency from the
            # cached terms without compiling anything
            def compute_terms():
                artifact, _ = self._artifact(candidate, plan)
                return [float(artifact.flops), float(artifact.bytes_accessed),
                        float(artifact.collective_bytes)]

            terms = self.cache.get_or_compute(
                self._program_key("roofline_terms", candidate, sig),
                compute_terms)
            report = roofline_terms(
                hlo_flops=terms[0], hlo_bytes=terms[1],
                collective_bytes=terms[2], n_chips=1,
                chip=self.generator.target.chip)
            return float(report.bound_s)

        def compute() -> float:
            artifact, concrete = self._artifact(candidate, plan)
            return float(self.manager.benchmark(artifact, concrete)["latency_s"])

        return self.cache.get_or_compute(
            ("measured",) + self._target_key(self.name, candidate, sig),
            compute)


@ESTIMATORS.register("peak_bytes")
class CompiledMemoryEstimator(_CompiledEstimator):
    name = "peak_bytes"

    def estimate(self, candidate: BuiltModel, context=None) -> float:
        plan = self._schedule_plan(candidate, context)

        def compute() -> float:
            artifact, _ = self._artifact(candidate, plan)
            return float(artifact.memory.get("peak_bytes_per_device", 0))

        # memory_analysis is a property of the compiled program, not the
        # chip, so targets sharing a mesh topology share the entry
        return self.cache.get_or_compute(
            self._program_key(self.name, candidate, plan[1]), compute)


@ESTIMATORS.register("val_accuracy")
class TrainedAccuracyEstimator(Estimator):
    """Short-budget training + validation accuracy (maximize).

    context/data: {"x_train", "y_train", "x_val", "y_val"}.  Reports
    intermediate accuracy to the trial for pruning when provided.
    """

    name = "val_accuracy"

    def __init__(self, steps: int = 60, batch: int = 32, lr: float = 1e-3,
                 momentum: float = 0.9, report_every: int = 20):
        self.steps = steps
        self.batch = batch
        self.lr = lr
        self.momentum = momentum
        self.report_every = report_every

    def estimate(self, candidate: BuiltModel, context=None) -> float:
        data = (context or {}).get("data")
        assert data is not None, "TrainedAccuracyEstimator needs context['data']"
        trial = (context or {}).get("trial")
        x_train, y_train = data["x_train"], data["y_train"]
        x_val, y_val = data["x_val"], data["y_val"]
        params = candidate.init(jax.random.PRNGKey(0))

        def loss_fn(p, xb, yb):
            logits = candidate.apply(p, xb)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, yb[:, None], axis=-1)[:, 0]
            return jnp.mean(logz - gold)

        @jax.jit
        def step(p, m, xb, yb):
            loss, g = jax.value_and_grad(loss_fn)(p, xb, yb)
            m = jax.tree_util.tree_map(lambda mw, gw: self.momentum * mw + gw, m, g)
            p = jax.tree_util.tree_map(lambda w, mw: w - self.lr * mw, p, m)
            return p, m, loss

        @jax.jit
        def accuracy(p, xb, yb):
            pred = jnp.argmax(candidate.apply(p, xb), axis=-1)
            return jnp.mean((pred == yb).astype(jnp.float32))

        rng = np.random.default_rng(0)
        n = x_train.shape[0]
        momentum = jax.tree_util.tree_map(jnp.zeros_like, params)
        for i in range(self.steps):
            idx = rng.integers(0, n, self.batch)
            params, momentum, _ = step(params, momentum, x_train[idx], y_train[idx])
            if trial is not None and (i + 1) % self.report_every == 0:
                acc = float(accuracy(params, x_val, y_val))
                trial.report(i + 1, -acc)  # studies minimize by default
                if trial.should_prune():
                    from repro.search.study import TrialPruned

                    raise TrialPruned()
        return float(accuracy(params, x_val, y_val))
