"""Evaluation API (paper §V): estimators + optimization criteria.

Estimators are independent of the NAS workflow; each produces one scalar
for a candidate.  They can be used directly as study objectives or
registered as :class:`OptimizationCriteria` with a kind:

  * ``objective``        — enters the scalarized score
  * ``soft_constraint``  — enters the score via hinge penalty above target
  * ``hard_constraint``  — checked FIRST; violation terminates the trial
                           early (staged evaluation)

Scalarization defaults to a weighted sum; a custom aggregator can be
injected (paper: "custom optimization aggregation functions").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.search.study import HardConstraintViolated


class Estimator:
    """Base class: estimate(candidate, context) -> float."""

    name: str = "estimator"

    def estimate(self, candidate: Any, context: Optional[Dict] = None) -> float:
        raise NotImplementedError


@dataclasses.dataclass
class OptimizationCriteria:
    estimator: Estimator
    kind: str = "objective"  # objective | soft_constraint | hard_constraint
    direction: str = "minimize"  # objectives only
    weight: float = 1.0
    limit: Optional[float] = None  # constraints: threshold

    KINDS = ("objective", "soft_constraint", "hard_constraint")
    DIRECTIONS = ("minimize", "maximize")

    def __post_init__(self):
        # real raises, not asserts: criteria frequently come from config
        # (YAML experiments), and asserts vanish under ``python -O``
        if self.kind not in self.KINDS:
            raise ValueError(
                f"unknown criteria kind {self.kind!r}; expected one of {self.KINDS}"
            )
        if self.direction not in self.DIRECTIONS:
            raise ValueError(
                f"unknown direction {self.direction!r}; expected one of {self.DIRECTIONS}"
            )
        if self.kind != "objective" and self.limit is None:
            raise ValueError(f"{self.kind} requires a limit")


def weighted_sum(values: Dict[str, float], criteria: List[OptimizationCriteria]) -> float:
    """Default scalarization: weighted sum; soft constraints add a hinge
    penalty proportional to relative violation."""
    score = 0.0
    by_name = {c.estimator.name: c for c in criteria}
    for name, v in values.items():
        c = by_name[name]
        if c.kind == "objective":
            score += c.weight * (v if c.direction == "minimize" else -v)
        elif c.kind == "soft_constraint":
            score += c.weight * max(0.0, (v - c.limit) / max(abs(c.limit), 1e-12))
    return score


class CriteriaRunner:
    """Staged evaluation: hard constraints first (early termination),
    then objectives + soft constraints, then scalarization."""

    def __init__(
        self,
        criteria: Sequence[OptimizationCriteria],
        aggregator: Callable[[Dict[str, float], List[OptimizationCriteria]], float] = weighted_sum,
        cache=None,
    ):
        self.criteria = list(criteria)
        # values (and the weighted_sum aggregation) key by estimator name:
        # two criteria sharing a name would silently overwrite each other,
        # dropping one from the score — fail loudly at construction instead
        by_name: Dict[str, OptimizationCriteria] = {}
        for c in self.criteria:
            name = c.estimator.name
            if name in by_name:
                raise ValueError(
                    f"criteria share estimator name {name!r}: {by_name[name]!r} "
                    f"and {c!r} — values aggregate by name, so one would be "
                    f"silently dropped; give the estimators distinct .name values"
                )
            by_name[name] = c
        self.aggregator = aggregator
        # One shared EvaluationCache for every compiled-cost estimator in
        # the runner: candidates evaluated under several criteria (e.g.
        # latency soft constraint + memory hard constraint) compile once.
        self.cache = cache
        if cache is not None:
            for c in self.criteria:
                if hasattr(c.estimator, "cache"):
                    c.estimator.cache = cache

    def evaluate(self, candidate: Any, context: Optional[Dict] = None, trial=None) -> float:
        context = context or {}
        values: Dict[str, float] = {}
        # stage 1: hard constraints
        for c in self.criteria:
            if c.kind != "hard_constraint":
                continue
            v = float(c.estimator.estimate(candidate, context))
            values[c.estimator.name] = v
            if trial is not None:
                trial.set_user_attr(c.estimator.name, v)
            if v > c.limit:
                raise HardConstraintViolated(c.estimator.name, v, c.limit)
        # stage 2: objectives + soft constraints
        for c in self.criteria:
            if c.kind == "hard_constraint":
                continue
            v = float(c.estimator.estimate(candidate, context))
            values[c.estimator.name] = v
            if trial is not None:
                trial.set_user_attr(c.estimator.name, v)
        return self.aggregator(values, self.criteria)

    def evaluate_multi(self, candidate: Any, context: Optional[Dict] = None, trial=None):
        """Multi-objective form: returns the tuple of objective values
        (hard constraints still terminate early)."""
        context = context or {}
        for c in self.criteria:
            if c.kind == "hard_constraint":
                v = float(c.estimator.estimate(candidate, context))
                if trial is not None:
                    trial.set_user_attr(c.estimator.name, v)
                if v > c.limit:
                    raise HardConstraintViolated(c.estimator.name, v, c.limit)
        out = []
        for c in self.criteria:
            if c.kind == "objective":
                v = float(c.estimator.estimate(candidate, context))
                if trial is not None:
                    trial.set_user_attr(c.estimator.name, v)
                out.append(v)
        return tuple(out)
