"""Evaluation API (paper §V): estimators + optimization criteria.

Estimators are independent of the NAS workflow; each produces one scalar
for a candidate.  They can be used directly as study objectives or
registered as :class:`OptimizationCriteria` with a kind:

  * ``objective``        — enters the scalarized score
  * ``soft_constraint``  — enters the score via a direction-aware hinge
                           penalty (minimize: above the limit; maximize:
                           below it)
  * ``hard_constraint``  — checked FIRST; violation terminates the trial
                           early (staged evaluation); direction-aware
                           like the hinge, so "val_accuracy >= 0.9" is
                           ``direction="maximize", limit=0.9``

Scalarization defaults to a weighted sum; a custom aggregator can be
injected (paper: "custom optimization aggregation functions").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.search.study import HardConstraintViolated


class Estimator:
    """Base class: estimate(candidate, context) -> float."""

    name: str = "estimator"

    def estimate(self, candidate: Any, context: Optional[Dict] = None) -> float:
        raise NotImplementedError


@dataclasses.dataclass
class OptimizationCriteria:
    estimator: Estimator
    kind: str = "objective"  # objective | soft_constraint | hard_constraint
    # objectives: which way the score folds the value; constraints: which
    # side of ``limit`` violates (minimize: value must stay <= limit,
    # maximize: value must stay >= limit — "val_accuracy >= 0.9" is
    # ``direction="maximize", limit=0.9``)
    direction: str = "minimize"
    weight: float = 1.0
    limit: Optional[float] = None  # constraints: threshold

    KINDS = ("objective", "soft_constraint", "hard_constraint")
    DIRECTIONS = ("minimize", "maximize")

    def __post_init__(self):
        # real raises, not asserts: criteria frequently come from config
        # (YAML experiments), and asserts vanish under ``python -O``
        if self.kind not in self.KINDS:
            raise ValueError(
                f"unknown criteria kind {self.kind!r}; expected one of {self.KINDS}"
            )
        if self.direction not in self.DIRECTIONS:
            raise ValueError(
                f"unknown direction {self.direction!r}; expected one of {self.DIRECTIONS}"
            )
        if self.kind != "objective" and self.limit is None:
            raise ValueError(f"{self.kind} requires a limit")


def constraint_violation(criterion: OptimizationCriteria, value: float) -> float:
    """Relative violation of a constraint criterion: positive when the
    constraint is violated, scaled by ``|limit|`` so hinge penalties are
    comparable across criteria of different magnitudes.  Honors the
    criterion's ``direction``: a minimize constraint violates above its
    limit, a maximize constraint below it."""
    scale = max(abs(criterion.limit), 1e-12)
    if criterion.direction == "minimize":
        return (value - criterion.limit) / scale
    return (criterion.limit - value) / scale


def weighted_sum(values: Dict[str, float], criteria: List[OptimizationCriteria]) -> float:
    """Default scalarization: weighted sum; soft constraints add a hinge
    penalty proportional to relative violation (direction-aware, see
    :func:`constraint_violation`)."""
    score = 0.0
    by_name = {c.estimator.name: c for c in criteria}
    for name, v in values.items():
        c = by_name[name]
        if c.kind == "objective":
            score += c.weight * (v if c.direction == "minimize" else -v)
        elif c.kind == "soft_constraint":
            score += c.weight * max(0.0, constraint_violation(c, v))
    return score


def check_distinct_names(criteria: Sequence[OptimizationCriteria]) -> None:
    """Values (and the weighted_sum aggregation) key by estimator name:
    two criteria sharing a name would silently overwrite each other,
    dropping one from the score — fail loudly at construction instead."""
    by_name: Dict[str, OptimizationCriteria] = {}
    for c in criteria:
        name = c.estimator.name
        if name in by_name:
            raise ValueError(
                f"criteria share estimator name {name!r}: {by_name[name]!r} "
                f"and {c!r} — values aggregate by name, so one would be "
                f"silently dropped; give the estimators distinct .name values"
            )
        by_name[name] = c


class CriteriaRunner:
    """Staged evaluation: hard constraints first (early termination),
    then objectives + soft constraints, then scalarization.

    This is the degenerate single-stage case of the fidelity cascade: a
    :class:`~repro.evaluation.cascade.CascadeRunner` with no screening
    stages evaluates exactly like a ``CriteriaRunner`` over its final
    stage (``CascadeRunner`` subclasses this class and inherits both
    evaluation paths unchanged)."""

    def __init__(
        self,
        criteria: Sequence[OptimizationCriteria],
        aggregator: Callable[[Dict[str, float], List[OptimizationCriteria]], float] = weighted_sum,
        cache=None,
    ):
        self.criteria = list(criteria)
        check_distinct_names(self.criteria)
        self.aggregator = aggregator
        # One shared EvaluationCache for every compiled-cost estimator in
        # the runner: candidates evaluated under several criteria (e.g.
        # latency soft constraint + memory hard constraint) compile once.
        self.cache = cache
        if cache is not None:
            for c in self.criteria:
                if hasattr(c.estimator, "cache"):
                    c.estimator.cache = cache

    def _staged_values(self, candidate: Any, context: Dict, trial,
                       later_kinds: Sequence[str]) -> Dict[str, float]:
        """The one staged iteration both evaluation paths share: hard
        constraints run FIRST in declaration order (violation terminates
        the trial before any expensive later-kind estimator runs), then
        the ``later_kinds`` in declaration order.  Every computed value is
        recorded on ``trial`` (when given) under the estimator's name."""
        values: Dict[str, float] = {}

        def record(c: OptimizationCriteria) -> float:
            v = float(c.estimator.estimate(candidate, context))
            values[c.estimator.name] = v
            if trial is not None:
                trial.set_user_attr(c.estimator.name, v)
            return v

        for c in self.criteria:
            if c.kind == "hard_constraint":
                v = record(c)
                if constraint_violation(c, v) > 0.0:
                    raise HardConstraintViolated(c.estimator.name, v, c.limit,
                                                 direction=c.direction)
        for c in self.criteria:
            if c.kind in later_kinds:
                record(c)
        return values

    def evaluate(self, candidate: Any, context: Optional[Dict] = None, trial=None) -> float:
        values = self._staged_values(candidate, context or {}, trial,
                                     ("objective", "soft_constraint"))
        return self.aggregator(values, self.criteria)

    def evaluate_multi(self, candidate: Any, context: Optional[Dict] = None, trial=None):
        """Multi-objective form: returns the tuple of objective values
        (hard constraints still terminate early)."""
        values = self._staged_values(candidate, context or {}, trial,
                                     ("objective",))
        return tuple(values[c.estimator.name]
                     for c in self.criteria if c.kind == "objective")
