"""Traffic-shaped serving estimators (the serving-path counterpart of
the latency/memory estimators).

A candidate that wins on single-request kernel time can still lose in
production: tail latency and throughput depend on how requests arrive,
how long their prompts are, and how the engine batches.  These
estimators rank candidates under the experiment's **declared traffic
mix** (the validated ``serving:`` section, injected by the Explorer as
the ``serving`` kwarg):

  * ``prefill_latency_s`` — roofline bound of one full-batch prompt
    forward of the *compiled* program at ``(max_batch, L, C)``
  * ``decode_latency_s`` — analytic per-step decode bound: per-token
    FLOPs vs the parameter + decode-state bytes streamed every step
  * ``kv_cache_peak_bytes`` — peak decode-state footprint the traffic
    actually reaches (simulated concurrency × per-layer state metadata)
  * ``throughput_tok_s`` / ``p99_latency_s`` — summary of a
    discrete-event simulation of the continuous-batching engine
    (:class:`repro.launch.traffic.ServingSim`) under the declared mix

Every value is a deterministic pure function of (program, chip
constants, serving spec): the simulator advances a modelled clock, never
a wall clock, so fixed-seed sweeps produce identical rankings on the
serial and process backends.  The single compile behind
``prefill_latency_s`` flows through the shared evaluation cache and the
content-addressed artifact store like any other compiled estimator.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Optional

from repro.core.builder import BuiltModel
from repro.evaluation.cache import EvaluationCache
from repro.evaluation.estimators import _CompiledEstimator
from repro.explorer.registry import ESTIMATORS
from repro.hwgen.autotune import ScheduleTuner
from repro.hwgen.roofline import roofline_terms
from repro.hwgen.targets import TargetSpec
from repro.launch.traffic import ServingCosts, ServingSim


def resolve_serving(serving: Any):
    """Normalize the injected ``serving`` value to a ServingSpec: the
    spec object itself, a raw mapping, or None (all defaults)."""
    from repro.explorer.experiment import ServingSpec

    if serving is None:
        return ServingSpec()
    if isinstance(serving, ServingSpec):
        return serving
    spec = ServingSpec.from_raw(serving)
    return spec if spec is not None else ServingSpec()


class _ServingEstimator(_CompiledEstimator):
    """Shared machinery: compiled prefill terms + analytic decode costs
    + the memoized traffic simulation, all under the shared cache."""

    def __init__(self, target: TargetSpec | str,
                 serving: Any = None,
                 cache: Optional[EvaluationCache | str] = None,
                 tuner: Optional[ScheduleTuner] = None):
        spec = resolve_serving(serving)
        super().__init__(target, batch=spec.max_batch, cache=cache,
                         tuner=tuner)
        self.serving = spec
        # the spec is part of every derived value's identity
        self._serving_sig = json.dumps(spec.to_dict(), sort_keys=True,
                                       separators=(",", ":"))

    # -- modelled costs ------------------------------------------------------

    def _forward_terms(self, candidate: BuiltModel, plan):
        """Chip-independent (flops, bytes, collective) of the compiled
        full-batch forward; shares the cache entry (and the artifact
        store blob) with every other compiled estimator at this batch."""
        def compute_terms():
            artifact, _ = self._artifact(candidate, plan)
            return [float(artifact.flops), float(artifact.bytes_accessed),
                    float(artifact.collective_bytes)]

        return self.cache.get_or_compute(
            self._program_key("roofline_terms", candidate, plan[1]),
            compute_terms)

    def _prefill_bound_s(self, candidate: BuiltModel, plan) -> float:
        """Roofline bound of one (max_batch, L, C) prompt forward."""
        terms = self._forward_terms(candidate, plan)
        report = roofline_terms(
            hlo_flops=terms[0], hlo_bytes=terms[1],
            collective_bytes=terms[2], n_chips=1,
            chip=self.generator.target.chip)
        return float(report.bound_s)

    def _decode_step_s(self, candidate: BuiltModel) -> float:
        """Analytic bound of one continuous-batching decode step: the
        whole active batch advances one token.  Compute scales with the
        batch; memory streams the parameters once per step plus each
        sequence's decode state at the traffic's mean context depth."""
        spec = self.serving
        chip = self.generator.target.chip
        seq_len = max(1, int(candidate.input_shape[-1]))
        flops_per_token = candidate.flops / seq_len
        mean_prompt = sum(l * w for l, w in spec.traffic.prompt_lens.items())
        mean_gen = sum(l * w for l, w in spec.traffic.gen_lens.items())
        mean_ctx = mean_prompt + 0.5 * mean_gen
        state_bytes = spec.max_batch * spec.dtype_bytes * (
            candidate.state_elems_fixed
            + candidate.state_elems_per_token * mean_ctx)
        param_bytes = candidate.n_params * 4  # f32 weights
        compute_s = spec.max_batch * flops_per_token / chip.peak_flops_bf16
        memory_s = (param_bytes + state_bytes) / chip.hbm_bandwidth
        return max(compute_s, memory_s)

    # -- the traffic simulation ---------------------------------------------

    def _simulate(self, candidate: BuiltModel, context=None) -> Dict[str, Any]:
        plan = self._schedule_plan(candidate, context)
        spec = self.serving
        prefill_bound = self._prefill_bound_s(candidate, plan)
        seq_len = max(1, int(candidate.input_shape[-1]))
        costs = ServingCosts(
            prefill_s_per_token=prefill_bound / (spec.max_batch * seq_len),
            decode_step_s=self._decode_step_s(candidate),
        )

        def run():
            sim = ServingSim(max_batch=spec.max_batch,
                             queue_limit=spec.queue_limit)
            summary = sim.run(spec.traffic.requests(), costs)
            summary.pop("shed_ids", None)  # keys must stay JSON-scalar-ish
            return summary

        key = self._program_key("serving_sim", candidate, plan[1]) \
            + (("serving", self._serving_sig),)
        return self.cache.get_or_compute(key, run)


@ESTIMATORS.register("prefill_latency_s")
class PrefillLatencyEstimator(_ServingEstimator):
    """Modelled latency of one full-batch prompt forward (the engine's
    prefill step) of the compiled program at ``(max_batch, L, C)``."""

    name = "prefill_latency_s"

    def estimate(self, candidate: BuiltModel, context=None) -> float:
        plan = self._schedule_plan(candidate, context)
        return self._prefill_bound_s(candidate, plan)


@ESTIMATORS.register("decode_latency_s")
class DecodeLatencyEstimator(_ServingEstimator):
    """Analytic per-step decode latency at the declared concurrency:
    max(compute, parameter + decode-state bandwidth) per engine step."""

    name = "decode_latency_s"

    def estimate(self, candidate: BuiltModel, context=None) -> float:
        return self._decode_step_s(candidate)


@ESTIMATORS.register("kv_cache_peak_bytes")
class KVCachePeakBytesEstimator(_ServingEstimator):
    """Peak decode-state bytes the declared traffic actually reaches:
    simulated peak cached tokens × per-token state elements, plus the
    fixed (context-independent) state of every concurrently-active
    sequence."""

    name = "kv_cache_peak_bytes"

    def estimate(self, candidate: BuiltModel, context=None) -> float:
        summary = self._simulate(candidate, context)
        spec = self.serving
        grown = summary["kv_peak_tokens"] * candidate.state_elems_per_token
        fixed = summary["peak_concurrency"] * candidate.state_elems_fixed
        return float((grown + fixed) * spec.dtype_bytes)


@ESTIMATORS.register("throughput_tok_s")
class ThroughputEstimator(_ServingEstimator):
    """Decoded tokens per second over the simulated run (maximize)."""

    name = "throughput_tok_s"

    def estimate(self, candidate: BuiltModel, context=None) -> float:
        return float(self._simulate(candidate, context)["throughput_tok_s"])


@ESTIMATORS.register("p99_latency_s")
class P99LatencyEstimator(_ServingEstimator):
    """99th-percentile request latency (arrival to last token) under the
    declared traffic mix — the serving criterion sweeps rank by."""

    name = "p99_latency_s"

    def estimate(self, candidate: BuiltModel, context=None) -> float:
        return float(self._simulate(candidate, context)["p99_latency_s"])
