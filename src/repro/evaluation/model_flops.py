"""MODEL_FLOPS = 6*N*D accounting (dense) / 6*N_active*D (MoE).

``N`` counts matmul-participating parameters: embeddings and learned
positional tables are excluded (gather, not matmul), the LM head is
included (tied heads therefore add the embed matrix back once).  MoE
expert weights are scaled by top_k/n_experts (+ capacity slack is real
compute but excluded from the *model* flops definition — the gap shows up
in the useful-ratio column instead, which is the point of that column).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.lm import LM
from repro.models.specs import ModelSpec
from repro.nn.types import split


def active_matmul_params(spec: ModelSpec) -> int:
    """Parameters participating in per-token matmuls, MoE-scaled."""
    model = LM(spec)
    annotated = jax.eval_shape(
        functools.partial(model.init, dtype=jnp.bfloat16), jax.random.PRNGKey(0)
    )
    values, _ = split(annotated)
    flat, _ = jax.tree_util.tree_flatten_with_path(values)

    # locate MoE sub-blocks: (segment name, sub index) -> top_k/n_experts
    moe_scale = {}
    for seg in model.segments:
        for i, sub in enumerate(seg.spec.subs):
            if sub.kind == "moe":
                moe_scale[(seg.name, f"sub_{i}")] = sub.cfg.top_k / sub.cfg.n_experts

    active = 0
    for path, leaf in flat:
        keys = [str(getattr(p, "key", "")) for p in path]
        n = int(leaf.size)
        if keys[0] == "pos_embed":
            continue
        if keys[0] == "embed":
            if spec.tie_embeddings:
                active += n  # used once as the LM head matmul
            continue
        scale = 1.0
        if len(keys) >= 2 and (keys[0], keys[1]) in moe_scale:
            # expert tensors have an experts dim; router + dense residual
            # within the moe params are always active
            if keys[-1] in ("w_up", "w_gate", "w_down") and "dense" not in keys:
                scale = moe_scale[(keys[0], keys[1])]
        active += int(n * scale)
    return active


def model_flops(spec: ModelSpec, kind: str, batch: int, seq: int) -> float:
    """Global MODEL_FLOPS for one step of the given cell kind."""
    n = active_matmul_params(spec)
    if kind == "train":
        tokens = batch * seq
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = batch * seq
        return 2.0 * n * tokens
    if kind == "decode":
        tokens = batch * 1
        return 2.0 * n * tokens
    raise ValueError(kind)
