"""Zero-cost proxy estimators: tier 0 of the fidelity cascade.

One eager pass on the *uncompiled* :class:`BuiltModel` — no
``jax.jit``, no :class:`~repro.hwgen.generator.XLAGenerator` — so a
candidate screened out by a proxy never touches the XLA compiler
(``generate_call_count()`` stays 0 for it).  The scores follow the
standard zero-cost NAS proxies (Benmeziane et al., arXiv:2101.09336
survey; Abdelfattah et al. "Zero-Cost Proxies for Lightweight NAS"):

  * ``synflow``   — sum over parameters of ``|θ ⊙ ∂R/∂θ|`` where ``R``
    is the summed output of the network run on an all-ones input with
    absolute-valued weights; computed with a single forward pass via
    the saliency-conservation identity (see
    :class:`SynFlowEstimator`), reported on a log scale so the score
    stays finite and JSON-serializable for arbitrarily deep candidates;
  * ``grad_norm`` — the global l2 norm of the loss gradient from one
    forward/backward on a fixed random batch.

Both are *rankings*, not costs: a quality-seeking screen runs them with
``direction: maximize`` (more trainable capacity survives), while a
latency-minimizing search can invert the screen with ``direction:
minimize`` — the cascade's keep rules rank the scalarized stage score
either way.

Scores are deterministic (fixed PRNG keys, fixed input) and memoized in
the shared :class:`EvaluationCache` keyed by the candidate's full
architecture signature + the proxy batch size, so they ride the same
flock-safe disk tier as compiled costs and survive restarts.  The
default batch comes from ``REPRO_PROXY_BATCH`` (see
``docs/reference/env.md``).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.builder import BuiltModel
from repro.envvars import read_env
from repro.evaluation.api import Estimator
from repro.evaluation.cache import EvaluationCache
from repro.explorer.registry import ESTIMATORS

# Small on purpose: a proxy exists to cost milliseconds next to a
# multi-second compile, and the score is a ranking — batch size barely
# moves it.  REPRO_PROXY_BATCH overrides for spaces whose first layers
# are batch-sensitive.
DEFAULT_PROXY_BATCH = 2


class ZeroCostProxy(Estimator):
    """Shared machinery: cache wiring + the eager input construction.

    Subclasses implement ``_score(candidate) -> float``; ``estimate``
    memoizes it under ``(name, batch, signature)`` — JSON-able, so the
    disk tier persists proxy scores exactly like compiled costs.
    """

    def __init__(self, batch: Optional[int] = None,
                 cache: Optional[EvaluationCache | str] = None):
        if batch is None:
            batch = read_env("REPRO_PROXY_BATCH", DEFAULT_PROXY_BATCH)
        self.batch = max(1, int(batch))
        if cache is None:
            cache = EvaluationCache()
        elif not isinstance(cache, EvaluationCache):
            cache = EvaluationCache(disk=cache)
        self.cache = cache

    def _input(self, candidate: BuiltModel, fill: str) -> jnp.ndarray:
        # mirror the compiled estimators: YAML input order is
        # (channels, length), apply() wants (batch, length, channels)
        l, c = candidate.input_shape[-1], candidate.input_shape[0]
        shape = (self.batch, l, c)
        if fill == "ones":
            return jnp.ones(shape, jnp.float32)
        return jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)

    @staticmethod
    def _apply_net(candidate: BuiltModel, params, x):
        # the layer stack only, WITHOUT the data-preprocessing stage:
        # proxies measure architecture saliency, and a normalizer maps
        # the synflow all-ones probe to a constant zero (zscore/minmax of
        # a constant input), which would zero every proxy score
        for i, layer in enumerate(candidate.layers):
            x = layer.apply(params[f"layer_{i}"], x)
        return x

    def _score(self, candidate: BuiltModel) -> float:
        raise NotImplementedError

    def estimate(self, candidate: BuiltModel, context=None) -> float:
        key = (self.name, self.batch, EvaluationCache.candidate_key(candidate))
        return self.cache.get_or_compute(
            key, lambda: float(self._score(candidate)))


@ESTIMATORS.register("synflow")
class SynFlowEstimator(ZeroCostProxy):
    """Synaptic-flow saliency (log scale) via the conservation identity.

    Synflow accumulates ``|θ ⊙ ∂R/∂θ|`` where ``R`` is the summed output
    on an all-ones input with absolute-valued weights.  Tanaka et al.
    (arXiv:2006.05467) prove layerwise saliency is *conserved*: with the
    whole network positive (abs weights, positive input, ReLU/pooling
    transparent) ``R`` is degree-1 homogeneous in each affine layer's
    weights, so every parameterized layer's saliency sum equals ``R``
    and the total is ``n_param_layers * R`` — one eager forward pass,
    no autodiff.  Bias leaves are zeroed in the probe to keep the
    identity exact (they are zero at init here anyway, so this matches
    the backward-pass formulation bit for bit); the test suite checks
    the identity against an autodiff reference."""

    name = "synflow"

    @staticmethod
    def _probe_params(candidate: BuiltModel):
        """|θ| with bias (1-D) leaves zeroed, plus the count of layers
        that carry any parameters at all."""
        params = candidate.init(jax.random.PRNGKey(0))
        probe = jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p) if p.ndim == 1 else jnp.abs(p),
            params)
        n_param_layers = sum(
            1 for layer in probe.values()
            if jax.tree_util.tree_leaves(layer))
        return probe, n_param_layers

    def _score(self, candidate: BuiltModel) -> float:
        x = self._input(candidate, "ones")
        probe, n_param_layers = self._probe_params(candidate)
        r = float(jnp.sum(self._apply_net(candidate, probe, x)))
        total = n_param_layers * max(r, 0.0)
        # log1p: raw synflow grows multiplicatively with depth/width and
        # overflows float ranges for deep candidates; log keeps the
        # ranking and stays strict-JSON-serializable on the disk tier
        return math.log1p(total)


@ESTIMATORS.register("grad_norm")
class GradNormEstimator(ZeroCostProxy):
    """Global l2 norm of the cross-entropy gradient from one
    forward/backward on a fixed random batch with random labels."""

    name = "grad_norm"

    def _score(self, candidate: BuiltModel) -> float:
        x = self._input(candidate, "normal")
        y = jax.random.randint(jax.random.PRNGKey(2), (self.batch,), 0,
                               max(1, candidate.output_dim))
        params = candidate.init(jax.random.PRNGKey(0))

        def loss(p):
            logits = self._apply_net(candidate, p, x)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
            return jnp.mean(logz - gold)

        grads = jax.grad(loss)(params)
        sq = sum(float(jnp.sum(g * g))
                 for g in jax.tree_util.tree_leaves(grads))
        return math.sqrt(max(sq, 0.0))
