"""Content-addressed artifact store: compiled executables that survive
the process.

The disk evaluation cache (:mod:`repro.evaluation.disk_cache`) persists
*scalar* estimator values; the compiled executables themselves stayed
memory-only, so a server booting after an exploration — a different
process — had to recompile the winning architecture even though the
study had already paid for it.  This store closes that gap: it persists
the serialized XLA executable (via
``jax.experimental.serialize_executable``) plus the artifact's static
analysis, content-addressed by the same identity the evaluation cache
uses, so ``python -m repro.launch.serve --from-report`` performs **zero**
XLA compiles for any program the exploration touched.

Content key
-----------
An entry's identity is the estimator program key — ``(name, mesh_scope,
batch, full architecture signature[, effective kernel schedules])`` —
wrapped with the **toolchain salt** (jax/jaxlib versions, the same salt
:func:`repro.evaluation.disk_cache.canonical_key` applies).  Every part
is load-bearing:

  * the *full* signature (layers AND pre-processing) — two candidates
    share an entry iff they are the same program (the cache-collision
    class of bug the property tests in ``tests/test_property.py`` pin);
  * ``mesh_scope`` not target name — the compiled program depends on the
    mesh topology only, so single-chip targets reuse each other's blobs;
  * the *effective* (shape-clamped) kernel-schedule signature — two
    requested schedules that clamp to the same launch share one entry,
    two that clamp apart never collide;
  * the toolchain salt — a jax/jaxlib upgrade structurally misses
    instead of deserializing an executable built by a different compiler.

Layout
------
``<dir>/artifacts/manifest.jsonl`` — append-only JSONL manifest under
the same ``flock`` + CRC32 discipline as the value cache: one record
``{"key": <canonical>, "blob": <sha256>, "meta": {...}, "crc": ...}``
per store; corrupt records read back as misses.  ``<dir>/artifacts/
<sha256>.bin`` — the pickled ``(payload, in_tree, out_tree)`` triple
from ``serialize_executable.serialize`` plus the analysis scalars.  The
blob name is the sha256 of the canonical key, so a re-store of the same
content is a no-op and two different keys can never share a blob.

Degradation
-----------
Executable serialization is platform/version dependent; every failure
path (serialize raises, unpickle fails, deserialize rejects the
payload, blob missing or torn) degrades to a miss — the caller
recompiles, exactly as before the store existed.  ``REPRO_ARTIFACTS=0``
disables the store wholesale (registered in :mod:`repro.envvars`).
"""
from __future__ import annotations

import hashlib
import json
import os
import pickle
import threading
import warnings
from typing import Any, Dict, Hashable, Optional, Tuple

from repro import faults
from repro.envvars import read_env
from repro.evaluation.disk_cache import canonical_key
from repro.ioutils import locked_append

ARTIFACTS_ENV = "REPRO_ARTIFACTS"

_PICKLE_PROTOCOL = 4  # stable across the supported interpreters


def store_enabled() -> bool:
    """False when ``REPRO_ARTIFACTS=0`` disables executable persistence."""
    return read_env(ARTIFACTS_ENV, True)


def serialize_compiled(compiled: Any) -> Optional[bytes]:
    """Pickled ``(payload, in_tree, out_tree)`` for a compiled executable,
    or None when the platform/toolchain cannot serialize it."""
    try:
        from jax.experimental import serialize_executable as se

        payload, in_tree, out_tree = se.serialize(compiled)
        return pickle.dumps((payload, in_tree, out_tree), _PICKLE_PROTOCOL)
    except Exception:
        return None


def deserialize_compiled(blob: bytes) -> Optional[Any]:
    """Inverse of :func:`serialize_compiled`; None on any failure."""
    try:
        from jax.experimental import serialize_executable as se

        payload, in_tree, out_tree = pickle.loads(blob)
        return se.deserialize_and_load(payload, in_tree, out_tree)
    except Exception:
        return None


def content_hash(canonical: str) -> str:
    return hashlib.sha256(canonical.encode("utf-8")).hexdigest()


def _manifest_crc(key: str, blob: str) -> int:
    import zlib

    return zlib.crc32(json.dumps([key, blob], sort_keys=True,
                                 separators=(",", ":")).encode("utf-8"))


class ArtifactStore:
    """Content-addressed executable store next to a disk value cache.

    ``dir`` is the evaluation-cache store directory; blobs and the
    manifest live in an ``artifacts/`` subdirectory so the two tiers
    share one location (and one ``cache.dir`` spec knob).
    """

    SUBDIR = "artifacts"
    MANIFEST = "manifest.jsonl"

    def __init__(self, path: str):
        from repro.evaluation.disk_cache import CACHE_DIR_ENV

        override = read_env(CACHE_DIR_ENV, None)
        base = str(override) if override else str(path)
        self.path = os.path.join(base, self.SUBDIR)
        self._manifest = os.path.join(self.path, self.MANIFEST)
        self._lock = threading.Lock()
        self._index: Dict[str, Dict[str, Any]] = {}  # canonical key -> record
        self._offset = 0
        self.hits = 0
        self.misses = 0
        self.puts = 0
        self.bad_blobs = 0  # blobs that failed to load/deserialize
        os.makedirs(self.path, exist_ok=True)
        self.refresh()

    # -- manifest ----------------------------------------------------------

    def refresh(self) -> int:
        with self._lock:
            return self._read_new()

    def _read_new(self) -> int:
        if not os.path.exists(self._manifest):
            return 0
        try:
            with open(self._manifest, "rb") as f:
                f.seek(self._offset)
                data = f.read()
        except OSError:
            return 0
        lines = data.split(b"\n")
        self._offset += len(data) - len(lines[-1])
        n = 0
        for raw in lines[:-1]:
            if not raw.strip():
                continue
            try:
                rec = json.loads(raw.decode("utf-8"))
            except (UnicodeDecodeError, json.JSONDecodeError):
                continue
            if not isinstance(rec, dict):
                continue
            key, blob = rec.get("key"), rec.get("blob")
            if not isinstance(key, str) or not isinstance(blob, str):
                continue
            if rec.get("crc") != _manifest_crc(key, blob):
                continue  # torn/rotted record: a miss, never a wrong program
            self._index[key] = rec
            n += 1
        return n

    # -- keys --------------------------------------------------------------

    @staticmethod
    def canonical(key: Hashable) -> Optional[str]:
        """The store's canonical string key: the evaluation-cache program
        key wrapped with the toolchain salt.  None = not storable (the
        key contains non-JSON parts, e.g. an uncacheable candidate)."""
        if isinstance(key, tuple) and any(k is None for k in key):
            return None
        return canonical_key(key)

    def keys(self):
        with self._lock:
            return list(self._index)

    def __contains__(self, key: Hashable) -> bool:
        ck = self.canonical(key)
        if ck is None:
            return False
        with self._lock:
            self._read_new()
            return ck in self._index

    def __len__(self) -> int:
        with self._lock:
            return len(self._index)

    # -- store/load --------------------------------------------------------

    def put(self, key: Hashable, artifact: Any) -> bool:
        """Persist one compiled artifact; returns True when (newly or
        already) stored.  Never raises: an unserializable executable or
        an unwritable store degrades to memory-only, same as the value
        cache."""
        if not store_enabled():
            return False
        ck = self.canonical(key)
        if ck is None:
            return False
        with self._lock:
            self._read_new()
            if ck in self._index:
                return True  # content-addressed: same key == same program
        payload = serialize_compiled(artifact.compiled)
        if payload is None:
            return False
        meta = {
            "flops": float(artifact.flops),
            "bytes_accessed": float(artifact.bytes_accessed),
            "collective_bytes": float(artifact.collective_bytes),
            "memory": {k: int(v) for k, v in artifact.memory.items()},
            "schedules": artifact.schedules,
        }
        blob_name = content_hash(ck)
        blob_path = os.path.join(self.path, blob_name + ".bin")
        try:
            if not os.path.exists(blob_path):
                tmp = blob_path + f".tmp.{os.getpid()}"
                with open(tmp, "wb") as f:
                    f.write(payload)
                    f.flush()
                    os.fsync(f.fileno())
                os.replace(tmp, blob_path)  # atomic: readers never see a torn blob
            line = json.dumps({"key": ck, "blob": blob_name, "meta": meta,
                               "crc": _manifest_crc(ck, blob_name)}) + "\n"
            locked_append(self._manifest, line)
        except (OSError, faults.InjectedFault) as e:
            warnings.warn(
                f"artifact store append to {self._manifest!r} failed ({e!r}); "
                f"the executable stays memory-only", RuntimeWarning,
                stacklevel=2)
            return False
        with self._lock:
            self._index[ck] = {"key": ck, "blob": blob_name, "meta": meta}
            self.puts += 1
            self._read_new()  # consume our own append (offset hygiene)
        return True

    def get(self, key: Hashable, target: Any = None) -> Optional[Any]:
        """Load one compiled artifact, rebound to ``target``; None on miss
        or any deserialization failure (the caller recompiles)."""
        if not store_enabled():
            return None
        ck = self.canonical(key)
        if ck is None:
            return None
        with self._lock:
            if ck not in self._index:
                self._read_new()  # a sibling may have stored it since
            rec = self._index.get(ck)
            if rec is None:
                self.misses += 1
                return None
        blob_path = os.path.join(self.path, str(rec["blob"]) + ".bin")
        try:
            with open(blob_path, "rb") as f:
                payload = f.read()
        except OSError:
            with self._lock:
                self.bad_blobs += 1
                self.misses += 1
            return None
        compiled = deserialize_compiled(payload)
        if compiled is None:
            with self._lock:
                self.bad_blobs += 1
                self.misses += 1
            return None
        artifact = self._rebuild(rec.get("meta") or {}, compiled, target)
        with self._lock:
            self.hits += 1
        return artifact

    def _rebuild(self, meta: Dict[str, Any], compiled: Any, target: Any):
        from repro.hwgen.generator import Artifact
        from repro.hwgen.roofline import roofline_terms
        from repro.hwgen.targets import get_target

        if isinstance(target, str):
            target = get_target(target)
        flops = float(meta.get("flops", 0.0))
        bytes_accessed = float(meta.get("bytes_accessed", 0.0))
        coll = float(meta.get("collective_bytes", 0.0))
        roofline = None
        if target is not None:
            roofline = roofline_terms(
                hlo_flops=flops, hlo_bytes=bytes_accessed,
                collective_bytes=coll, n_chips=1, chip=target.chip)
        return Artifact(
            target=target,
            compiled=compiled,
            flops=flops,
            bytes_accessed=bytes_accessed,
            collective_bytes=coll,
            memory={k: int(v) for k, v in (meta.get("memory") or {}).items()},
            roofline=roofline,
            example_args=(),
            schedules=meta.get("schedules"),
        )

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "entries": len(self._index),
                "hits": self.hits,
                "misses": self.misses,
                "puts": self.puts,
                "bad_blobs": self.bad_blobs,
            }
