"""Disk-persistent tier for :class:`~repro.evaluation.cache.EvaluationCache`.

XLA compilation dominates hardware-in-the-loop NAS, and the in-memory
cache dies with the process: every resumed study — and every process
worker — used to recompile architectures the host had already paid for.
This module persists the *scalar* estimator values (latency, peak bytes,
roofline bounds) so a restarted or process-parallel study compiles each
architecture at most once per host:

  * layout: one append-only JSONL file, ``entries.jsonl``, inside the
    store directory (default ``results/cache/``), one record per value:
    ``{"key": <canonical key>, "value": <scalar>, "crc": <crc32>}`` —
    the CRC32 covers key+value, so bit rot that still parses as JSON
    reads back as a miss (and is dropped at compaction), never as a
    wrong compiled-latency value; pre-CRC records (no ``crc`` field)
    are accepted and re-checksummed by the next compaction;
  * keys are the cache's own tuples — estimator name, target, batch,
    full architecture signature (layers AND pre-processing) — wrapped
    together with a **toolchain salt** (the jax/jaxlib versions, see
    :func:`toolchain_versions`) and canonicalized to a JSON string, so a
    changed architecture, target, batch size, or XLA toolchain can never
    alias an old entry.  **Invalidation** is therefore structural:
    entries never go stale as long as signatures capture the program,
    and a jax/jaxlib upgrade (which can change compiled latency and
    memory results) simply stops matching the old records instead of
    serving them;
  * compiled executables are not persistable — non-JSON values are
    silently skipped and live only in the memory tier;
  * concurrency: appends take an ``flock`` around a single ``write`` (the
    same discipline as study JSONL storage), so sibling *processes*
    sharing the store never tear records; readers only consume complete
    lines and re-scan the tail on miss, so a value computed by one
    worker is found by the others without recompiling.

**Shared-filesystem caveat (remote workers):** worker daemons pointed at
one store directory over NFS share compiled values across hosts, but
``flock`` on NFS is only reliable on NFSv4-era mounts; older setups
reject it (``ENOLCK``/``EOPNOTSUPP``) or grant it without cross-host
exclusion.  When ``flock`` raises, :mod:`repro.ioutils` falls back to
``fcntl.lockf`` range locks (NFS's native locking protocol) with a
one-line ``RuntimeWarning`` per store.  If a mount grants ``flock``
*non-exclusively* (silent NFSv2/v3 emulation), no error is observable —
worst case is a torn JSONL line, which readers already skip as corrupt
and rewrite on the next store; the cache degrades to extra recomputes,
never to wrong values.  ``REPRO_CACHE_DIR`` overrides the store
directory for every cache opened in the process — this is how
``python -m repro.worker --cache-dir`` redirects shipped experiment
specs (whose ``cache.dir`` names a path on the submitting host) into the
worker's local or cluster-shared store.

The store is warm-loaded at construction (study/estimator setup time)
and refreshed incrementally on miss, so a restarted study starts with
every previously compiled value already resident.

**Migration note (toolchain salt):** keys written before the salt was
introduced (records whose ``key`` field is a bare JSON list rather than
a ``{"key": ..., "toolchain": ...}`` object) are still parsed but can no
longer match a lookup, so the first run on the new format recomputes and
appends fresh records — no manual migration is needed.  The same applies
after any jax/jaxlib upgrade.

**Compaction (size hygiene at scale):** the store is append-only, so
superseded-toolchain records and evicted duplicates accumulate.  When
the file holds more than ``REPRO_CACHE_MAX_ENTRIES`` records (or the
``max_entries`` constructor argument; unset = unbounded), the next
append rewrites ``entries.jsonl`` in place under the same ``flock`` the
appends take: records whose toolchain salt no longer matches the running
jax/jaxlib are dropped first, then least-recently-used current-salt
records down to ~75% of the cap — the slack means a steady stream of
new keys doesn't rewrite the file on every append (recency = this
process's lookup/store order; records only ever seen in the file rank
oldest, in file order).
Sibling processes notice the shrink through the existing
truncation-detection path and re-read.  Dropping a live record only
costs a recompute — the store is a cache, never the source of truth.
"""
from __future__ import annotations

import json
import os
import threading
import warnings
import zlib
from typing import Any, Dict, Hashable, List, Optional, Tuple

from repro import faults
from repro.envvars import read_env
from repro.ioutils import lock_file, locked_append, unlock_file

DEFAULT_DIR = os.path.join("results", "cache")

MAX_ENTRIES_ENV = "REPRO_CACHE_MAX_ENTRIES"
CACHE_DIR_ENV = "REPRO_CACHE_DIR"


def _max_entries_from_env() -> Optional[int]:
    # declared in repro.envvars (the shared REPRO_* registry): malformed
    # values warn and leave the store unbounded
    return read_env(MAX_ENTRIES_ENV, None)

_JSON_SCALARS = (str, int, float, bool, type(None))


def jsonable(value: Any) -> bool:
    """True if ``value`` round-trips through JSON (tuples become lists)."""
    if isinstance(value, _JSON_SCALARS):
        return True
    if isinstance(value, (list, tuple)):
        return all(jsonable(v) for v in value)
    if isinstance(value, dict):
        return all(isinstance(k, str) and jsonable(v) for k, v in value.items())
    return False


def toolchain_versions() -> Dict[str, str]:
    """jax/jaxlib versions, or "unavailable" when not importable — the
    compiled-value salt: two toolchains may compile the same program to
    different latency/memory, so their values must never alias."""
    try:
        import jax

        jax_version = str(getattr(jax, "__version__", "unknown"))
    except Exception:
        jax_version = "unavailable"
    try:
        import jaxlib.version

        jaxlib_version = str(jaxlib.version.__version__)
    except Exception:
        jaxlib_version = "unavailable"
    return {"jax": jax_version, "jaxlib": jaxlib_version}


_TOOLCHAIN: Optional[Dict[str, str]] = None


def _toolchain_salt() -> Dict[str, str]:
    global _TOOLCHAIN
    if _TOOLCHAIN is None:
        _TOOLCHAIN = toolchain_versions()
    return _TOOLCHAIN


def canonical_key(key: Hashable) -> Optional[str]:
    """Stable string form of a cache key salted with the jax/jaxlib
    versions (an XLA upgrade invalidates structurally instead of serving
    stale compiled values), or None when the key contains non-JSON parts
    (those entries stay memory-only)."""
    if not jsonable(key):
        return None
    return json.dumps({"key": key, "toolchain": _toolchain_salt()},
                      sort_keys=True, separators=(",", ":"))


def _record_crc(key: str, value: Any) -> int:
    """CRC32 integrity checksum over the record's canonical content.
    Bit rot or a mangled write that still parses as JSON must read back
    as a *miss*, never as a wrong compiled-latency value."""
    return zlib.crc32(json.dumps([key, value], sort_keys=True,
                                 separators=(",", ":")).encode("utf-8"))


def _record_line(key: str, value: Any) -> str:
    return json.dumps({"key": key, "value": value,
                       "crc": _record_crc(key, value)}) + "\n"


def _record_value(rec: Any) -> Tuple[Optional[str], Any, str]:
    """Validate one parsed record -> (key, value, status), status one of
    ``"ok"`` | ``"skip"`` (not a value record) | ``"corrupt"`` (checksum
    mismatch).  Records written before checksums (no ``crc`` field) are
    accepted as-is; a present checksum must match or the record is
    dropped — a miss and a recompute, never a wrong value."""
    if not isinstance(rec, dict):
        return None, None, "skip"
    key = rec.get("key")
    if not isinstance(key, str) or "value" not in rec:
        return None, None, "skip"
    if "crc" in rec and rec["crc"] != _record_crc(key, rec["value"]):
        return None, None, "corrupt"
    return key, rec["value"], "ok"


class DiskEvaluationCache:
    """Append-only JSONL value store, safe across threads and processes,
    with optional size-capped LRU compaction (see module docstring)."""

    FILENAME = "entries.jsonl"
    EPOCH_FILENAME = "compaction.epoch"

    def __init__(self, path: str = DEFAULT_DIR, max_entries: Optional[int] = None):
        # REPRO_CACHE_DIR redirects every store opened in this process —
        # worker daemons use it to keep shipped specs (whose cache.dir is
        # a path on the submitting host) inside their own store
        override = read_env(CACHE_DIR_ENV, None)
        self.path = str(override) if override else str(path)
        self._file = os.path.join(self.path, self.FILENAME)
        self._epoch_file = os.path.join(self.path, self.EPOCH_FILENAME)
        self._epoch: Optional[str] = None  # last-seen compaction token
        self._lock = threading.Lock()
        # insertion order doubles as recency: lookup hits and stores
        # re-insert their key at the end, so iteration runs LRU-first
        self._mem: Dict[str, Any] = {}
        self._offset = 0  # byte offset of the next unread record
        self._file_records = 0  # records this process believes are on disk
        self.max_entries = max_entries if max_entries is not None else _max_entries_from_env()
        if self.max_entries is not None:
            self.max_entries = max(1, int(self.max_entries))
        self.compactions = 0
        self.dropped_superseded = 0
        self.dropped_lru = 0
        self.corrupt_records = 0  # checksum/parse failures seen on read
        self.dropped_corrupt = 0  # corrupt records removed by compaction
        os.makedirs(self.path, exist_ok=True)
        self.refresh()  # warm load at construction

    # -- reading ---------------------------------------------------------------

    def refresh(self) -> int:
        """Consume records appended since the last read (by this process
        or siblings sharing the store); returns how many were new."""
        with self._lock:
            return self._read_new()

    def _read_epoch(self) -> Optional[str]:
        try:
            with open(self._epoch_file) as f:
                return f.read()
        except OSError:
            return None

    def _read_new(self) -> int:
        if not os.path.exists(self._file):
            return 0
        epoch = self._read_epoch()
        if epoch != self._epoch:
            # a sibling compacted the store: our byte offset no longer
            # aligns with record boundaries (the rewrite may even leave
            # the file the same length) — drop the view and re-read
            self._epoch = epoch
            self._mem.clear()
            self._offset = 0
            self._file_records = 0
        if os.path.getsize(self._file) < self._offset:
            # the store was truncated (a sibling's clear()): our offset
            # points past EOF and our memory view predates the wipe —
            # drop both and re-read whatever the siblings rebuilt.  (If
            # the file regrew past our offset before we noticed, stale
            # entries can linger: cross-process invalidation is
            # best-effort; delete the store directory between runs for a
            # guaranteed rebuild.)
            self._mem.clear()
            self._offset = 0
            self._file_records = 0
        try:
            with open(self._file, "rb") as f:
                f.seek(self._offset)
                data = f.read()
        except OSError:
            return 0  # store vanished / unreadable: degrade to misses
        lines = data.split(b"\n")
        # the final element is b"" after a complete record, or the torn
        # tail of an append in progress — leave it for the next refresh
        self._offset += len(data) - len(lines[-1])
        n = 0
        for raw in lines[:-1]:
            if not raw.strip():
                continue
            self._file_records += 1
            try:
                raw = faults.fault_point("disk_cache.read", raw)
            except faults.InjectedFault:
                self.corrupt_records += 1
                continue
            if raw is faults.DROP:
                continue
            try:
                rec = json.loads(raw.decode("utf-8"))
            except (UnicodeDecodeError, json.JSONDecodeError):
                # corrupt line: skip rather than poison the run
                self.corrupt_records += 1
                continue
            key, value, status = _record_value(rec)
            if status == "corrupt":
                self.corrupt_records += 1
                continue
            if status == "ok":
                # re-insert so a key re-appended by a sibling ranks recent
                self._mem.pop(key, None)
                self._mem[key] = value
                n += 1
        return n

    def lookup(self, key: Hashable) -> Tuple[bool, Any]:
        """(found, value).  Re-scans the file tail first, so entries
        appended by sibling processes are found before the caller pays a
        compile — and a sibling's truncation is noticed before a stale
        memory entry is served.  Callers (the memory tier) only reach
        this once per key per process, so the extra stat+read is cheap."""
        ck = canonical_key(key)
        if ck is None:
            return False, None
        with self._lock:
            self._read_new()
            if ck in self._mem:
                value = self._mem.pop(ck)  # re-insert: hits rank recent
                self._mem[ck] = value
                return True, value
        return False, None

    # -- writing ---------------------------------------------------------------

    def store(self, key: Hashable, value: Any) -> bool:
        """Write-through one value; returns False (and skips the disk) for
        non-canonical keys or non-JSON values (e.g. compiled artifacts)."""
        ck = canonical_key(key)
        if ck is None or not jsonable(value):
            return False
        with self._lock:
            if ck in self._mem:  # already persisted (possibly by a sibling)
                self._mem.pop(ck)
                self._mem[ck] = value
                return True
            line = faults.fault_point("disk_cache.write", _record_line(ck, value))
            if line is not faults.DROP:
                try:
                    locked_append(self._file, line)
                except (OSError, faults.InjectedFault) as e:
                    # a full/unwritable/faulted store must not fail the
                    # study — the value stays resident in memory and the
                    # cache degrades to recomputes in other processes
                    warnings.warn(
                        f"disk cache append to {self._file!r} failed "
                        f"({e!r}); keeping the value in memory only",
                        RuntimeWarning, stacklevel=3)
            self._mem[ck] = value
            # consume the tail (our own append + anything siblings added)
            # instead of bumping a counter: the next _read_new would
            # re-read our record from the old offset and double-count it
            self._read_new()
            if self.max_entries is not None and self._file_records > self.max_entries:
                self._compact()
        return True

    # -- compaction ------------------------------------------------------------

    def _compact(self) -> None:
        """Rewrite ``entries.jsonl`` in place under flock, dropping
        superseded-toolchain records first, then LRU current-salt records
        down to ~75% of ``max_entries`` (headroom so the next appends
        don't immediately re-trigger).  Caller holds ``self._lock``."""
        try:
            f = open(self._file, "r+b")
        except OSError:
            return  # store vanished under us: nothing to compact
        with f:
            how = lock_file(f, self._file)
            try:
                # re-read the WHOLE file under the lock: siblings may have
                # appended records this process has never seen, and the
                # cap applies to the union
                entries: Dict[str, Any] = {}
                corrupt = 0
                for raw in f.read().split(b"\n"):
                    if not raw.strip():
                        continue
                    try:
                        rec = json.loads(raw.decode("utf-8"))
                    except (UnicodeDecodeError, json.JSONDecodeError):
                        corrupt += 1
                        continue  # corrupt line: compacted away
                    key, value, status = _record_value(rec)
                    if status == "corrupt":
                        corrupt += 1
                        continue
                    if status == "ok":
                        entries.pop(key, None)  # keep-last, ranked by file order
                        entries[key] = value
                current = _toolchain_salt()
                live: Dict[str, Any] = {}
                for key, value in entries.items():
                    try:
                        salt = json.loads(key).get("toolchain")
                    except (ValueError, AttributeError):
                        salt = None  # pre-salt legacy key: superseded
                    if salt == current:
                        live[key] = value
                superseded = len(entries) - len(live)
                # promote this process's access order (oldest..newest), so
                # iteration order over `live` is LRU-first; keys only ever
                # seen in the file keep file order and rank oldest
                for key in list(self._mem):
                    if key in live:
                        live[key] = live.pop(key)
                # hysteresis: compact down to ~75% of the cap, so a
                # steady state of all-new keys doesn't rewrite the whole
                # file on every single append past the cap
                keep = max(1, self.max_entries - self.max_entries // 4)
                lru = max(0, len(live) - keep)
                for key in list(live)[:lru]:
                    del live[key]
                f.seek(0)
                f.truncate()
                # the rewrite re-checksums every surviving record, which
                # also upgrades pre-CRC legacy records in place
                for key, value in live.items():
                    f.write(_record_line(key, value).encode("utf-8"))
                f.flush()
                os.fsync(f.fileno())
                end = f.tell()
                # bump the epoch (still under the store flock) so sibling
                # processes drop their now-misaligned byte offsets
                epoch = f"{os.getpid()}:{os.urandom(8).hex()}"
                with open(self._epoch_file, "w") as ef:
                    ef.write(epoch)
                self._epoch = epoch
            finally:
                unlock_file(f, how)
        self._mem = dict(live)
        self._offset = end
        self._file_records = len(live)
        self.compactions += 1
        self.dropped_superseded += superseded
        self.dropped_lru += lru
        self.dropped_corrupt += corrupt

    def stats(self) -> Dict[str, int]:
        """Hygiene counters for reports: resident entries + what
        compaction has dropped so far in this process."""
        with self._lock:
            return {
                "disk_entries": len(self._mem),
                "compactions": self.compactions,
                "dropped_superseded": self.dropped_superseded,
                "dropped_lru": self.dropped_lru,
                "corrupt_records": self.corrupt_records,
                "dropped_corrupt": self.dropped_corrupt,
            }

    def clear(self) -> None:
        """Drop every persisted entry (truncates the store file)."""
        with self._lock:
            with open(self._file, "w"):
                pass
            self._mem.clear()
            self._offset = 0
            self._file_records = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._mem)
