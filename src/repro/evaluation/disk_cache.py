"""Disk-persistent tier for :class:`~repro.evaluation.cache.EvaluationCache`.

XLA compilation dominates hardware-in-the-loop NAS, and the in-memory
cache dies with the process: every resumed study — and every process
worker — used to recompile architectures the host had already paid for.
This module persists the *scalar* estimator values (latency, peak bytes,
roofline bounds) so a restarted or process-parallel study compiles each
architecture at most once per host:

  * layout: one append-only JSONL file, ``entries.jsonl``, inside the
    store directory (default ``results/cache/``), one record per value:
    ``{"key": <canonical key>, "value": <scalar>}``;
  * keys are the cache's own tuples — estimator name, target, batch,
    full architecture signature (layers AND pre-processing) — wrapped
    together with a **toolchain salt** (the jax/jaxlib versions, see
    :func:`toolchain_versions`) and canonicalized to a JSON string, so a
    changed architecture, target, batch size, or XLA toolchain can never
    alias an old entry.  **Invalidation** is therefore structural:
    entries never go stale as long as signatures capture the program,
    and a jax/jaxlib upgrade (which can change compiled latency and
    memory results) simply stops matching the old records instead of
    serving them;
  * compiled executables are not persistable — non-JSON values are
    silently skipped and live only in the memory tier;
  * concurrency: appends take an ``flock`` around a single ``write`` (the
    same discipline as study JSONL storage), so sibling *processes*
    sharing the store never tear records; readers only consume complete
    lines and re-scan the tail on miss, so a value computed by one
    worker is found by the others without recompiling.

The store is warm-loaded at construction (study/estimator setup time)
and refreshed incrementally on miss, so a restarted study starts with
every previously compiled value already resident.

**Migration note (toolchain salt):** keys written before the salt was
introduced (records whose ``key`` field is a bare JSON list rather than
a ``{"key": ..., "toolchain": ...}`` object) are still parsed but can no
longer match a lookup, so the first run on the new format recomputes and
appends fresh records — no manual migration is needed.  The same applies
after any jax/jaxlib upgrade.  The store is append-only, so superseded
records linger on disk until the directory is deleted (a rebuild is
cheap: one compile per live architecture).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, Hashable, Optional, Tuple

from repro.ioutils import locked_append

DEFAULT_DIR = os.path.join("results", "cache")

_JSON_SCALARS = (str, int, float, bool, type(None))


def jsonable(value: Any) -> bool:
    """True if ``value`` round-trips through JSON (tuples become lists)."""
    if isinstance(value, _JSON_SCALARS):
        return True
    if isinstance(value, (list, tuple)):
        return all(jsonable(v) for v in value)
    if isinstance(value, dict):
        return all(isinstance(k, str) and jsonable(v) for k, v in value.items())
    return False


def toolchain_versions() -> Dict[str, str]:
    """jax/jaxlib versions, or "unavailable" when not importable — the
    compiled-value salt: two toolchains may compile the same program to
    different latency/memory, so their values must never alias."""
    try:
        import jax

        jax_version = str(getattr(jax, "__version__", "unknown"))
    except Exception:
        jax_version = "unavailable"
    try:
        import jaxlib.version

        jaxlib_version = str(jaxlib.version.__version__)
    except Exception:
        jaxlib_version = "unavailable"
    return {"jax": jax_version, "jaxlib": jaxlib_version}


_TOOLCHAIN: Optional[Dict[str, str]] = None


def _toolchain_salt() -> Dict[str, str]:
    global _TOOLCHAIN
    if _TOOLCHAIN is None:
        _TOOLCHAIN = toolchain_versions()
    return _TOOLCHAIN


def canonical_key(key: Hashable) -> Optional[str]:
    """Stable string form of a cache key salted with the jax/jaxlib
    versions (an XLA upgrade invalidates structurally instead of serving
    stale compiled values), or None when the key contains non-JSON parts
    (those entries stay memory-only)."""
    if not jsonable(key):
        return None
    return json.dumps({"key": key, "toolchain": _toolchain_salt()},
                      sort_keys=True, separators=(",", ":"))


class DiskEvaluationCache:
    """Append-only JSONL value store, safe across threads and processes."""

    FILENAME = "entries.jsonl"

    def __init__(self, path: str = DEFAULT_DIR):
        self.path = str(path)
        self._file = os.path.join(self.path, self.FILENAME)
        self._lock = threading.Lock()
        self._mem: Dict[str, Any] = {}
        self._offset = 0  # byte offset of the next unread record
        os.makedirs(self.path, exist_ok=True)
        self.refresh()  # warm load at construction

    # -- reading ---------------------------------------------------------------

    def refresh(self) -> int:
        """Consume records appended since the last read (by this process
        or siblings sharing the store); returns how many were new."""
        with self._lock:
            return self._read_new()

    def _read_new(self) -> int:
        if not os.path.exists(self._file):
            return 0
        if os.path.getsize(self._file) < self._offset:
            # the store was truncated (a sibling's clear()): our offset
            # points past EOF and our memory view predates the wipe —
            # drop both and re-read whatever the siblings rebuilt.  (If
            # the file regrew past our offset before we noticed, stale
            # entries can linger: cross-process invalidation is
            # best-effort; delete the store directory between runs for a
            # guaranteed rebuild.)
            self._mem.clear()
            self._offset = 0
        with open(self._file, "rb") as f:
            f.seek(self._offset)
            data = f.read()
        lines = data.split(b"\n")
        # the final element is b"" after a complete record, or the torn
        # tail of an append in progress — leave it for the next refresh
        self._offset += len(data) - len(lines[-1])
        n = 0
        for raw in lines[:-1]:
            if not raw.strip():
                continue
            try:
                rec = json.loads(raw.decode("utf-8"))
            except (UnicodeDecodeError, json.JSONDecodeError):
                continue  # corrupt line: skip rather than poison the run
            key = rec.get("key")
            if isinstance(key, str) and "value" in rec:
                self._mem[key] = rec["value"]
                n += 1
        return n

    def lookup(self, key: Hashable) -> Tuple[bool, Any]:
        """(found, value).  Re-scans the file tail first, so entries
        appended by sibling processes are found before the caller pays a
        compile — and a sibling's truncation is noticed before a stale
        memory entry is served.  Callers (the memory tier) only reach
        this once per key per process, so the extra stat+read is cheap."""
        ck = canonical_key(key)
        if ck is None:
            return False, None
        with self._lock:
            self._read_new()
            if ck in self._mem:
                return True, self._mem[ck]
        return False, None

    # -- writing ---------------------------------------------------------------

    def store(self, key: Hashable, value: Any) -> bool:
        """Write-through one value; returns False (and skips the disk) for
        non-canonical keys or non-JSON values (e.g. compiled artifacts)."""
        ck = canonical_key(key)
        if ck is None or not jsonable(value):
            return False
        with self._lock:
            if ck in self._mem:  # already persisted (possibly by a sibling)
                self._mem[ck] = value
                return True
            locked_append(self._file, json.dumps({"key": ck, "value": value}) + "\n")
            self._mem[ck] = value
        return True

    def clear(self) -> None:
        """Drop every persisted entry (truncates the store file)."""
        with self._lock:
            with open(self._file, "w"):
                pass
            self._mem.clear()
            self._offset = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._mem)
