from repro.evaluation.api import (
    CriteriaRunner,
    Estimator,
    OptimizationCriteria,
    constraint_violation,
    weighted_sum,
)
from repro.evaluation.cache import CacheStats, EvaluationCache
from repro.evaluation.cascade import (
    CascadeRunner,
    CohortResult,
    FidelityStage,
    KeepRule,
)
from repro.evaluation.artifact_store import ArtifactStore
from repro.evaluation.disk_cache import DiskEvaluationCache
from repro.evaluation.estimators import (
    ActivationMemoryEstimator,
    CompiledLatencyEstimator,
    CompiledMemoryEstimator,
    FlopsEstimator,
    ParamCountEstimator,
    TrainedAccuracyEstimator,
)
from repro.evaluation.proxies import GradNormEstimator, SynFlowEstimator
from repro.evaluation.serving import (
    DecodeLatencyEstimator,
    KVCachePeakBytesEstimator,
    P99LatencyEstimator,
    PrefillLatencyEstimator,
    ThroughputEstimator,
)
