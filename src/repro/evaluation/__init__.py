from repro.evaluation.api import (
    CriteriaRunner,
    Estimator,
    OptimizationCriteria,
    weighted_sum,
)
from repro.evaluation.cache import CacheStats, EvaluationCache
from repro.evaluation.disk_cache import DiskEvaluationCache
from repro.evaluation.estimators import (
    ActivationMemoryEstimator,
    CompiledLatencyEstimator,
    CompiledMemoryEstimator,
    FlopsEstimator,
    ParamCountEstimator,
    TrainedAccuracyEstimator,
)
