"""Lightweight distribution hooks usable from model code.

Model code calls :func:`constrain` with *logical* activation axes; when a
sharding context (rules + mesh) is active this becomes
``lax.with_sharding_constraint``, otherwise it is a no-op — so the same
model code runs single-device (smoke tests) and pod-scale (dry-run)
unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax

_state = threading.local()


def current_rules():
    return getattr(_state, "rules", None)


def current_mesh():
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def sharding_context(mesh, rules):
    """Activate (mesh, rules) for :func:`constrain` within the block."""
    prev = (current_mesh(), current_rules())
    _state.mesh, _state.rules = mesh, rules
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def constrain(x, logical_axes: Tuple[Optional[str], ...]):
    """Attach a sharding constraint for activation ``x`` if context active."""
    mesh, rules = current_mesh(), current_rules()
    if mesh is None or rules is None:
        return x
    from repro.distributed.sharding import logical_to_sharding

    sharding = logical_to_sharding(logical_axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, sharding)
