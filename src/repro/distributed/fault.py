"""Fault-tolerance utilities: preemption handling, elastic re-meshing,
straggler detection.

On a real pod these hooks pair with the cluster scheduler (SIGTERM before
preemption, jax.distributed for membership).  The mechanisms — atomic
checkpoints, reshard-on-restore, deterministic step-indexed data — are all
exercised in tests on the host backend.
"""
from __future__ import annotations

import signal
import time
from typing import Callable, List, Optional

import jax


class PreemptionHandler:
    """SIGTERM/SIGINT -> set a flag the training loop polls; the loop then
    flushes a final checkpoint and exits cleanly."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._requested = False
        self._old = {}
        for s in signals:
            try:
                self._old[s] = signal.signal(s, self._handler)
            except ValueError:  # non-main thread (tests)
                pass

    def _handler(self, signum, frame):
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested

    def restore(self):
        for s, h in self._old.items():
            signal.signal(s, h)


class StragglerMonitor:
    """Tracks per-step wall time; flags steps slower than ``threshold`` x
    the trailing median.  On multi-host pods the flagged host triggers
    data-shard reassignment (the deterministic pipeline makes that free).
    """

    def __init__(self, window: int = 32, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self.durations: List[float] = []
        self.flags = 0

    def record(self, seconds: float) -> bool:
        self.durations.append(seconds)
        hist = self.durations[-self.window :]
        if len(hist) < 5:
            return False
        med = sorted(hist)[len(hist) // 2]
        slow = seconds > self.threshold * med
        if slow:
            self.flags += 1
        return slow


def elastic_remesh(preferred_shape, axes, min_model: int = 1):
    """Build the largest mesh the *current* device population supports.

    After a failure shrinks the pool (or a restart grows it), training
    resumes on the new mesh: checkpoints restore with resharding, so no
    state is lost — elastic scaling.
    """
    n = len(jax.devices())
    data, model = preferred_shape[-2], preferred_shape[-1]
    model = min(model, n)
    while model > min_model and n % model:
        model //= 2
    data = n // model
    from repro.launch.mesh import make_mesh

    return make_mesh((data, model), axes[-2:])


def with_retries(fn: Callable, retries: int = 3, backoff: float = 1.0,
                 on_error: Optional[Callable] = None):
    """Retry wrapper for transient runtime failures (collective timeouts,
    flaky hosts).  Used around step execution in the trainer."""

    def wrapped(*args, **kwargs):
        for attempt in range(retries + 1):
            try:
                return fn(*args, **kwargs)
            except Exception as e:  # pragma: no cover (exercised via tests)
                if attempt == retries:
                    raise
                if on_error:
                    on_error(e, attempt)
                time.sleep(backoff * (2 ** attempt))

    return wrapped
