"""Logical-axis sharding resolver.

Parameters and activations carry *logical* axis names ("embed", "mlp",
"heads", "vocab", "experts", "batch", "kv_seq", ...).  A rule-set maps each
logical name to zero or more mesh axes.  The resolver applies the rules
with a **divisibility fallback**: if a dimension is not divisible by the
product of its mapped mesh axes, the mapping is dropped (replicated) for
that dimension — e.g. kv_heads=8 cannot shard over model=16 and silently
falls back, which is what makes every (arch x shape) cell lower cleanly.

Default policy = FSDP + TP:
  * weights: ``embed -> data`` (FSDP), ``mlp/heads/kv_heads/vocab/experts
    -> model`` (TP/EP) — 340B/480B-param archs fit 16 GiB/chip.
  * activations: ``batch -> (pod, data)``; decode KV caches shard their
    sequence dim over ``model`` (sequence-sharded KV, Pope et al.) so a
    32k-context cache fits.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.nn.types import P as Param, is_annotated


Rules = Dict[str, Tuple[str, ...]]


def default_rules(mesh: Mesh) -> Rules:
    has_pod = "pod" in mesh.axis_names
    batch = ("pod", "data") if has_pod else ("data",)
    return {
        "batch": batch,
        "embed": ("data",),
        "mlp": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "expert_mlp": ("data",),  # 2D expert sharding (MoEConfig.shard_ff)
        "kv_seq": ("model",),
        # attention-internal context parallelism: q's seq dim shards over
        # model so score panels are 1/|model| per chip and no head_dim
        # contraction sharding (-> giant score all-reduces) can be chosen
        "act_seq": ("model",),
        "layers": (),
    }


def _axis_size(mesh: Mesh, names: Sequence[str]) -> int:
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


def partition_spec(
    logical_axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Rules,
) -> PartitionSpec:
    """Map logical axes -> PartitionSpec honoring divisibility + uniqueness."""
    used: set = set()
    out = []
    for dim, name in zip(shape, logical_axes):
        if name is None:
            out.append(None)
            continue
        mesh_axes = tuple(a for a in rules.get(name, ()) if a in mesh.axis_names)
        mesh_axes = tuple(a for a in mesh_axes if a not in used)
        if not mesh_axes or dim % _axis_size(mesh, mesh_axes) != 0:
            out.append(None)
            continue
        used.update(mesh_axes)
        out.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
    return PartitionSpec(*out)


def logical_to_sharding(logical_axes, shape, mesh: Mesh, rules: Rules) -> NamedSharding:
    if len(logical_axes) < len(shape):
        # leading stacked dims (e.g. the scan "layers" axis) default to None
        logical_axes = (None,) * (len(shape) - len(logical_axes)) + tuple(logical_axes)
    return NamedSharding(mesh, partition_spec(logical_axes, shape, mesh, rules))


def params_shardings(annotated_params, mesh: Mesh, rules: Optional[Rules] = None):
    """P-tree -> matching tree of NamedShardings (same treedef as values)."""
    rules = rules or default_rules(mesh)

    def one(p):
        if isinstance(p, Param):
            return logical_to_sharding(p.axes, p.value.shape, mesh, rules)
        return NamedSharding(mesh, PartitionSpec())

    return jax.tree_util.tree_map(one, annotated_params, is_leaf=is_annotated)


def _is_axes_leaf(x) -> bool:
    return x is None or (
        isinstance(x, tuple) and all(e is None or isinstance(e, str) for e in x)
    )


def shapes_shardings_from_axes(values, axes_tree, mesh: Mesh, rules: Optional[Rules] = None):
    """(values, axes) trees -> shardings tree.  Values may be
    ShapeDtypeStructs (dry-run) or arrays.  ``axes_tree`` leaves are the
    per-dim logical-axis tuples produced by ``repro.nn.types.split``."""
    rules = rules or default_rules(mesh)

    def one(a, v):
        if a is None:
            return NamedSharding(mesh, PartitionSpec())
        return logical_to_sharding(a, v.shape, mesh, rules)

    return jax.tree_util.tree_map(one, axes_tree, values, is_leaf=_is_axes_leaf)
