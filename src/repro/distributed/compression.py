"""Gradient compression with error feedback (distributed-optimization
trick for the DP all-reduce).

Int8 block-quantization: grads are quantized per-block (absmax scale),
all-reduced in low precision, dequantized; the quantization residual is
carried in an error-feedback buffer and added before the next step —
convergence-neutral in expectation (Karimireddy et al., 2019).

Under GSPMD the DP all-reduce is implicit, so ``compress_decompress``
models the numerics end-to-end (quantize -> dequantize around the
gradient path) and the byte savings appear on real pods when paired with
the provided ``shard_map`` manual-collective path (``compressed_psum``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    block: int = 256
    dtype = jnp.int8
    levels: int = 127


class GradientCompressor:
    def __init__(self, cfg: CompressionConfig = CompressionConfig()):
        self.cfg = cfg

    def init_state(self, params):
        return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def _quant_dequant(self, g):
        cfg = self.cfg
        flat = g.astype(jnp.float32).reshape(-1)
        pad = (-flat.size) % cfg.block
        flat = jnp.pad(flat, (0, pad))
        blocks = flat.reshape(-1, cfg.block)
        scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / cfg.levels
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(blocks / scale), -cfg.levels, cfg.levels).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        out = deq.reshape(-1)[: g.size].reshape(g.shape)
        return out

    def compress_decompress(self, grads, err_state):
        """grads+err -> quantized grads, new error state."""
        if err_state is None:
            err_state = self.init_state(grads)

        def one(g, e):
            corrected = g.astype(jnp.float32) + e
            deq = self._quant_dequant(corrected)
            return deq.astype(g.dtype), corrected - deq

        out = jax.tree_util.tree_map(one, grads, err_state)
        new_g = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_e = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_g, new_e

    def compressed_psum(self, grads, axis_name: str):
        """Manual-collective path (inside shard_map): quantize, all-reduce
        int32 accumulators, dequantize.  Moves ~4x fewer bytes than f32
        psum on the DP axis."""
        cfg = self.cfg

        def one(g):
            flat = g.astype(jnp.float32).reshape(-1)
            pad = (-flat.size) % cfg.block
            flat = jnp.pad(flat, (0, pad))
            blocks = flat.reshape(-1, cfg.block)
            scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / cfg.levels
            scale = jnp.maximum(scale, 1e-12)
            q = jnp.clip(jnp.round(blocks / scale), -cfg.levels, cfg.levels).astype(jnp.int32)
            qsum = jax.lax.psum(q, axis_name)
            ssum = jax.lax.psum(scale, axis_name)  # average the scales
            n = jax.lax.psum(1, axis_name)
            deq = qsum.astype(jnp.float32) * (ssum / n)
            return deq.reshape(-1)[: g.size].reshape(g.shape).astype(g.dtype) / n

        return jax.tree_util.tree_map(one, grads)
