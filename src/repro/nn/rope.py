"""Rotary position embeddings (RoPE), supporting arbitrary position ids."""
from __future__ import annotations

import jax.numpy as jnp


def rope_frequencies(d_head: int, theta: float = 10000.0):
    """Inverse frequencies for half the head dim."""
    half = d_head // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float = 10000.0):
    """Apply RoPE.

    x:         (..., seq, heads, d_head)  [or (..., seq, d_head)]
    positions: (..., seq) integer position ids broadcastable to x's seq dim.
    """
    d_head = x.shape[-1]
    inv_freq = rope_frequencies(d_head, theta)  # (half,)
    # angles: (..., seq, half)
    angles = positions.astype(jnp.float32)[..., None] * inv_freq
    cos = jnp.cos(angles)
    sin = jnp.sin(angles)
    if x.ndim == positions.ndim + 2:  # heads axis present between seq and d_head
        cos = cos[..., None, :]
        sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
