"""Parameter annotation substrate.

Every parameter created by a layer's ``init`` is wrapped in :class:`P`,
which carries the array together with per-dimension *logical axis* names
("embed", "mlp", "heads", "vocab", "experts", ...).  The distributed layer
(`repro.distributed.sharding`) later maps logical axes onto mesh axes,
falling back to replication when a dimension is not divisible.

``split(tree)`` separates a P-tree into (value pytree, axes pytree) so the
value tree is a plain jit-able pytree of arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class P:
    """An annotated parameter: array + logical axis name per dimension.

    Registered as a pytree node (axes are static aux data) so annotated
    trees pass through jit/vmap — ``jax.vmap`` over a layer ``init``
    produces stacked values whose axes tuples then describe the *trailing*
    dims (the sharding resolver pads leading dims with None).
    """

    value: jnp.ndarray
    axes: Tuple[Optional[str], ...]


jax.tree_util.register_pytree_node(
    P,
    lambda p: ((p.value,), p.axes),
    lambda axes, children: P(children[0], axes),
)


def is_annotated(x: Any) -> bool:
    return isinstance(x, P)


def split(tree):
    """P-tree -> (values, axes).  Non-P leaves pass through with axes=None."""
    values = jax.tree_util.tree_map(
        lambda x: x.value if isinstance(x, P) else x, tree, is_leaf=is_annotated
    )
    axes = jax.tree_util.tree_map(
        lambda x: x.axes if isinstance(x, P) else None, tree, is_leaf=is_annotated
    )
    return values, axes


def merge(values, axes):
    """Inverse of :func:`split`."""
    return jax.tree_util.tree_map(
        lambda v, a: P(v, a) if a is not None else v, values, axes,
        is_leaf=lambda x: x is None,
    )


def param_count(values) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(values))


def param_bytes(values) -> int:
    return sum(
        int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(values)
    )
