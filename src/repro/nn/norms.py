"""Normalization layers (RMSNorm / LayerNorm), functional style."""
from __future__ import annotations

import jax.numpy as jnp

from repro.nn.types import P


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": P(jnp.ones((d,), dtype), ("embed",))}


def rmsnorm_apply(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * (var + eps) ** -0.5
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {
        "scale": P(jnp.ones((d,), dtype), ("embed",)),
        "bias": P(jnp.zeros((d,), dtype), ("embed",)),
    }


def layernorm_apply(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * (var + eps) ** -0.5
    return (y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)).astype(dtype)


NORM_INIT = {"rmsnorm": rmsnorm_init, "layernorm": layernorm_init}
NORM_APPLY = {"rmsnorm": rmsnorm_apply, "layernorm": layernorm_apply}
