"""1-D convolution / pooling primitives (channels-last: (B, L, C)).

Used by the paper-native NAS search spaces (1-D convolutional classifiers
over sensor streams) and by tests.  LM frontends for audio/vision are
stubs per the assignment brief.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.nn import initializers as init
from repro.nn.types import P


def conv1d_init(key, in_ch, out_ch, kernel_size, dtype=jnp.float32, use_bias=True):
    kw, kb = jax.random.split(key)
    params = {
        "w": P(
            init.scaled_normal(kw, (kernel_size, in_ch, out_ch), dtype, fan_in=kernel_size * in_ch),
            (None, None, "mlp"),
        )
    }
    if use_bias:
        params["b"] = P(jnp.zeros((out_ch,), dtype), ("mlp",))
    return params


def conv1d_apply(params, x, stride=1, padding="SAME"):
    """x: (B, L, C_in) -> (B, L', C_out)."""
    y = lax.conv_general_dilated(
        x,
        params["w"],
        window_strides=(stride,),
        padding=padding,
        dimension_numbers=("NWC", "WIO", "NWC"),
    )
    if "b" in params:
        y = y + params["b"]
    return y


def conv1d_out_len(l, kernel_size, stride, padding="SAME"):
    if padding == "SAME":
        return -(-l // stride)
    return (l - kernel_size) // stride + 1


def maxpool1d(x, window=2, stride=None):
    stride = stride or window
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, window, 1),
        window_strides=(1, stride, 1),
        padding="VALID",
    )


def avgpool1d(x, window=2, stride=None):
    stride = stride or window
    summed = lax.reduce_window(
        x,
        0.0,
        lax.add,
        window_dimensions=(1, window, 1),
        window_strides=(1, stride, 1),
        padding="VALID",
    )
    return summed / window


def pool_out_len(l, window, stride=None):
    stride = stride or window
    return (l - window) // stride + 1


def global_avg_pool(x):
    return jnp.mean(x, axis=1)
