"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, true recurrence).

mLSTM uses the same TPU-native chunking strategy as Mamba2: intra-chunk
work becomes MXU matmuls over (chunk x chunk) tiles, the inter-chunk state
``(C, n, m)`` is carried by a short scan.  Exponential gating is stabilized
with the running max ``m`` exactly as in the xLSTM paper.  A step-by-step
recurrent oracle (``mlstm_recurrent``) is used by tests and by decode.

sLSTM has hidden-state-dependent gates, so it is inherently sequential;
we implement it as a `lax.scan` over time with block-diagonal (per-head)
recurrent matrices.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn import initializers as init
from repro.nn.types import P as Param


@dataclasses.dataclass(frozen=True)
class MLSTMConfig:
    d_model: int
    n_heads: int = 4
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128
    impl: str = "xla"  # "xla" | "pallas"
    # dry-run cost accounting: unroll the chunk scan so HloCostAnalysis
    # sees every chunk's matmuls (see launch/dryrun.py)
    scan_unroll: bool = False

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def d_head(self) -> int:
        return self.d_inner // self.n_heads


@dataclasses.dataclass(frozen=True)
class SLSTMConfig:
    d_model: int
    n_heads: int = 4
    conv_width: int = 4
    proj_factor: float = 4.0 / 3.0

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_up(self) -> int:
        # round up to a multiple of 64 for MXU alignment
        return int(-(-self.d_model * self.proj_factor // 64) * 64)


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------

def mlstm_init(cfg: MLSTMConfig, key, dtype=jnp.float32):
    d_in = cfg.d_inner
    ks = jax.random.split(key, 8)
    return {
        "up_proj": Param(init.scaled_normal(ks[0], (cfg.d_model, 2 * d_in), dtype), ("embed", "mlp")),
        "conv_w": Param(init.scaled_normal(ks[1], (cfg.conv_width, d_in), dtype, fan_in=cfg.conv_width), (None, "mlp")),
        "conv_b": Param(jnp.zeros((d_in,), dtype), ("mlp",)),
        # per-head block-diagonal projections (official xLSTM BlockLinear)
        "wq": Param(init.scaled_normal(ks[2], (cfg.n_heads, cfg.d_head, cfg.d_head), dtype, fan_in=cfg.d_head), ("heads", "mlp", None)),
        "wk": Param(init.scaled_normal(ks[3], (cfg.n_heads, cfg.d_head, cfg.d_head), dtype, fan_in=cfg.d_head), ("heads", "mlp", None)),
        "wv": Param(init.scaled_normal(ks[4], (cfg.n_heads, cfg.d_head, cfg.d_head), dtype, fan_in=cfg.d_head), ("heads", "mlp", None)),
        "w_if": Param(init.scaled_normal(ks[5], (d_in, 2 * cfg.n_heads), jnp.float32), ("mlp", None)),
        "b_if": Param(jnp.concatenate([jnp.zeros((cfg.n_heads,)), 3.0 * jnp.ones((cfg.n_heads,))]), (None,)),
        "norm_scale": Param(jnp.ones((d_in,), dtype), ("mlp",)),
        "down_proj": Param(init.scaled_normal(ks[6], (d_in, cfg.d_model), dtype, fan_in=d_in), ("mlp", "embed")),
    }


def _causal_conv(x, w, b, state=None):
    from repro.nn.ssm import causal_conv1d

    return causal_conv1d(x, w, b, state)


def mlstm_chunked(q, k, v, i_log, f_log, chunk, initial=None, unroll=False):
    """Chunkwise-parallel mLSTM cell.

    q, k, v: (B, L, H, P);  i_log, f_log: (B, L, H) log-space gates.
    Returns (h (B,L,H,P), final (C, n, m)).
    """
    b, l, h, p = q.shape
    assert l % chunk == 0
    nc, qq = l // chunk, chunk
    scale = p ** -0.5

    qc = q.reshape(b, nc, qq, h, p)
    kc = k.reshape(b, nc, qq, h, p) * scale
    vc = v.reshape(b, nc, qq, h, p)
    ic = i_log.reshape(b, nc, qq, h).astype(jnp.float32)
    fc = f_log.reshape(b, nc, qq, h).astype(jnp.float32)
    fcum = jnp.cumsum(fc, axis=2)  # inclusive within chunk
    ftot = fcum[:, :, -1]  # (b,nc,h)

    if initial is None:
        c0 = jnp.zeros((b, h, p, p), jnp.float32)
        n0 = jnp.zeros((b, h, p), jnp.float32)
        m0 = jnp.full((b, h), -jnp.inf, jnp.float32)
    else:
        c0, n0, m0 = initial

    tri = jnp.tril(jnp.ones((qq, qq), bool))[None, None]  # (1,1,q,q)

    def chunk_step(carry, inp):
        c_s, n_s, m_s = carry
        qk_, kk_, vk_, ik_, fk_, fcum_k, ftot_k = inp
        # log weights: intra  a[i,j] = fcum_i - fcum_j + i_j   (j <= i)
        #              inter  b[i]   = fcum_i + m_s
        fci = fcum_k.transpose(0, 2, 1)  # (b,h,q)
        a_log = fci[:, :, :, None] - fci[:, :, None, :] + ik_.transpose(0, 2, 1)[:, :, None, :]
        a_log = jnp.where(tri, a_log, -jnp.inf)  # (b,h,qi,qj)
        b_log = fci + m_s[:, :, None]  # (b,h,q)
        m_i = jnp.maximum(jnp.max(a_log, axis=-1), b_log)  # (b,h,q)
        m_i = jnp.maximum(m_i, -(10.0 ** 6))  # avoid -inf - -inf
        intra_w = jnp.exp(a_log - m_i[..., None])  # (b,h,qi,qj)
        inter_w = jnp.exp(b_log - m_i)  # (b,h,q)

        qkT = jnp.einsum("bqhp,bjhp->bhqj", qk_, kk_).astype(jnp.float32)
        s_intra = qkT * intra_w
        h_num = jnp.einsum("bhqj,bjhp->bqhp", s_intra.astype(vk_.dtype), vk_).astype(jnp.float32)
        h_num += jnp.einsum("bqhp,bhpd,bhq->bqhd", qk_.astype(jnp.float32), c_s, inter_w)
        denom = s_intra.sum(axis=-1)  # (b,h,q)
        denom += jnp.einsum("bqhp,bhp->bhq", qk_.astype(jnp.float32), n_s) * inter_w
        denom = jnp.maximum(jnp.abs(denom), jnp.exp(-m_i))  # (b,h,q)
        h_out = h_num / denom.transpose(0, 2, 1)[..., None]

        # state update to chunk end
        w_log = ftot_k[:, :, None] - fci + ik_.transpose(0, 2, 1)  # (b,h,q)
        m_next = jnp.maximum(ftot_k + m_s, jnp.max(w_log, axis=-1))
        m_next = jnp.maximum(m_next, -(10.0 ** 6))
        kw = jnp.exp(w_log - m_next[..., None])  # (b,h,q)
        c_upd = jnp.einsum("bjhp,bhj,bjhd->bhpd", kk_.astype(jnp.float32), kw, vk_.astype(jnp.float32))
        n_upd = jnp.einsum("bjhp,bhj->bhp", kk_.astype(jnp.float32), kw)
        carry_decay = jnp.exp(ftot_k + m_s - m_next)[:, :, None]
        c_next = carry_decay[..., None] * c_s + c_upd
        n_next = carry_decay * n_s + n_upd
        return (c_next, n_next, m_next), h_out

    xs = (
        qc.transpose(1, 0, 2, 3, 4),
        kc.transpose(1, 0, 2, 3, 4),
        vc.transpose(1, 0, 2, 3, 4),
        ic.transpose(1, 0, 2, 3),
        fc.transpose(1, 0, 2, 3),
        fcum.transpose(1, 0, 2, 3),
        ftot.transpose(1, 0, 2),
    )
    (c_f, n_f, m_f), hs = jax.lax.scan(chunk_step, (c0, n0, m0), xs,
                                       unroll=nc if unroll else 1)
    h_seq = hs.transpose(1, 0, 2, 3, 4).reshape(b, l, h, p)
    return h_seq.astype(q.dtype), (c_f, n_f, m_f)


def mlstm_step(state, q_t, k_t, v_t, i_t, f_t):
    """Single recurrent mLSTM step.  q/k/v: (B,H,P); i/f: (B,H) raw logs.
    state = (C (B,H,P,P), n (B,H,P), m (B,H))."""
    c_s, n_s, m_s = state
    p = q_t.shape[-1]
    k_t = k_t * (p ** -0.5)
    m_next = jnp.maximum(f_t + m_s, i_t)
    m_next = jnp.maximum(m_next, -(10.0 ** 6))
    f_w = jnp.exp(f_t + m_s - m_next)[..., None]
    i_w = jnp.exp(i_t - m_next)[..., None]
    kf = k_t.astype(jnp.float32)
    vf = v_t.astype(jnp.float32)
    c_next = f_w[..., None] * c_s + i_w[..., None] * kf[..., :, None] * vf[..., None, :]
    n_next = f_w * n_s + i_w * kf
    qf = q_t.astype(jnp.float32)
    num = jnp.einsum("bhp,bhpd->bhd", qf, c_next)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", qf, n_next)), jnp.exp(-m_next))
    h = num / den[..., None]
    return (c_next, n_next, m_next), h.astype(q_t.dtype)


def mlstm_recurrent(q, k, v, i_log, f_log, initial=None):
    """Step-by-step oracle.  Same shapes/returns as :func:`mlstm_chunked`."""
    b, l, h, p = q.shape
    if initial is None:
        initial = (
            jnp.zeros((b, h, p, p), jnp.float32),
            jnp.zeros((b, h, p), jnp.float32),
            jnp.full((b, h), -jnp.inf, jnp.float32),
        )

    def step(carry, inp):
        qt, kt, vt, it, ft = inp
        carry, h_t = mlstm_step(carry, qt, kt, vt, it, ft)
        return carry, h_t

    xs = (
        q.transpose(1, 0, 2, 3),
        k.transpose(1, 0, 2, 3),
        v.transpose(1, 0, 2, 3),
        i_log.transpose(1, 0, 2).astype(jnp.float32),
        f_log.transpose(1, 0, 2).astype(jnp.float32),
    )
    final, hs = jax.lax.scan(step, initial, xs)
    return hs.transpose(1, 0, 2, 3), final


def _group_norm_heads(x, scale, eps=1e-6):
    """Per-head group norm over the head dim. x: (B,L,H,P), scale: (H*P,)."""
    b, l, h, p = x.shape
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * (var + eps) ** -0.5
    return (y.reshape(b, l, h * p) * scale.astype(jnp.float32)).astype(x.dtype)


def _mlstm_qkv_gates(params, cfg: MLSTMConfig, x, conv_state=None):
    b, l, _ = x.shape
    d_in = cfg.d_inner
    up = jnp.einsum("bld,dk->blk", x, params["up_proj"])
    xm, z = up[..., :d_in], up[..., d_in:]
    if conv_state is None:
        xc = jax.nn.silu(_causal_conv(xm, params["conv_w"], params["conv_b"]))
        new_conv = None
    else:
        xc, new_conv = _causal_conv(xm, params["conv_w"], params["conv_b"], state=conv_state)
        xc = jax.nn.silu(xc)
    xch = xc.reshape(b, l, cfg.n_heads, cfg.d_head)
    xmh = xm.reshape(b, l, cfg.n_heads, cfg.d_head)
    q = jnp.einsum("blhp,hpk->blhk", xch, params["wq"])
    k = jnp.einsum("blhp,hpk->blhk", xch, params["wk"])
    v = jnp.einsum("blhp,hpk->blhk", xmh, params["wv"])
    if_pre = jnp.einsum("bld,dk->blk", xm.astype(jnp.float32), params["w_if"]) + params["b_if"]
    i_log = if_pre[..., : cfg.n_heads]
    f_log = jax.nn.log_sigmoid(if_pre[..., cfg.n_heads :])
    return q, k, v, i_log, f_log, z, new_conv


def _fit_chunk(l: int, chunk: int) -> int:
    ck = min(chunk, l)
    while l % ck:
        ck -= 1
    return ck


def mlstm_block_apply(params, cfg: MLSTMConfig, x):
    """Full mLSTM block: up-proj, conv, cell, gated output, down-proj."""
    q, k, v, i_log, f_log, z, _ = _mlstm_qkv_gates(params, cfg, x)
    chunk = _fit_chunk(x.shape[1], cfg.chunk)
    if cfg.impl == "pallas":
        from repro.kernels import ops as kops

        h, _ = kops.mlstm_scan(q, k, v, i_log, f_log, chunk=chunk)
    else:
        h, _ = mlstm_chunked(q, k, v, i_log, f_log, chunk, unroll=cfg.scan_unroll)
    h = _group_norm_heads(h, params["norm_scale"])
    h = h * jax.nn.silu(z)
    return jnp.einsum("bld,dk->blk", h, params["down_proj"])


def init_mlstm_cache(cfg: MLSTMConfig, batch, dtype=jnp.float32):
    p = cfg.d_head
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner), dtype),
        "c": jnp.zeros((batch, cfg.n_heads, p, p), jnp.float32),
        "n": jnp.zeros((batch, cfg.n_heads, p), jnp.float32),
        "m": jnp.full((batch, cfg.n_heads), -1e6, jnp.float32),
    }


def mlstm_block_decode(params, cfg: MLSTMConfig, x, cache):
    """One-token decode.  x: (B,1,d_model)."""
    q, k, v, i_log, f_log, z, new_conv = _mlstm_qkv_gates(
        params, cfg, x, conv_state=cache["conv"].astype(x.dtype)
    )
    state = (cache["c"], cache["n"], cache["m"])
    state, h_t = mlstm_step(
        state, q[:, 0], k[:, 0], v[:, 0], i_log[:, 0], f_log[:, 0]
    )
    h = h_t[:, None]
    h = _group_norm_heads(h, params["norm_scale"])
    h = h * jax.nn.silu(z)
    out = jnp.einsum("bld,dk->blk", h, params["down_proj"])
    new_cache = {
        "conv": new_conv.astype(cache["conv"].dtype),
        "c": state[0],
        "n": state[1],
        "m": state[2],
    }
    return out, new_cache


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------

def slstm_init(cfg: SLSTMConfig, key, dtype=jnp.float32):
    d, hh, p = cfg.d_model, cfg.n_heads, cfg.d_head
    ks = jax.random.split(key, 6)
    return {
        "conv_w": Param(init.scaled_normal(ks[0], (cfg.conv_width, d), dtype, fan_in=cfg.conv_width), (None, "embed")),
        "conv_b": Param(jnp.zeros((d,), dtype), ("embed",)),
        "w_gates": Param(init.scaled_normal(ks[1], (d, 4 * d), dtype), ("embed", "mlp")),
        "r_gates": Param(init.scaled_normal(ks[2], (hh, p, 4 * p), dtype, fan_in=p), (None, None, None)),
        "b_gates": Param(jnp.zeros((4 * d,), jnp.float32), ("mlp",)),
        "norm_scale": Param(jnp.ones((d,), dtype), ("embed",)),
        "up_proj": Param(init.scaled_normal(ks[3], (d, 2 * cfg.d_up), dtype), ("embed", "mlp")),
        "down_proj": Param(init.scaled_normal(ks[4], (cfg.d_up, d), dtype, fan_in=cfg.d_up), ("mlp", "embed")),
    }


def slstm_cell_step(state, x_gates, r_w, n_heads, d_head):
    """One sLSTM step.  state = (c, n, m, h) each (B, H, P) except m (B,H).
    x_gates: (B, 4*d) input-side gate preactivations."""
    c_s, n_s, m_s, h_s = state
    b = x_gates.shape[0]
    # recurrent contribution: block-diagonal per head
    h_heads = h_s.reshape(b, n_heads, d_head)
    r_contrib = jnp.einsum("bhp,hpk->bhk", h_heads.astype(jnp.float32), r_w.astype(jnp.float32))
    # gate layout is per-head-major: (head, gate-kind, unit)
    gates = x_gates.astype(jnp.float32).reshape(b, n_heads, 4, d_head) + r_contrib.reshape(
        b, n_heads, 4, d_head
    )
    i_raw, f_raw = gates[:, :, 0], gates[:, :, 1]
    z_raw, o_raw = gates[:, :, 2], gates[:, :, 3]
    f_log = jax.nn.log_sigmoid(f_raw)
    m_next = jnp.maximum(f_log + m_s, i_raw)
    m_next = jnp.maximum(m_next, -(10.0 ** 6))
    i_w = jnp.exp(i_raw - m_next)
    f_w = jnp.exp(f_log + m_s - m_next)
    c_next = f_w * c_s + i_w * jnp.tanh(z_raw)
    n_next = f_w * n_s + i_w
    h_next = jax.nn.sigmoid(o_raw) * c_next / jnp.maximum(n_next, 1.0)
    return (c_next, n_next, m_next, h_next.astype(h_s.dtype))


def slstm_block_apply(params, cfg: SLSTMConfig, x, cache=None):
    """sLSTM block forward (scan over time).  x: (B, L, d_model).

    When ``cache`` is provided (decode), x is (B, 1, d) and the updated
    cache is returned alongside the output.
    """
    b, l, d = x.shape
    decode = cache is not None
    if decode:
        xc, new_conv = _causal_conv(x, params["conv_w"], params["conv_b"], state=cache["conv"].astype(x.dtype))
        xc = jax.nn.silu(xc)
        state = (cache["c"], cache["n"], cache["m"], cache["h"])
    else:
        xc = jax.nn.silu(_causal_conv(x, params["conv_w"], params["conv_b"]))
        state = (
            jnp.zeros((b, cfg.n_heads, cfg.d_head), jnp.float32),
            jnp.zeros((b, cfg.n_heads, cfg.d_head), jnp.float32),
            jnp.full((b, cfg.n_heads, cfg.d_head), -1e6, jnp.float32),
            jnp.zeros((b, cfg.n_heads, cfg.d_head), x.dtype),
        )
    x_gates_all = jnp.einsum("bld,dk->blk", xc, params["w_gates"]) + params["b_gates"]

    if decode:
        state = slstm_cell_step(state, x_gates_all[:, 0], params["r_gates"], cfg.n_heads, cfg.d_head)
        h_seq = state[3].reshape(b, 1, d)
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "c": state[0], "n": state[1], "m": state[2], "h": state[3]}
    else:
        def step(carry, xg):
            carry = slstm_cell_step(carry, xg, params["r_gates"], cfg.n_heads, cfg.d_head)
            return carry, carry[3]

        _, hs = jax.lax.scan(step, state, x_gates_all.transpose(1, 0, 2))
        h_seq = hs.transpose(1, 0, 2, 3).reshape(b, l, d)
        new_cache = None

    # output: group norm + gated up/down projection
    xf = h_seq.astype(jnp.float32).reshape(b, -1, cfg.n_heads, cfg.d_head)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = ((xf - mu) * (var + 1e-6) ** -0.5).reshape(b, -1, d).astype(x.dtype) * params["norm_scale"]
    up = jnp.einsum("bld,dk->blk", y, params["up_proj"])
    u1, u2 = jnp.split(up, 2, axis=-1)
    y = jax.nn.gelu(u1) * u2
    out = jnp.einsum("bld,dk->blk", y, params["down_proj"])
    if decode:
        return out, new_cache
    return out


def init_slstm_cache(cfg: SLSTMConfig, batch, dtype=jnp.float32):
    hp = (batch, cfg.n_heads, cfg.d_head)
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_model), dtype),
        "c": jnp.zeros(hp, jnp.float32),
        "n": jnp.zeros(hp, jnp.float32),
        "m": jnp.full(hp, -1e6, jnp.float32),
        "h": jnp.zeros(hp, dtype),
    }
