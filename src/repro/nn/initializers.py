"""Weight initializers (pure functions of a PRNG key)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def normal(key, shape, dtype=jnp.float32, stddev=0.02):
    return (jax.random.normal(key, shape) * stddev).astype(dtype)


def scaled_normal(key, shape, dtype=jnp.float32, fan_in=None):
    """Truncated-normal scaled by 1/sqrt(fan_in) (default: first dim)."""
    fan = fan_in if fan_in is not None else shape[0]
    std = (1.0 / max(1, fan)) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


def zeros(_key, shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones(_key, shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)
