"""Mixture-of-Experts feed-forward with token-choice top-k routing.

Dispatch is *gather-based*: instead of scattering tokens into expert
buffers (scatters shard poorly under GSPMD), we compute, for every expert
slot ``(e, c)``, the token index that fills it, and gather.  The combine is
another gather.  HLO FLOPs stay proportional to ``top_k * capacity_factor``
(active experts), not ``n_experts`` — critical for the arctic-480b
(128-expert) roofline.

Routing is per batch row (tokens never cross rows), so data-parallel
sharding needs no routing communication; expert parallelism shards the
``experts`` logical axis of the buffers and weights.

Supports the two assigned MoE archs:
  * dbrx-132b   — 16 experts, top-4
  * arctic-480b — 128 experts, top-2, plus a *dense residual* MLP branch
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import initializers as init
from repro.nn.mlp import ACTIVATIONS, MLPConfig, mlp_apply, mlp_init
from repro.nn.types import P


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    activation: str = "silu"
    gated: bool = True
    dense_residual: bool = False  # arctic-style parallel dense MLP
    dense_d_ff: Optional[int] = None
    router_jitter: float = 0.0
    # shard_ff: 2D expert sharding (experts -> model axis, d_ff -> data
    # axis).  Keeps the huge expert weights fully resident instead of
    # FSDP-regathering them every layer: collectives become
    # activation-sized reduce-scatters.  §Perf beyond-paper optimization.
    shard_ff: bool = False

    def capacity(self, seq: int) -> int:
        cap = int(self.top_k * seq * self.capacity_factor / self.n_experts)
        return max(1, min(seq, cap))


def moe_init(cfg: MoEConfig, key, dtype=jnp.float32):
    kr, kg, ku, kd, kres = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    if cfg.shard_ff:
        up_axes, down_axes = ("experts", None, "expert_mlp"), ("experts", "expert_mlp", None)
    else:
        up_axes, down_axes = ("experts", "embed", "mlp"), ("experts", "mlp", "embed")
    params = {
        "w_router": P(init.scaled_normal(kr, (d, e), jnp.float32), ("embed", None)),
        "w_up": P(init.scaled_normal(ku, (e, d, f), dtype, fan_in=d), up_axes),
        "w_down": P(init.scaled_normal(kd, (e, f, d), dtype, fan_in=f), down_axes),
    }
    if cfg.gated:
        params["w_gate"] = P(init.scaled_normal(kg, (e, d, f), dtype, fan_in=d), up_axes)
    if cfg.dense_residual:
        dcfg = MLPConfig(cfg.d_model, cfg.dense_d_ff or cfg.d_ff, cfg.activation, gated=cfg.gated)
        params["dense"] = mlp_init(dcfg, kres, dtype)
    return params


def route_topk(router_logits, top_k):
    """Top-k routing.  router_logits: (B,S,E) f32.

    Returns (expert_ids (B,S,K) int32, gates (B,S,K) f32 renormalized,
             full_probs (B,S,E) f32 for aux losses).
    """
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gates, expert_ids = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.clip(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return expert_ids.astype(jnp.int32), gates, probs


def _slot_assignment(expert_ids, n_experts, capacity):
    """Compute the gather plan for one batch of routed tokens.

    expert_ids: (B, S, K).  Flattened choice order is row-major in (s, k) so
    earlier tokens win capacity (stable, matches GShard cumsum semantics).

    Returns:
      slot_token: (B, E, C) int32 — flat (s*K+k) choice index filling each
                  expert slot, or -1.
      token_slot: (B, S, K) int32 — capacity slot for each choice, or -1
                  when dropped.
    """
    b, s, k = expert_ids.shape
    flat = expert_ids.reshape(b, s * k)
    n = s * k
    # Stable sort by expert id; ties keep (s,k) order.
    sort_idx = jnp.argsort(flat, axis=-1, stable=True)  # (B, N)
    sorted_experts = jnp.take_along_axis(flat, sort_idx, axis=-1)
    # Position within each expert's run.
    arange = jnp.arange(n)[None, :]
    seg_start = jnp.where(
        sorted_experts[:, :, None] == jnp.arange(n_experts)[None, None, :],
        arange[:, :, None],
        n,
    ).min(axis=1)  # (B, E): first sorted index of each expert (n if absent)
    pos_in_expert = arange - jnp.take_along_axis(seg_start, sorted_experts, axis=-1)
    # slot_token[b, e, c] = sorted choice at seg_start[e] + c, if within run.
    c_idx = jnp.arange(capacity)[None, None, :]
    gather_idx = jnp.clip(seg_start[:, :, None] + c_idx, 0, n - 1)
    cand = jnp.take_along_axis(sort_idx, gather_idx.reshape(b, -1), axis=-1).reshape(
        b, n_experts, capacity
    )
    cand_expert = jnp.take_along_axis(
        sorted_experts, jnp.clip(gather_idx, 0, n - 1).reshape(b, -1), axis=-1
    ).reshape(b, n_experts, capacity)
    valid_slot = (cand_expert == jnp.arange(n_experts)[None, :, None]) & (
        seg_start[:, :, None] + c_idx < n
    )
    slot_token = jnp.where(valid_slot, cand, -1)
    # token_slot: invert. pos_in_expert per sorted entry; map back to choice.
    kept = pos_in_expert < capacity
    choice_slot_sorted = jnp.where(kept, pos_in_expert, -1)
    token_slot = jnp.take_along_axis(
        choice_slot_sorted, jnp.argsort(sort_idx, axis=-1), axis=-1
    )
    return slot_token, token_slot.reshape(b, s, k)


def moe_apply(params, cfg: MoEConfig, x, return_aux: bool = False):
    """x: (B, S, d_model) -> (B, S, d_model)."""
    b, s, d = x.shape
    cap = cfg.capacity(s)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["w_router"])
    expert_ids, gates, probs = route_topk(logits, cfg.top_k)
    slot_token, token_slot = _slot_assignment(expert_ids, cfg.n_experts, cap)

    # Dispatch: gather tokens into (B, E, C, d).
    token_of_choice = jnp.clip(slot_token, 0) // cfg.top_k  # flat choice -> s
    gather_s = token_of_choice.reshape(b, cfg.n_experts * cap)
    buf = jnp.take_along_axis(x, gather_s[:, :, None], axis=1)
    buf = buf.reshape(b, cfg.n_experts, cap, d)
    buf = buf * (slot_token >= 0)[..., None].astype(buf.dtype)

    # Expert computation: (B,E,C,d) x (E,d,f).
    act = ACTIVATIONS[cfg.activation]
    up = jnp.einsum("becd,edf->becf", buf, params["w_up"])
    if cfg.gated:
        gate = act(jnp.einsum("becd,edf->becf", buf, params["w_gate"]))
        h = gate * up
    else:
        h = act(up)
    out_buf = jnp.einsum("becf,efd->becd", h, params["w_down"])  # (B,E,C,d)

    # Combine: for each (token, choice) gather its slot output.
    flat_out = out_buf.reshape(b, cfg.n_experts * cap, d)
    choice_expert = expert_ids.reshape(b, s * cfg.top_k)
    choice_slot = token_slot.reshape(b, s * cfg.top_k)
    flat_idx = jnp.clip(choice_expert * cap + choice_slot, 0)
    y = jnp.take_along_axis(flat_out, flat_idx[:, :, None], axis=1)
    y = y * (choice_slot >= 0)[..., None].astype(y.dtype)
    y = y.reshape(b, s, cfg.top_k, d)
    y = jnp.sum(y * gates[..., None].astype(y.dtype), axis=2)

    if cfg.dense_residual:
        dcfg = MLPConfig(cfg.d_model, cfg.dense_d_ff or cfg.d_ff, cfg.activation, gated=cfg.gated)
        y = y + mlp_apply(params["dense"], dcfg, x)

    if return_aux:
        # Load-balancing auxiliaries (Switch-style).
        me = jnp.mean(probs, axis=(0, 1))  # mean router prob per expert
        ce = jnp.mean(
            (jax.nn.one_hot(expert_ids, cfg.n_experts).sum(2) > 0).astype(jnp.float32),
            axis=(0, 1),
        )
        aux = {
            "load_balance_loss": cfg.n_experts * jnp.sum(me * ce),
            "dropped_fraction": jnp.mean((token_slot < 0).astype(jnp.float32)),
        }
        return y.astype(x.dtype), aux
    return y.astype(x.dtype)
