"""Feed-forward blocks: SwiGLU / GELU / squared-ReLU / ReLU variants."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn import initializers as init
from repro.nn.types import P


def squared_relu(x):
    r = jax.nn.relu(x)
    return r * r


ACTIVATIONS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "squared_relu": squared_relu,
    "identity": lambda x: x,
}


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    d_model: int
    d_ff: int
    activation: str = "silu"
    gated: bool = True  # SwiGLU-style gate when True
    use_bias: bool = False


def mlp_init(cfg: MLPConfig, key, dtype=jnp.float32):
    kg, ku, kd = jax.random.split(key, 3)
    params = {
        "w_up": P(init.scaled_normal(ku, (cfg.d_model, cfg.d_ff), dtype), ("embed", "mlp")),
        "w_down": P(init.scaled_normal(kd, (cfg.d_ff, cfg.d_model), dtype, fan_in=cfg.d_ff), ("mlp", "embed")),
    }
    if cfg.gated:
        params["w_gate"] = P(init.scaled_normal(kg, (cfg.d_model, cfg.d_ff), dtype), ("embed", "mlp"))
    if cfg.use_bias:
        params["b_up"] = P(jnp.zeros((cfg.d_ff,), dtype), ("mlp",))
        params["b_down"] = P(jnp.zeros((cfg.d_model,), dtype), ("embed",))
    return params


def mlp_apply(params, cfg: MLPConfig, x):
    act = ACTIVATIONS[cfg.activation]
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    if cfg.use_bias:
        up = up + params["b_up"]
    if cfg.gated:
        gate = act(jnp.einsum("bsd,df->bsf", x, params["w_gate"]))
        h = gate * up
    else:
        h = act(up)
    out = jnp.einsum("bsf,fd->bsd", h, params["w_down"])
    if cfg.use_bias:
        out = out + params["b_down"]
    return out
