"""Grouped-query attention with qk-norm, RoPE, sliding windows and KV caches.

Three entry points:
  * ``attention_init``    -- parameters
  * ``attention_apply``   -- full-sequence (training / prefill / encoder /
                             cross-attention) attention
  * ``attention_decode``  -- single-token decode against a preallocated
                             KV cache (in-place ``.at[].set`` update)

The sequence-mixing math is grouped (no materialized KV repetition): q is
reshaped to (batch, seq, kv_heads, group, d_head) so the einsum contracts
directly against the grouped KV, which keeps HLO FLOPs/bytes at the GQA
level rather than the MHA level.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import initializers as init
from repro.nn.rope import apply_rope
from repro.nn.types import P

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: Optional[int] = None
    use_bias: bool = False
    qk_norm: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    causal: bool = True
    window: Optional[int] = None  # sliding-window size (None = full)
    impl: str = "xla"  # "xla" | "xla_chunked" | "pallas"
    softmax_scale: Optional[float] = None
    # cost-variant accounting: unroll the chunked-attention KV scan so
    # HloCostAnalysis sees every chunk (see launch/dryrun.py)
    scan_unroll: bool = False
    kv_chunk: int = 1024  # xla_chunked block size (bigger = fewer carry rewrites)
    # context-parallel q + replicated kv in full-seq attention (see
    # _project_qkv docstring); enabled by the "seq_shard" dry-run variant
    seq_shard: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def group(self) -> int:
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads

    @property
    def scale(self) -> float:
        return (
            self.softmax_scale
            if self.softmax_scale is not None
            else self.head_dim ** -0.5
        )


def attention_init(cfg: AttentionConfig, key, dtype=jnp.float32):
    dh = cfg.head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    params = {
        "wq": P(init.scaled_normal(kq, (cfg.d_model, cfg.n_heads * dh), dtype), ("embed", "heads")),
        "wk": P(init.scaled_normal(kk, (cfg.d_model, cfg.n_kv_heads * dh), dtype), ("embed", "kv_heads")),
        "wv": P(init.scaled_normal(kv, (cfg.d_model, cfg.n_kv_heads * dh), dtype), ("embed", "kv_heads")),
        "wo": P(init.scaled_normal(ko, (cfg.n_heads * dh, cfg.d_model), dtype, fan_in=cfg.n_heads * dh), ("heads", "embed")),
    }
    if cfg.use_bias:
        params["bq"] = P(jnp.zeros((cfg.n_heads * dh,), dtype), ("heads",))
        params["bk"] = P(jnp.zeros((cfg.n_kv_heads * dh,), dtype), ("kv_heads",))
        params["bv"] = P(jnp.zeros((cfg.n_kv_heads * dh,), dtype), ("kv_heads",))
    if cfg.qk_norm:
        params["q_norm"] = P(jnp.ones((dh,), dtype), (None,))
        params["k_norm"] = P(jnp.ones((dh,), dtype), (None,))
    return params


def _headwise_rmsnorm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * (var + eps) ** -0.5 * scale.astype(jnp.float32)).astype(x.dtype)


def _project_qkv(params, cfg: AttentionConfig, x, kv_x, positions, kv_positions,
                 constrain_full_seq: bool = False):
    """Shared projection path. Returns q:(B,S,H,Dh), k/v:(B,T,K,Dh).

    constrain_full_seq (full-sequence attention only): pins q to
    sequence-sharded ("act_seq" -> model axis) and k/v to replicated
    heads.  Without this, GSPMD can slide the fused-head-projection
    sharding onto the head_dim when n_heads doesn't divide the model axis
    (e.g. 56 heads on 16 chips) and then all-reduces the full O(S^2)
    score tensors — observed 896 GiB ARs on arctic-480b/prefill_32k.
    """
    from repro.distributed.api import constrain

    b, s, _ = x.shape
    dh = cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"])
    k = jnp.einsum("bsd,dh->bsh", kv_x, params["wk"])
    v = jnp.einsum("bsd,dh->bsh", kv_x, params["wv"])
    if cfg.use_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    t = kv_x.shape[1]
    q = q.reshape(b, s, cfg.n_heads, dh)
    k = k.reshape(b, t, cfg.n_kv_heads, dh)
    v = v.reshape(b, t, cfg.n_kv_heads, dh)
    if constrain_full_seq:
        q = constrain(q, ("batch", "act_seq", None, None))
        k = constrain(k, ("batch", None, None, None))
        v = constrain(v, ("batch", None, None, None))
    if cfg.qk_norm:
        q = _headwise_rmsnorm(q, params["q_norm"])
        k = _headwise_rmsnorm(k, params["k_norm"])
    if cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def chunked_attention(q, k, v, scale, *, causal=True, window=None, kv_chunk=1024,
                      q_offset=0, unroll=False):
    """Flash-style attention in pure JAX: lax.scan over KV chunks with an
    online softmax — O(S * kv_chunk) score memory instead of O(S^2), and
    GSPMD-shardable (used by the dry-run's optimized configs, where the
    Pallas kernel cannot lower on the CPU host platform).

    q: (B,S,H,Dh); k/v: (B,T,K,Dh).  Returns (B,S,H,Dh).
    """
    b, s, h, dh = q.shape
    t, kheads = k.shape[1], k.shape[2]
    g = h // kheads
    nchunks = t // kv_chunk
    assert t % kv_chunk == 0, (t, kv_chunk)
    qg = q.reshape(b, s, kheads, g, dh)
    q_pos = (jnp.arange(s) + q_offset)[:, None]

    kc = k.reshape(b, nchunks, kv_chunk, kheads, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunks, kv_chunk, kheads, dh).transpose(1, 0, 2, 3, 4)

    def body(carry, inp):
        m_prev, l_prev, acc = carry
        idx, k_blk, v_blk = inp
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, k_blk).astype(jnp.float32) * scale
        k_pos = idx * kv_chunk + jnp.arange(kv_chunk)[None, :]
        mask = jnp.ones((s, kv_chunk), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        m_cur = jnp.max(scores, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(scores - m_new[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(v_blk.dtype), v_blk
        ).astype(jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, kheads, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kheads, g, s), jnp.float32)
    acc0 = jnp.zeros((b, kheads, g, s, dh), jnp.float32)
    # unroll=True is used by the dry-run cost variant: HloCostAnalysis
    # counts while bodies once, so the KV loop must be visible.
    (m_f, l_f, acc), _ = jax.lax.scan(body, (m0, l0, acc0),
                                      (jnp.arange(nchunks), kc, vc),
                                      unroll=nchunks if unroll else 1)
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, dh).astype(q.dtype)


def grouped_attention(q, k, v, mask, scale):
    """Core GQA soft-attention.

    q: (B,S,H,Dh), k/v: (B,T,K,Dh), mask: broadcastable to (B,K,G,S,T).
    Returns (B,S,H,Dh).
    """
    b, s, h, dh = q.shape
    kheads = k.shape[2]
    g = h // kheads
    qg = q.reshape(b, s, kheads, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, dh)


def make_mask(s, t, causal, window, q_offset=0):
    """(1,1,1,S,T) boolean attention mask."""
    qi = jnp.arange(s)[:, None] + q_offset
    kj = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    return mask[None, None, None]


def attention_apply(
    params,
    cfg: AttentionConfig,
    x,
    positions=None,
    kv_x=None,
    kv_positions=None,
    mask=None,
):
    """Full-sequence attention.  ``kv_x`` enables cross-attention."""
    b, s, _ = x.shape
    cross = kv_x is not None
    if kv_x is None:
        kv_x = x
    if positions is None:
        positions = jnp.arange(s)[None]
    if kv_positions is None:
        kv_positions = jnp.arange(kv_x.shape[1])[None]
    q, k, v = _project_qkv(params, cfg, x, kv_x, positions, kv_positions,
                           constrain_full_seq=cfg.seq_shard and not cross)
    if mask is None:
        causal = cfg.causal and not cross
        mask = make_mask(s, kv_x.shape[1], causal, None if cross else cfg.window)
    if cfg.impl == "pallas" and not cross:
        from repro.kernels import ops as kops

        out = kops.flash_attention(
            q, k, v, causal=cfg.causal, window=cfg.window, scale=cfg.scale
        )
    elif cfg.impl == "xla_chunked" and not cross:
        kv_chunk = min(cfg.kv_chunk, kv_x.shape[1])
        while kv_x.shape[1] % kv_chunk:
            kv_chunk //= 2
        out = chunked_attention(
            q, k, v, cfg.scale, causal=cfg.causal, window=cfg.window,
            kv_chunk=max(kv_chunk, 1), unroll=cfg.scan_unroll,
        )
    else:
        out = grouped_attention(q, k, v, mask, cfg.scale)
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return jnp.einsum("bsh,hd->bsd", out, params["wo"])


def init_kv_cache(cfg: AttentionConfig, batch, max_seq, dtype=jnp.bfloat16):
    shape = (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def precompute_cross_kv(params, cfg: AttentionConfig, enc_out, dtype=jnp.bfloat16):
    """Project encoder output once; reused for every decode step."""
    b, t, _ = enc_out.shape
    k = jnp.einsum("bsd,dh->bsh", enc_out, params["wk"])
    v = jnp.einsum("bsd,dh->bsh", enc_out, params["wv"])
    if cfg.use_bias:
        k, v = k + params["bk"], v + params["bv"]
    dh = cfg.head_dim
    return {
        "k": k.reshape(b, t, cfg.n_kv_heads, dh).astype(dtype),
        "v": v.reshape(b, t, cfg.n_kv_heads, dh).astype(dtype),
    }


def cross_attention_cached(params, cfg: AttentionConfig, x, cache):
    """Decode-time cross-attention against a precomputed cross-KV cache."""
    b, s, _ = x.shape
    dh = cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"])
    if cfg.use_bias:
        q = q + params["bq"]
    q = q.reshape(b, s, cfg.n_heads, dh)
    if cfg.qk_norm:
        q = _headwise_rmsnorm(q, params["q_norm"])
    t = cache["k"].shape[1]
    mask = jnp.ones((1, 1, 1, s, t), bool)
    out = grouped_attention(q, cache["k"].astype(q.dtype), cache["v"].astype(q.dtype), mask, cfg.scale)
    out = out.reshape(b, s, cfg.n_heads * dh)
    return jnp.einsum("bsh,hd->bsd", out, params["wo"])


def attention_prefill(params, cfg: AttentionConfig, x, cache, pos_offset=0):
    """Batched prefill: full-sequence attention through the same kernel
    dispatch as :func:`attention_apply` (pallas flash / xla_chunked /
    grouped), writing the prompt's K/V into the preallocated cache in one
    shot instead of token-by-token.  x: (B,S,d_model); the prompt
    occupies cache positions ``[pos_offset, pos_offset+S)``.

    Returns (y (B,S,d_model), new_cache) — bitwise the same cache a
    ``attention_decode`` loop over the prompt would produce, at
    full-sequence kernel cost (see tests/test_serving.py).
    """
    b, s, _ = x.shape
    positions = (pos_offset + jnp.arange(s))[None]
    q, k, v = _project_qkv(params, cfg, x, x, positions, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), pos_offset, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), pos_offset, axis=1)
    if cfg.impl == "pallas" and pos_offset == 0:
        from repro.kernels import ops as kops

        out = kops.flash_attention(
            q, k, v, causal=cfg.causal, window=cfg.window, scale=cfg.scale)
    elif cfg.impl == "xla_chunked" and pos_offset == 0:
        kv_chunk = min(cfg.kv_chunk, s)
        while s % kv_chunk:
            kv_chunk //= 2
        out = chunked_attention(
            q, k, v, cfg.scale, causal=cfg.causal, window=cfg.window,
            kv_chunk=max(kv_chunk, 1), unroll=cfg.scan_unroll)
    else:
        # pos_offset > 0 (chunked prompt ingestion) attends against the
        # cache prefix, which the flash/chunked paths don't slice yet
        t = pos_offset + s
        mask = make_mask(s, t, cfg.causal, cfg.window, q_offset=pos_offset)
        k_pfx = jax.lax.dynamic_slice_in_dim(k_cache, 0, t, axis=1)
        v_pfx = jax.lax.dynamic_slice_in_dim(v_cache, 0, t, axis=1)
        out = grouped_attention(q, k_pfx.astype(q.dtype),
                                v_pfx.astype(q.dtype), mask, cfg.scale)
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    y = jnp.einsum("bsh,hd->bsd", out, params["wo"])
    return y, {"k": k_cache, "v": v_cache}


def attention_decode(params, cfg: AttentionConfig, x, cache, pos):
    """One-token decode.  x: (B,1,d_model); pos: scalar int32, or an
    int32 vector (B,) of *per-sequence* positions (continuous batching:
    each serving slot decodes at its own depth).

    Updates ``cache`` in place (functionally) and attends to positions
    ``<= pos`` (within the sliding window when configured).
    """
    b = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1
    positions = pos[:, None] if per_slot else jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(params, cfg, x, x, positions, positions)
    if per_slot:
        # scatter one (K,Dh) row per sequence at that sequence's position
        rows = jnp.arange(b)
        k_cache = cache["k"].at[rows, pos].set(k_new[:, 0].astype(cache["k"].dtype))
        v_cache = cache["v"].at[rows, pos].set(v_new[:, 0].astype(cache["v"].dtype))
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1)
    t = k_cache.shape[1]
    kj = jnp.arange(t)
    valid = kj[None, :] <= positions if per_slot else (kj <= pos)[None, :]
    if cfg.window is not None:
        wfloor = positions - cfg.window if per_slot else pos - cfg.window
        valid &= kj[None, :] > wfloor
    mask = valid[:, None, None, None, :]  # (B or 1, 1,1,1,T)
    out = grouped_attention(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype), mask, cfg.scale)
    out = out.reshape(b, 1, cfg.n_heads * cfg.head_dim)
    y = jnp.einsum("bsh,hd->bsd", out, params["wo"])
    return y, {"k": k_cache, "v": v_cache}
