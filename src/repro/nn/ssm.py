"""Mamba2 (state-space duality) block, TPU-native chunked formulation.

The GPU reference implementations use warp-level scans; on TPU we use the
*chunked parallel form*: the sequence is split into chunks of ``chunk``
steps, intra-chunk interactions become MXU matmuls, and the inter-chunk
state recurrence is a short `lax.scan` over ``L/chunk`` carries.  The same
decomposition is implemented as a Pallas kernel in
``repro/kernels/ssm_scan.py`` with this module's ``ssd_chunked`` (via
``repro/kernels/ref.py``) as its oracle.

Layout conventions:
  x     (B, L, H, P)   inner activations, H heads of dim P
  dt    (B, L, H)      softplus-discretized step sizes
  A     (H,)           negative per-head decay rates
  B_, C_ (B, L, G, N)  input/output projections, G groups, state size N
State: (B, H, N, P).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn import initializers as init
from repro.nn.types import P as Param


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64
    d_head: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 128
    impl: str = "xla"  # "xla" | "pallas"

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.d_head


def mamba2_init(cfg: Mamba2Config, key, dtype=jnp.float32):
    d_in = cfg.d_inner
    conv_dim = d_in + 2 * cfg.n_groups * cfg.d_state
    proj_out = 2 * d_in + 2 * cfg.n_groups * cfg.d_state + cfg.n_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": Param(init.scaled_normal(k1, (cfg.d_model, proj_out), dtype), ("embed", "mlp")),
        "conv_w": Param(init.scaled_normal(k2, (cfg.conv_width, conv_dim), dtype, fan_in=cfg.conv_width), (None, "mlp")),
        "conv_b": Param(jnp.zeros((conv_dim,), dtype), ("mlp",)),
        "A_log": Param(jnp.log(jnp.linspace(1.0, 16.0, cfg.n_heads)).astype(jnp.float32), (None,)),
        "D": Param(jnp.ones((cfg.n_heads,), jnp.float32), (None,)),
        "dt_bias": Param(jnp.zeros((cfg.n_heads,), jnp.float32), (None,)),
        "norm_scale": Param(jnp.ones((d_in,), dtype), ("mlp",)),
        "out_proj": Param(init.scaled_normal(k3, (d_in, cfg.d_model), dtype, fan_in=d_in), ("mlp", "embed")),
    }


def causal_conv1d(x, w, b, state=None):
    """Depthwise causal conv.  x: (B,L,C), w: (W,C).

    When ``state`` (B, W-1, C) is given, performs one-step decode and also
    returns the updated state.
    """
    width = w.shape[0]
    if state is not None:
        window = jnp.concatenate([state, x], axis=1)  # (B, W, C)
        y = jnp.einsum("bwc,wc->bc", window, w) + b
        return y[:, None, :], window[:, 1:, :]
    pad = jnp.zeros(x.shape[:1] + (width - 1,) + x.shape[2:], x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    # (B, L, W, C) windows via stacked slices (W is tiny: 4).
    windows = jnp.stack(
        [xp[:, i : i + x.shape[1]] for i in range(width)], axis=2
    )
    return jnp.einsum("blwc,wc->blc", windows, w) + b


def _segsum_cumsum(a):
    """Inclusive cumsum over the chunk axis (axis=-2 of (..., Q, H))."""
    return jnp.cumsum(a, axis=-2)


def ssd_chunked(x, dt, A, B_, C_, chunk):
    """Chunked SSD scan.  Shapes per module docstring; returns (y, final_state).

    y: (B, L, H, P);  final_state: (B, H, N, P).
    """
    b, l, h, p = x.shape
    g, n = B_.shape[2], B_.shape[3]
    assert l % chunk == 0, f"seq {l} must divide chunk {chunk}"
    nc, q = l // chunk, chunk
    rep = h // g

    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h).astype(jnp.float32)
    Bc = jnp.repeat(B_.reshape(b, nc, q, g, n), rep, axis=3)  # (b,nc,q,h,n)
    Cc = jnp.repeat(C_.reshape(b, nc, q, g, n), rep, axis=3)

    a = dtc * A[None, None, None, :]  # (b,nc,q,h) log-decay, negative
    cs = _segsum_cumsum(a)  # inclusive cumsum within chunk
    total = cs[:, :, -1]  # (b,nc,h)

    # Intra-chunk: att[i,j] = (C_i . B_j) exp(cs_i - cs_j) dt_j for j <= i.
    cb = jnp.einsum("bcqhn,bckhn->bchqk", Cc, Bc).astype(jnp.float32)
    cs_i = cs.transpose(0, 1, 3, 2)[:, :, :, :, None]  # (b,nc,h,q_i,1)
    cs_j = cs.transpose(0, 1, 3, 2)[:, :, :, None, :]  # (b,nc,h,1,q_j)
    tri = jnp.tril(jnp.ones((q, q), bool))[None, None, None]
    # Mask in log-space BEFORE exp so j>i never overflows.
    decay = jnp.exp(jnp.where(tri, cs_i - cs_j, -jnp.inf))  # (b,nc,h,q_i,q_j)
    att = cb * decay * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", att.astype(x.dtype), xc)

    # Chunk states: S_c = sum_j exp(total - cs_j) dt_j B_j x_j  -> (b,nc,h,n,p)
    w_state = jnp.exp(total[:, :, None, :] - cs) * dtc  # (b,nc,q,h)
    s_chunk = jnp.einsum("bcqhn,bcqh,bcqhp->bchnp", Bc.astype(jnp.float32), w_state, xc.astype(jnp.float32))

    # Inter-chunk recurrence over nc.
    def step(carry, inp):
        s_prev = carry
        tot_c, s_c = inp
        s_next = jnp.exp(tot_c)[:, :, None, None] * s_prev + s_c
        return s_next, s_prev

    init_s = jnp.zeros((b, h, n, p), jnp.float32)
    final_state, s_carry = jax.lax.scan(
        step,
        init_s,
        (total.transpose(1, 0, 2), s_chunk.transpose(1, 0, 2, 3, 4)),
    )
    s_carry = s_carry.transpose(1, 0, 2, 3, 4)  # (b,nc,h,n,p): state entering chunk c

    y_inter = jnp.einsum("bcqhn,bchnp->bcqhp", (Cc.astype(jnp.float32) * jnp.exp(cs)[..., None]), s_carry)
    y = (y_intra.astype(jnp.float32) + y_inter).reshape(b, l, h, p)
    return y.astype(x.dtype), final_state


def ssd_recurrent_step(state, x_t, dt_t, A, B_t, C_t):
    """One decode step.  state: (B,H,N,P); x_t: (B,H,P); dt_t: (B,H);
    B_t/C_t: (B,G,N).  Returns (y_t, new_state)."""
    h, g = x_t.shape[1], B_t.shape[1]
    rep = h // g
    Bh = jnp.repeat(B_t, rep, axis=1).astype(jnp.float32)  # (B,H,N)
    Ch = jnp.repeat(C_t, rep, axis=1).astype(jnp.float32)
    dtf = dt_t.astype(jnp.float32)
    da = jnp.exp(dtf * A[None, :])  # (B,H)
    upd = jnp.einsum("bhn,bh,bhp->bhnp", Bh, dtf, x_t.astype(jnp.float32))
    new_state = da[:, :, None, None] * state + upd
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new_state)
    return y.astype(x_t.dtype), new_state


def _split_proj(cfg: Mamba2Config, zxbcdt):
    d_in, gn = cfg.d_inner, cfg.n_groups * cfg.d_state
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : 2 * d_in + 2 * gn]
    dt_raw = zxbcdt[..., 2 * d_in + 2 * gn :]
    return z, xbc, dt_raw


def _gated_norm(y, z, scale, eps=1e-6):
    yf = (y * jax.nn.silu(z.astype(jnp.float32))).astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * (var + eps) ** -0.5 * scale.astype(jnp.float32)).astype(y.dtype)


def mamba2_apply(params, cfg: Mamba2Config, x):
    """Full-sequence forward.  x: (B, L, d_model) -> (B, L, d_model)."""
    b, l, _ = x.shape
    d_in, gn = cfg.d_inner, cfg.n_groups * cfg.d_state
    zxbcdt = jnp.einsum("bld,dk->blk", x, params["in_proj"])
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    xbc = jax.nn.silu(causal_conv1d(xbc, params["conv_w"], params["conv_b"]))
    xs = xbc[..., :d_in].reshape(b, l, cfg.n_heads, cfg.d_head)
    B_ = xbc[..., d_in : d_in + gn].reshape(b, l, cfg.n_groups, cfg.d_state)
    C_ = xbc[..., d_in + gn :].reshape(b, l, cfg.n_groups, cfg.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    chunk = min(cfg.chunk, l)
    while l % chunk:
        chunk -= 1
    if cfg.impl == "pallas":
        from repro.kernels import ops as kops

        y, _ = kops.ssm_scan(xs, dt, A, B_, C_, chunk=chunk)
    else:
        y, _ = ssd_chunked(xs, dt, A, B_, C_, chunk)
    y = (y + xs * params["D"][None, None, :, None]).astype(x.dtype)
    y = y.reshape(b, l, d_in)
    y = _gated_norm(y, z, params["norm_scale"])
    return jnp.einsum("bld,dk->blk", y, params["out_proj"])


def init_ssm_cache(cfg: Mamba2Config, batch, dtype=jnp.float32):
    conv_dim = cfg.d_inner + 2 * cfg.n_groups * cfg.d_state
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, cfg.n_heads, cfg.d_state, cfg.d_head), jnp.float32),
    }


def mamba2_decode(params, cfg: Mamba2Config, x, cache):
    """One-token decode.  x: (B, 1, d_model)."""
    b = x.shape[0]
    d_in, gn = cfg.d_inner, cfg.n_groups * cfg.d_state
    zxbcdt = jnp.einsum("bld,dk->blk", x, params["in_proj"])
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    xbc_t, conv_state = causal_conv1d(
        xbc, params["conv_w"], params["conv_b"], state=cache["conv"].astype(xbc.dtype)
    )
    xbc_t = jax.nn.silu(xbc_t)[:, 0]  # (B, conv_dim)
    x_t = xbc_t[..., :d_in].reshape(b, cfg.n_heads, cfg.d_head)
    B_t = xbc_t[..., d_in : d_in + gn].reshape(b, cfg.n_groups, cfg.d_state)
    C_t = xbc_t[..., d_in + gn :].reshape(b, cfg.n_groups, cfg.d_state)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y_t, state = ssd_recurrent_step(
        cache["state"], x_t, dt, A, B_t, C_t
    )
    y_t = (y_t + x_t * params["D"][None, :, None]).astype(x.dtype)
    y = y_t.reshape(b, 1, d_in)
    y = _gated_norm(y, z, params["norm_scale"])
    out = jnp.einsum("bld,dk->blk", y, params["out_proj"])
    return out, {"conv": conv_state.astype(cache["conv"].dtype), "state": state}
