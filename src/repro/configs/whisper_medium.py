"""whisper-medium [audio]: enc-dec, 24L each, d_model=1024 16H (kv=16)
d_ff=4096 vocab=51865 — conv frontend STUBBED per the brief: input_specs
provides precomputed frame embeddings.  [arXiv:2212.04356; unverified]

Decode cells: decoder self-KV = cell seq; cross-attention against a fixed
1,500-frame encoder context.  Full attention -> long_500k is skipped.
"""
from repro.configs.base import ArchConfig
from repro.models.specs import LayerSpec, ModelSpec, SubBlock
from repro.nn.attention import AttentionConfig
from repro.nn.mlp import MLPConfig


def _layers(d, h, ff, max_pos):
    enc = LayerSpec(subs=(
        SubBlock("attention", AttentionConfig(d, h, h, causal=False, rope=False, use_bias=True)),
        SubBlock("mlp", MLPConfig(d, ff, activation="gelu", gated=False, use_bias=True)),
    ))
    dec = LayerSpec(subs=(
        SubBlock("attention", AttentionConfig(d, h, h, causal=True, rope=False, use_bias=True)),
        SubBlock("cross_attention", AttentionConfig(d, h, h, causal=False, rope=False, use_bias=True)),
        SubBlock("mlp", MLPConfig(d, ff, activation="gelu", gated=False, use_bias=True)),
    ))
    return enc, dec


def spec_fn(long_context: bool = False) -> ModelSpec:
    enc, dec = _layers(1024, 16, 4096, 65536)
    return ModelSpec(
        name="whisper-medium", d_model=1024, vocab=51865,
        layers=(dec,) * 24, encoder_layers=(enc,) * 24,
        norm="layernorm", positional="learned", max_position=65536,
        frontend="audio_stub", tie_embeddings=True,
    )


def smoke_spec_fn() -> ModelSpec:
    enc, dec = _layers(64, 4, 128, 128)
    return ModelSpec(
        name="whisper-smoke", d_model=64, vocab=512,
        layers=(dec,) * 2, encoder_layers=(enc,) * 2,
        norm="layernorm", positional="learned", max_position=128,
        frontend="audio_stub",
    )


ARCH = ArchConfig(
    name="whisper-medium", family="audio",
    spec_fn=spec_fn, smoke_spec_fn=smoke_spec_fn,
    batch_kind="encdec", enc_context=1500,
    source="arXiv:2212.04356 (unverified)",
)
