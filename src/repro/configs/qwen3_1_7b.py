"""qwen3-1.7b [dense]: 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936 — qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ArchConfig
from repro.models.specs import ModelSpec, transformer_layer


def spec_fn(long_context: bool = False) -> ModelSpec:
    layer = transformer_layer(
        2048, 16, 8, 6144,
        activation="silu", gated=True, qk_norm=True, d_head=128,
        rope_theta=1_000_000.0,
    )
    return ModelSpec(
        name="qwen3-1.7b", d_model=2048, vocab=151936,
        layers=(layer,) * 28, norm="rmsnorm", tie_embeddings=True,
    )


def smoke_spec_fn() -> ModelSpec:
    layer = transformer_layer(64, 4, 2, 192, activation="silu", gated=True,
                              qk_norm=True, d_head=16)
    return ModelSpec(name="qwen3-smoke", d_model=64, vocab=512,
                     layers=(layer,) * 2, tie_embeddings=True)


ARCH = ArchConfig(
    name="qwen3-1.7b", family="dense",
    spec_fn=spec_fn, smoke_spec_fn=smoke_spec_fn,
    source="hf:Qwen/Qwen3-8B",
)
