"""qwen1.5-4b [dense]: 40L d_model=2560 20H (GQA kv=20 = MHA) d_ff=6912
vocab=151936 — QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs.base import ArchConfig
from repro.models.specs import ModelSpec, transformer_layer


def spec_fn(long_context: bool = False) -> ModelSpec:
    layer = transformer_layer(
        2560, 20, 20, 6912, activation="silu", gated=True,
        attn_bias=True, d_head=128,
    )
    return ModelSpec(
        name="qwen1.5-4b", d_model=2560, vocab=151936,
        layers=(layer,) * 40, norm="rmsnorm",
    )


def smoke_spec_fn() -> ModelSpec:
    layer = transformer_layer(64, 4, 4, 192, activation="silu", gated=True,
                              attn_bias=True, d_head=16)
    return ModelSpec(name="qwen1.5-smoke", d_model=64, vocab=512, layers=(layer,) * 2)


ARCH = ArchConfig(
    name="qwen1.5-4b", family="dense",
    spec_fn=spec_fn, smoke_spec_fn=smoke_spec_fn,
    source="hf:Qwen/Qwen1.5-0.5B",
)
