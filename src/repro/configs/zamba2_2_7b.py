"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240,
ssm_state=64 — Mamba2 backbone + weight-shared attention block applied
every 6 layers.  [arXiv:2411.15242; hf]

Long-context note (DESIGN.md §shape-cell skips): at long_500k the shared
attention block runs with a 4096-token sliding window; the Mamba2 state is
the O(1) context carrier.  The published model applies LoRA adapters per
shared-block invocation — omitted here (weight-tied exactly), documented
as a simplification.
"""
from repro.configs.base import ArchConfig
from repro.models.specs import LayerSpec, ModelSpec, SubBlock, transformer_layer
from repro.nn.ssm import Mamba2Config

SHARED_PERIOD = 6


def _layers(d_model, n_heads, d_ff, d_state, d_head_ssm, n_mamba, period, window, smoke=False):
    mamba = LayerSpec(
        subs=(SubBlock("mamba2", Mamba2Config(
            d_model, d_state=d_state, d_head=d_head_ssm, expand=2,
            n_groups=1, chunk=8 if smoke else 128)),),
    )
    shared = LayerSpec(
        subs=transformer_layer(
            d_model, n_heads, n_heads, d_ff, activation="gelu", gated=True,
            window=window, d_head=d_model // n_heads,
        ).subs,
        shared=True,
    )
    layers = []
    for i in range(n_mamba):
        layers.append(mamba)
        if (i + 1) % period == 0:
            layers.append(shared)
    return tuple(layers)


def spec_fn(long_context: bool = False) -> ModelSpec:
    return ModelSpec(
        name="zamba2-2.7b", d_model=2560, vocab=32000,
        layers=_layers(2560, 32, 10240, 64, 64, 54, SHARED_PERIOD,
                       window=4096 if long_context else None),
        norm="rmsnorm", positional="none",
    )


def smoke_spec_fn() -> ModelSpec:
    return ModelSpec(
        name="zamba2-smoke", d_model=64, vocab=512,
        layers=_layers(64, 4, 128, 16, 16, 4, 2, window=None, smoke=True),
        norm="rmsnorm", positional="none",
    )


ARCH = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    spec_fn=spec_fn, smoke_spec_fn=smoke_spec_fn,
    supports_long_context=True,
    source="arXiv:2411.15242",
)
