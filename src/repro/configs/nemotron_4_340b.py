"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000 — GQA, squared-ReLU (non-gated).  [arXiv:2402.16819]"""
from repro.configs.base import ArchConfig
from repro.models.specs import ModelSpec, transformer_layer


def spec_fn(long_context: bool = False) -> ModelSpec:
    layer = transformer_layer(
        18432, 96, 8, 73728,
        activation="squared_relu", gated=False, d_head=192,
    )
    return ModelSpec(
        name="nemotron-4-340b", d_model=18432, vocab=256000,
        layers=(layer,) * 96, norm="layernorm",
    )


def smoke_spec_fn() -> ModelSpec:
    layer = transformer_layer(96, 6, 2, 384, activation="squared_relu",
                              gated=False, d_head=16)
    return ModelSpec(name="nemotron-smoke", d_model=96, vocab=512,
                     layers=(layer,) * 2, norm="layernorm")


ARCH = ArchConfig(
    name="nemotron-4-340b", family="dense",
    spec_fn=spec_fn, smoke_spec_fn=smoke_spec_fn,
    source="arXiv:2402.16819 (unverified)",
)
