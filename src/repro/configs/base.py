"""Architecture config protocol + the 4 assigned input-shape cells.

Each ``configs/<arch>.py`` exposes ``ARCH: ArchConfig`` with:
  * ``spec_fn(long_context)``  — the exact published configuration
  * ``smoke_spec_fn()``        — reduced same-family config for CPU tests
  * ``batch_kind``             — "lm" | "encdec" | "vlm" (input dict layout)
  * ``supports_long_context``  — whether the ``long_500k`` decode cell runs
    (sub-quadratic archs only; skips are documented in DESIGN.md)

``input_specs`` builds weak-type-correct ShapeDtypeStruct stand-ins for
every model input of a (arch x shape) cell — no device allocation, as
required by the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.specs import ModelSpec


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq: int
    batch: int
    long_context: bool = False


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1, long_context=True),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | hybrid | ssm | audio | moe | vlm
    spec_fn: Callable[..., ModelSpec]
    smoke_spec_fn: Callable[[], ModelSpec]
    batch_kind: str = "lm"
    supports_long_context: bool = False
    enc_context: int = 1500  # enc-dec: encoder frames available at decode
    prefix_tokens: int = 256  # vlm: patch-embedding prefix length
    source: str = ""

    def spec(self, long_context: bool = False) -> ModelSpec:
        try:
            return self.spec_fn(long_context=long_context)
        except TypeError:
            return self.spec_fn()

    def cell_supported(self, cell: ShapeCell) -> Tuple[bool, str]:
        if cell.long_context and not self.supports_long_context:
            return False, (
                "long_500k requires sub-quadratic sequence mixing; "
                f"{self.name} is a full-attention arch (skip per brief)"
            )
        return True, ""


def _tok(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(arch: ArchConfig, cell: ShapeCell, spec: Optional[ModelSpec] = None):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Returns (batch_dict, batch_logical_axes) — axes feed the sharding
    resolver for in_shardings.
    """
    spec = spec or arch.spec(long_context=cell.long_context)
    b, s = cell.batch, cell.seq
    d = spec.d_model
    act = jnp.bfloat16

    if cell.kind in ("train",):
        batch = {"tokens": _tok(b, s), "labels": _tok(b, s)}
        axes = {"tokens": ("batch", None), "labels": ("batch", None)}
        if arch.batch_kind == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct((b, s, d), act)
            axes["frames"] = ("batch", None, None)
        if arch.batch_kind == "vlm":
            batch["patch_embeds"] = jax.ShapeDtypeStruct((b, arch.prefix_tokens, d), act)
            axes["patch_embeds"] = ("batch", None, None)
        return batch, axes

    if cell.kind == "prefill":
        batch = {"tokens": _tok(b, s)}
        axes = {"tokens": ("batch", None)}
        if arch.batch_kind == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct((b, s, d), act)
            axes["frames"] = ("batch", None, None)
        if arch.batch_kind == "vlm":
            batch["patch_embeds"] = jax.ShapeDtypeStruct((b, arch.prefix_tokens, d), act)
            axes["patch_embeds"] = ("batch", None, None)
        return batch, axes

    if cell.kind == "decode":
        # one new token against a cache of cell.seq
        batch = {"tokens": _tok(b, 1)}
        axes = {"tokens": ("batch", None)}
        return batch, axes

    raise ValueError(cell.kind)
