"""paligemma-3b [vlm]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=257216 — SigLIP vision tower STUBBED per the brief (input_specs
provides precomputed patch embeddings, 256 prefix tokens) + gemma decoder.
[arXiv:2407.07726; hf]"""
from repro.configs.base import ArchConfig
from repro.models.specs import ModelSpec, transformer_layer


def spec_fn(long_context: bool = False) -> ModelSpec:
    layer = transformer_layer(
        2048, 8, 1, 16384, activation="gelu", gated=True, d_head=256,
    )
    return ModelSpec(
        name="paligemma-3b", d_model=2048, vocab=257216,
        layers=(layer,) * 18, norm="rmsnorm",
        tie_embeddings=True, embed_scale=True,
        frontend="vision_stub", num_prefix_tokens=256,
    )


def smoke_spec_fn() -> ModelSpec:
    layer = transformer_layer(64, 4, 1, 256, activation="gelu", gated=True, d_head=16)
    return ModelSpec(
        name="paligemma-smoke", d_model=64, vocab=512, layers=(layer,) * 2,
        tie_embeddings=True, embed_scale=True,
        frontend="vision_stub", num_prefix_tokens=8,
    )


ARCH = ArchConfig(
    name="paligemma-3b", family="vlm",
    spec_fn=spec_fn, smoke_spec_fn=smoke_spec_fn,
    batch_kind="vlm", prefix_tokens=256,
    source="arXiv:2407.07726",
)
