"""xlstm-1.3b [ssm]: 48L d_model=2048 4H — mLSTM blocks with periodic
sLSTM blocks (7:1 ratio), d_ff=0 (blocks contain their own projections).
[arXiv:2405.04517; unverified]"""
from repro.configs.base import ArchConfig
from repro.models.specs import LayerSpec, ModelSpec, SubBlock
from repro.nn.xlstm import MLSTMConfig, SLSTMConfig


def _layers(d_model, n_heads, n_layers, slstm_every, chunk):
    m = LayerSpec(subs=(SubBlock("mlstm", MLSTMConfig(d_model, n_heads=n_heads, expand=2, chunk=chunk)),))
    s = LayerSpec(subs=(SubBlock("slstm", SLSTMConfig(d_model, n_heads=n_heads)),))
    return tuple(
        s if (i + 1) % slstm_every == 0 else m for i in range(n_layers)
    )


def spec_fn(long_context: bool = False) -> ModelSpec:
    return ModelSpec(
        name="xlstm-1.3b", d_model=2048, vocab=50304,
        layers=_layers(2048, 4, 48, 8, 128),
        norm="layernorm", positional="none",
    )


def smoke_spec_fn() -> ModelSpec:
    return ModelSpec(
        name="xlstm-smoke", d_model=64, vocab=512,
        layers=_layers(64, 2, 4, 4, 8),
        norm="layernorm", positional="none",
    )


ARCH = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    spec_fn=spec_fn, smoke_spec_fn=smoke_spec_fn,
    supports_long_context=True,
    source="arXiv:2405.04517 (unverified)",
)
