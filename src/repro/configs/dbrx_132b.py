"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752,
MoE 16 experts top-4 (fine-grained).  [hf:databricks/dbrx-base; unverified]"""
from repro.configs.base import ArchConfig
from repro.models.specs import ModelSpec, moe_layer


def spec_fn(long_context: bool = False) -> ModelSpec:
    layer = moe_layer(
        6144, 48, 8, 10752, n_experts=16, top_k=4,
        activation="silu", capacity_factor=1.25,
    )
    return ModelSpec(
        name="dbrx-132b", d_model=6144, vocab=100352,
        layers=(layer,) * 40, norm="rmsnorm",
    )


def smoke_spec_fn() -> ModelSpec:
    layer = moe_layer(64, 4, 2, 96, n_experts=4, top_k=2, capacity_factor=2.0)
    return ModelSpec(name="dbrx-smoke", d_model=64, vocab=512, layers=(layer,) * 2)


ARCH = ArchConfig(
    name="dbrx-132b", family="moe",
    spec_fn=spec_fn, smoke_spec_fn=smoke_spec_fn,
    source="hf:databricks/dbrx-base (unverified)",
)
