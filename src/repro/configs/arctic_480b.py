"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864,
MoE 128 experts top-2 + dense residual MLP branch.
[hf:Snowflake/snowflake-arctic-base; hf]

The published Arctic uses a larger dense-branch d_ff; the assignment
fixes d_ff=4864, used here for both the experts and the dense residual
(noted deviation)."""
from repro.configs.base import ArchConfig
from repro.models.specs import ModelSpec, moe_layer


def spec_fn(long_context: bool = False) -> ModelSpec:
    layer = moe_layer(
        7168, 56, 8, 4864, n_experts=128, top_k=2,
        activation="silu", dense_residual=True, capacity_factor=1.25,
    )
    return ModelSpec(
        name="arctic-480b", d_model=7168, vocab=32000,
        layers=(layer,) * 35, norm="rmsnorm",
    )


def smoke_spec_fn() -> ModelSpec:
    layer = moe_layer(64, 4, 2, 96, n_experts=8, top_k=2,
                      dense_residual=True, capacity_factor=2.0)
    return ModelSpec(name="arctic-smoke", d_model=64, vocab=512, layers=(layer,) * 2)


ARCH = ArchConfig(
    name="arctic-480b", family="moe",
    spec_fn=spec_fn, smoke_spec_fn=smoke_spec_fn,
    source="hf:Snowflake/snowflake-arctic-base",
)
