"""Architecture config registry: one module per assigned architecture."""
from repro.configs.base import SHAPES, ArchConfig, ShapeCell, input_specs

_ARCH_MODULES = (
    "qwen3_1_7b",
    "phi4_mini_3_8b",
    "nemotron_4_340b",
    "qwen1_5_4b",
    "zamba2_2_7b",
    "xlstm_1_3b",
    "whisper_medium",
    "dbrx_132b",
    "arctic_480b",
    "paligemma_3b",
)

ARCHS = {}
for _m in _ARCH_MODULES:
    mod = __import__(f"repro.configs.{_m}", fromlist=["ARCH"])
    ARCHS[mod.ARCH.name] = mod.ARCH


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ARCHS", "ArchConfig", "SHAPES", "ShapeCell", "get_arch", "input_specs"]
