"""phi4-mini-3.8b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064 — RoPE SwiGLU GQA.  [arXiv:2412.08905; hf]"""
from repro.configs.base import ArchConfig
from repro.models.specs import ModelSpec, transformer_layer


def spec_fn(long_context: bool = False) -> ModelSpec:
    layer = transformer_layer(
        3072, 24, 8, 8192, activation="silu", gated=True, d_head=128,
    )
    return ModelSpec(
        name="phi4-mini-3.8b", d_model=3072, vocab=200064,
        layers=(layer,) * 32, norm="rmsnorm", tie_embeddings=True,
    )


def smoke_spec_fn() -> ModelSpec:
    layer = transformer_layer(96, 6, 2, 256, activation="silu", gated=True, d_head=16)
    return ModelSpec(name="phi4-smoke", d_model=96, vocab=512, layers=(layer,) * 2)


ARCH = ArchConfig(
    name="phi4-mini-3.8b", family="dense",
    spec_fn=spec_fn, smoke_spec_fn=smoke_spec_fn,
    source="arXiv:2412.08905",
)
