from repro.hwgen.generator import Artifact, HardwareManager, XLAGenerator
from repro.hwgen.hlo_analysis import parse_collectives, total_collective_bytes
from repro.hwgen.roofline import RooflineReport, roofline_from_record, roofline_terms
from repro.hwgen.targets import HOST_CPU, TARGETS, TPU_V5E, ChipSpec, TargetSpec, get_target
