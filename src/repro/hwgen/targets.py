"""Hardware target specifications (the TPU analogue of the paper's
Raspberry Pi / Pico / FPGA backend descriptors).

A TargetSpec bundles chip constants (for the roofline cost model) with a
mesh recipe and backend capabilities (for the reflection API, paper §VI).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float  # FLOP/s
    hbm_bandwidth: float  # B/s
    ici_bandwidth: float  # B/s per link
    hbm_bytes: int
    vmem_bytes: int = 128 * 1024 * 1024


TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    ici_bandwidth=50e9,
    hbm_bytes=16 * 1024 ** 3,
)

HOST_CPU = ChipSpec(
    name="host_cpu",
    peak_flops_bf16=1e11,  # nominal; host backend measures wall-clock instead
    hbm_bandwidth=20e9,
    ici_bandwidth=1e9,
    hbm_bytes=32 * 1024 ** 3,
)

# Edge-class accelerator (the paper's Raspberry-Pi/Pico deployment tier):
# a single-chip NPU with modest compute but *proportionally* even less
# memory bandwidth than the datacenter parts — its roofline crosses over
# at a much higher arithmetic intensity, so architectures that win on
# tpu_v5e (compute-bound) can lose here (bandwidth-bound).  That
# asymmetry is what makes cross-target sweep comparisons informative.
EDGE_NPU = ChipSpec(
    name="edge_npu",
    peak_flops_bf16=4e12,
    hbm_bandwidth=34e9,
    ici_bandwidth=0.25e9,
    hbm_bytes=8 * 1024 ** 3,
    vmem_bytes=8 * 1024 * 1024,
)


@dataclasses.dataclass(frozen=True)
class TargetSpec:
    name: str
    chip: ChipSpec
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    # reflection API (paper §VI): capability set consulted by the
    # ModelBuilder so only backend-supported ops are sampled
    supported_ops: frozenset = frozenset()
    supports_pallas: bool = False
    measurement: str = "roofline"  # "roofline" | "wallclock"

    @property
    def n_chips(self) -> int:
        n = 1
        for s in self.mesh_shape:
            n *= s
        return n

    @property
    def mesh_scope(self) -> str:
        """Identity of the *compiled program* this target produces.

        Two targets sharing a mesh topology compile byte-identical
        executables — chip constants only enter the roofline arithmetic
        afterwards — so compile-derived cache entries are scoped by this
        string instead of the target name, letting cross-target sweeps
        reuse each other's compiles (see ``_CompiledEstimator``).
        """
        return ("mesh:" + "x".join(str(s) for s in self.mesh_shape)
                + ":" + ",".join(self.mesh_axes))

    def to_dict(self) -> Dict[str, Any]:
        """JSON form with the full chip constants, persisted into
        ``ExplorationReport``/``SweepReport`` so a report stays
        interpretable even after a target's registered constants are
        edited (the numbers that produced it travel with it)."""
        return {
            "name": self.name,
            "chip": dataclasses.asdict(self.chip),
            "mesh_shape": list(self.mesh_shape),
            "mesh_axes": list(self.mesh_axes),
            "n_chips": self.n_chips,
            "supported_ops": sorted(self.supported_ops),
            "supports_pallas": self.supports_pallas,
            "measurement": self.measurement,
        }


_COMMON_OPS = frozenset({
    "linear", "conv1d", "maxpool", "avgpool", "identity", "global_avg_pool",
    "layernorm", "attention", "ssm",
})

TARGETS: Dict[str, TargetSpec] = {
    # single-chip tpu_v5e: the datacenter chip constants on a mesh any
    # host can compile for (the pod targets need 256+ spoofed devices) —
    # what cross-target sweeps compare against host_cpu/edge_npu
    "tpu_v5e": TargetSpec(
        name="tpu_v5e", chip=TPU_V5E,
        mesh_shape=(1, 1), mesh_axes=("data", "model"),
        supported_ops=_COMMON_OPS, supports_pallas=True,
        measurement="roofline",
    ),
    "tpu_v5e_pod": TargetSpec(
        name="tpu_v5e_pod", chip=TPU_V5E,
        mesh_shape=(16, 16), mesh_axes=("data", "model"),
        supported_ops=_COMMON_OPS, supports_pallas=True,
        measurement="roofline",
    ),
    "tpu_v5e_2pod": TargetSpec(
        name="tpu_v5e_2pod", chip=TPU_V5E,
        mesh_shape=(2, 16, 16), mesh_axes=("pod", "data", "model"),
        supported_ops=_COMMON_OPS, supports_pallas=True,
        measurement="roofline",
    ),
    "host_cpu": TargetSpec(
        name="host_cpu", chip=HOST_CPU,
        mesh_shape=(1, 1), mesh_axes=("data", "model"),
        supported_ops=_COMMON_OPS, supports_pallas=False,
        measurement="wallclock",
    ),
    # single-chip edge deployment tier: same mesh topology as host_cpu
    # (so sweeps reuse its compiles) but roofline-measured against the
    # EDGE_NPU constants — latency/memory trade-offs rank differently
    # than on either datacenter target
    "edge_npu": TargetSpec(
        name="edge_npu", chip=EDGE_NPU,
        mesh_shape=(1, 1), mesh_axes=("data", "model"),
        supported_ops=_COMMON_OPS, supports_pallas=False,
        measurement="roofline",
    ),
}


def get_target(name: str) -> TargetSpec:
    if name not in TARGETS:
        raise KeyError(f"unknown target {name!r}; available: {sorted(TARGETS)}")
    return TARGETS[name]


# Publish the built-in targets to the Explorer facade's registry so YAML
# experiments can name them; plugin targets register the same way
# (``register("target", "my_board", spec)``) without touching this dict.
from repro.explorer.registry import TARGETS as _EXPLORER_TARGETS  # noqa: E402

for _name, _spec in TARGETS.items():
    _EXPLORER_TARGETS.register(_name, _spec)
del _name, _spec
