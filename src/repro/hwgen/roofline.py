"""Three-term roofline model over compiled dry-run artifacts.

    compute    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory     = HLO_bytes / (chips x HBM_bw)
    collective = collective_bytes / (chips x link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()`` of the unrolled cost
variant; collective bytes from the HLO parser (trip-count aware).  The
analysis classifies the dominant term and reports
``MODEL_FLOPS = 6*N*D`` (dense; N_active for MoE) against HLO FLOPs to
expose remat/dispatch overheads.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.hwgen.targets import ChipSpec, TPU_V5E


@dataclasses.dataclass
class RooflineReport:
    cell: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    bound_s: float  # max of the three = modelled step latency
    model_flops: Optional[float] = None
    hlo_flops: Optional[float] = None
    useful_ratio: Optional[float] = None  # MODEL_FLOPS / HLO_FLOPs
    roofline_fraction: Optional[float] = None  # compute_s / bound_s

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def roofline_terms(
    *,
    hlo_flops: float,
    hlo_bytes: float,
    collective_bytes: float,
    n_chips: int,
    chip: ChipSpec = TPU_V5E,
    cell: str = "",
    model_flops: Optional[float] = None,
) -> RooflineReport:
    """All inputs are GLOBAL (whole-program) quantities; terms are
    per-chip times assuming perfect spatial balance."""
    compute_s = hlo_flops / (n_chips * chip.peak_flops_bf16)
    memory_s = hlo_bytes / (n_chips * chip.hbm_bandwidth)
    collective_s = collective_bytes / (n_chips * chip.ici_bandwidth)
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound = terms[dominant]
    useful = model_flops / hlo_flops if (model_flops and hlo_flops) else None
    return RooflineReport(
        cell=cell,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        bound_s=bound,
        model_flops=model_flops,
        hlo_flops=hlo_flops,
        useful_ratio=useful,
        roofline_fraction=(compute_s / bound) if bound > 0 else None,
    )


def roofline_from_record(record: Dict, chip: ChipSpec = TPU_V5E,
                         model_flops: Optional[float] = None) -> RooflineReport:
    """Build a report from a dry-run JSON record.

    NOTE on per-chip accounting: ``cost_analysis`` on the SPMD-partitioned
    executable reports the per-device program, so flops/bytes are already
    per-chip; we therefore pass n_chips=1 against per-chip peaks.
    Collective bytes from the HLO parser are per-device program bytes as
    well (each device executes the same collectives).
    """
    cost = record.get("cost", {})
    return roofline_terms(
        hlo_flops=float(cost.get("flops", 0.0)),
        hlo_bytes=float(cost.get("bytes_accessed", 0.0)),
        collective_bytes=float(record.get("collective_bytes", 0.0)),
        n_chips=1,
        chip=chip,
        cell=record.get("cell", ""),
        model_flops=model_flops,
    )
