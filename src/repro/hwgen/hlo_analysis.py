"""HLO text analysis: collective-communication byte accounting.

``cost_analysis()`` does not expose collective bytes, so we parse the
optimized (post-SPMD-partitioning) HLO and sum the operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
— the inputs to the §Roofline collective term.

Two subtleties:
  * operands are printed as names only -> pass 1 builds a name->bytes map
    from definition sites;
  * ``lax.scan`` lowers to ``while`` whose body is printed once -> we
    recover trip counts from the loop-condition constants and multiply
    each computation's collective bytes by its (possibly nested) trip
    multiplier.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]"
)
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)"
)
_CONST_INT_RE = re.compile(r"\b[su](?:8|16|32|64)\[\]\s+constant\((\d+)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _first_paren_group(s: str) -> str:
    start = s.find("(")
    if start < 0:
        return ""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return s[start + 1 : i]
    return s[start + 1 :]


def _split_computations(text: str) -> Dict[str, List[str]]:
    """computation name -> its body lines."""
    comps: Dict[str, List[str]] = {}
    current = None
    for line in text.splitlines():
        m = _COMP_HDR_RE.match(line)
        if m and "{" in line:
            current = m.group(1)
            comps[current] = []
            continue
        if current is not None:
            if line.strip() == "}":
                current = None
                continue
            comps[current].append(line)
    return comps


def _collective_kind(rhs: str):
    for kind in _COLLECTIVES:
        # match "<kind>(" or "<kind>-start(" as the opcode token
        if re.search(rf"\b{kind}(?:-start)?\(", rhs):
            if f"{kind}-done" in rhs:
                return None
            return kind
    return None


def analyze_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """{kind: {count, bytes}} with while-trip-count multipliers applied."""
    comps = _split_computations(hlo_text)

    # pass 1: name -> output bytes (first shape token on the rhs)
    name_bytes: Dict[str, int] = {}
    for lines in comps.values():
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, rhs = m.groups()
            shapes = _SHAPE_RE.findall(rhs.split("(")[0] + "(")
            if not shapes:
                shapes = _SHAPE_RE.findall(rhs)
                shapes = shapes[:1]
            name_bytes[name] = sum(_shape_bytes(d, dims) for d, dims in shapes)

    # pass 2: while nesting -> per-computation multiplier
    trip_of_comp: Dict[str, int] = {}
    located: List[Tuple[str, str, str]] = []  # (parent_comp, cond, body)
    for cname, lines in comps.items():
        for line in lines:
            w = _WHILE_RE.search(line)
            if w:
                located.append((cname, w.group(1), w.group(2)))

    def cond_trip(cond_name: str, depth: int = 0) -> int:
        ints = []
        for line in comps.get(cond_name, ()):  # constants in the condition
            ints += [int(x) for x in _CONST_INT_RE.findall(line)]
            if depth < 2:  # comparisons may live in called fusions
                for callee in re.findall(r"calls=%?([\w.\-]+)", line):
                    t = cond_trip(callee, depth + 1)
                    if t > 1:
                        ints.append(t)
        return max(ints) if ints else 1

    mult: Dict[str, int] = {c: 1 for c in comps}
    # iterate to fixpoint for nesting (bounded by nesting depth)
    for _ in range(8):
        changed = False
        for parent, cond, body in located:
            m = mult.get(parent, 1) * max(1, cond_trip(cond))
            for target in (body, cond):
                if mult.get(target, 1) != m:
                    mult[target] = m
                    changed = True
        if not changed:
            break

    # pass 3: per-computation collective bytes x multiplier
    out = {k: {"count": 0.0, "bytes": 0.0} for k in _COLLECTIVES}
    for cname, lines in comps.items():
        m = mult.get(cname, 1)
        for line in lines:
            d = _DEF_RE.match(line)
            if not d:
                continue
            rhs = d.group(2)
            kind = _collective_kind(rhs)
            if kind is None:
                continue
            operands = _first_paren_group(rhs[rhs.find(kind):] if kind in rhs else rhs)
            names = re.findall(r"%([\w.\-]+)", operands)
            nbytes = sum(name_bytes.get(n, 0) for n in names)
            if nbytes == 0:
                # operands may be printed with inline shapes in some versions
                nbytes = sum(_shape_bytes(t, dims) for t, dims in _SHAPE_RE.findall(operands))
            out[kind]["count"] += m
            out[kind]["bytes"] += m * nbytes
    return out


# Backwards-compatible name used by the dry-run
def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    return analyze_collectives(hlo_text)


def total_collective_bytes(stats: Dict[str, Dict[str, float]]) -> int:
    return int(sum(v["bytes"] for v in stats.values()))


def count_op(hlo_text: str, opcode: str) -> int:
    return len(re.findall(rf"\b{re.escape(opcode)}\(", hlo_text))
