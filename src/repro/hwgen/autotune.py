"""Per-target kernel schedule autotuning.

The paper's generators emit target-specific code; this is the jax_pallas
analogue for the *kernel mapping*: the same candidate architecture gets
its Pallas block/chunk parameters tuned per target and cached next to
its compiled artifacts.  :class:`ScheduleTuner` sweeps the small
candidate grid in :data:`repro.kernels.schedule.CANDIDATE_SCHEDULES` on
synthetic inputs at the call's real shapes, times each candidate under
the shared compile admission gate, and memoizes the winner in the
(optionally disk-backed) evaluation cache keyed by
``(kernel, shape_bucket, mesh_scope)`` — so a warm restart re-tunes
nothing, and same-topology targets share tuned schedules exactly like
they share compiled artifacts.

Shape buckets round every dimension up to the next power of two and fold
in the masking flags, so nearby shapes (which want the same blocking)
share one sweep instead of each paying their own.

Records are plain JSON dicts on purpose: the flock-safe disk cache
persists JSON-able values only, and the ``schedule`` field holds the
*requested* (validated, power-of-two) winner — re-loadable via
``as_schedule`` — while ``effective`` documents what that request
clamped to at the swept shapes.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.envvars import read_env
from repro.hwgen.generator import compile_gate
from repro.kernels import ops as kops
from repro.kernels import schedule as ksched
from repro.kernels.schedule import KernelSchedule

# the documented default of REPRO_TUNE_BUDGET (covers every built-in grid)
DEFAULT_BUDGET = 8

KernelCalls = Dict[Tuple[str, str], Dict[str, Any]]


def discover_kernel_calls(fn: Callable, example_args: Tuple) -> KernelCalls:
    """Which schedulable kernels does ``fn`` reach, at what shapes?

    Runs ``jax.eval_shape`` under the call recorder — an abstract trace,
    no compile, so discovery costs milliseconds even for programs whose
    compilation takes seconds."""
    sink: KernelCalls = {}
    with ksched.record_kernel_calls(sink):
        jax.eval_shape(fn, *example_args)
    return sink


def _pow2_ceil(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


class ScheduleTuner:
    """Sweeps schedule candidates per (kernel, shape-bucket, target).

    ``budget`` (explicit spec value, else ``REPRO_TUNE_BUDGET``) caps how
    many candidates each sweep times; grids are default-first, so budget
    1 degenerates to the named default.  ``overrides`` pins kernels to a
    fixed schedule — pinned kernels are never swept.  Thread-safe: the
    cache provides single-flight per key, the stats counter has its own
    lock.
    """

    def __init__(self, target, cache=None, budget: Optional[int] = None,
                 overrides: Optional[Mapping[str, Any]] = None,
                 warmup: int = 1, iters: int = 3):
        self.target = target
        self.cache = cache
        self._budget = budget
        self.overrides: Dict[str, KernelSchedule] = {
            kernel: ksched.as_schedule(kernel, value)
            for kernel, value in (overrides or {}).items()
        }
        self.warmup = warmup
        self.iters = iters
        self._lock = threading.Lock()
        self._stats = {"tunes": 0, "cache_hits": 0, "tune_time_s": 0.0}

    @property
    def budget(self) -> int:
        if self._budget is not None:
            return max(1, int(self._budget))
        return read_env("REPRO_TUNE_BUDGET", DEFAULT_BUDGET)

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return dict(self._stats)

    # -- planning -----------------------------------------------------------

    def plan(self, calls: KernelCalls) -> Dict[str, KernelSchedule]:
        """Tuned (or pinned) schedule per kernel in a discovered call
        set; the mapping feeds straight into ``use_schedules`` /
        ``XLAGenerator.generate(schedules=...)``."""
        schedules: Dict[str, KernelSchedule] = {}
        for entry in calls.values():
            kernel = entry["kernel"]
            if kernel in schedules:
                continue
            if kernel in self.overrides:
                schedules[kernel] = self.overrides[kernel]
                continue
            record = self.tune(kernel, entry["shapes"], entry["meta"])
            schedules[kernel] = ksched.as_schedule(kernel, record["schedule"])
        return schedules

    # -- tuning -------------------------------------------------------------

    def shape_bucket(self, kernel: str, shapes: Mapping[str, Tuple[int, ...]],
                     meta: Mapping[str, Any]) -> str:
        dims = ";".join(
            f"{name}={'x'.join(str(_pow2_ceil(d)) for d in shape)}"
            for name, shape in sorted(shapes.items()))
        flags = ",".join(f"{k}={meta[k]}" for k in sorted(meta))
        return f"{dims}|{flags}"

    def tune(self, kernel: str, shapes: Mapping[str, Tuple[int, ...]],
             meta: Mapping[str, Any]) -> Dict[str, Any]:
        """Best schedule for this call site, from cache or a fresh sweep."""
        bucket = self.shape_bucket(kernel, shapes, meta)
        swept: list = []

        def sweep() -> Dict[str, Any]:
            swept.append(True)
            return self._sweep(kernel, shapes, meta, bucket)

        if self.cache is not None:
            key = ("kernel_schedule", kernel, bucket, self.target.mesh_scope)
            record = self.cache.get_or_compute(key, sweep)
        else:
            record = sweep()
        with self._lock:
            if swept:
                self._stats["tunes"] += 1
                self._stats["tune_time_s"] += float(record["tune_time_s"])
            else:
                self._stats["cache_hits"] += 1
        return record

    def _sweep(self, kernel: str, shapes: Mapping[str, Tuple[int, ...]],
               meta: Mapping[str, Any], bucket: str) -> Dict[str, Any]:
        run, seq_len, kv_len = self._runner(kernel, shapes, meta)
        # dedupe by *effective* signature: two requests that clamp to the
        # same launch would time (and later compile) the same program
        seen: Dict[str, KernelSchedule] = {}
        for cand in ksched.CANDIDATE_SCHEDULES[kernel]:
            eff = ksched.effective_schedule(kernel, cand, seq_len=seq_len,
                                            kv_len=kv_len)
            seen.setdefault(ksched.schedule_signature(kernel, eff), cand)
            if len(seen) >= self.budget:
                break
        t_start = time.perf_counter()
        timed = []
        for eff_sig, cand in seen.items():
            # measurements must not overlap sibling compiles (same
            # rationale as HardwareManager.benchmark)
            with compile_gate():
                for _ in range(self.warmup):
                    jax.block_until_ready(run(cand))
                t0 = time.perf_counter()
                for _ in range(self.iters):
                    out = run(cand)
                jax.block_until_ready(out)
                latency = (time.perf_counter() - t0) / self.iters
            timed.append((latency, cand, eff_sig))
        # stable min: the default candidate is first, so a tie keeps it
        best_latency, best, best_eff_sig = min(timed, key=lambda t: t[0])
        best_eff = ksched.effective_schedule(kernel, best, seq_len=seq_len,
                                             kv_len=kv_len)
        return {
            "kernel": kernel,
            "bucket": bucket,
            "schedule": best.to_dict(),
            "effective": best_eff.to_dict(),
            "latency_s": best_latency,
            "default_latency_s": timed[0][0],
            "n_candidates": len(timed),
            "candidates": [
                {"schedule": cand.to_dict(), "effective": sig,
                 "latency_s": lat}
                for lat, cand, sig in timed
            ],
            "tune_time_s": time.perf_counter() - t_start,
        }

    # -- synthetic inputs ---------------------------------------------------

    def _runner(self, kernel: str, shapes: Mapping[str, Tuple[int, ...]],
                meta: Mapping[str, Any]):
        """(closure timing one candidate, seq_len, kv_len) with synthetic
        inputs at the call's real shapes, fixed seed."""
        dtype = jnp.dtype(meta.get("dtype", "float32"))
        keys = iter(jax.random.split(jax.random.PRNGKey(0), 8))

        def normal(shape):
            return jax.random.normal(next(keys), shape, jnp.float32
                                     ).astype(dtype)

        if kernel == "flash_attention":
            q = normal(shapes["q"])
            k = normal(shapes["k"])
            v = normal(shapes["v"])

            def run(cand):
                return kops.flash_attention(
                    q, k, v, causal=bool(meta.get("causal", True)),
                    window=meta.get("window"), scale=meta.get("scale"),
                    schedule=cand)
            return run, shapes["q"][1], shapes["k"][1]

        if kernel == "ssm_scan":
            x = normal(shapes["x"])
            dt = jax.nn.softplus(normal(shapes["dt"]))
            a = -jnp.exp(normal(shapes["a"]))
            b = normal(shapes["b"])
            c = normal(shapes["c"])

            def run(cand):
                return kops.ssm_scan(x, dt, a, b, c, schedule=cand)
            return run, shapes["x"][1], None

        if kernel == "mlstm_scan":
            q = normal(shapes["q"])
            k = normal(shapes["k"])
            v = normal(shapes["v"])
            i_log = normal(shapes["i_log"])
            f_log = normal(shapes["f_log"])

            def run(cand):
                return kops.mlstm_scan(q, k, v, i_log, f_log, schedule=cand)
            return run, shapes["q"][1], None

        raise ksched.ScheduleError(
            f"no tuning recipe for kernel {kernel!r}")
