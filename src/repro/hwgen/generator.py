"""Generator pipeline (paper §VI): model instance -> deployable artifact.

The paper's generators emit TorchScript/LiteRT/VHDL and drive Docker
cross-compilation; the TPU-native equivalent lowers a jitted + sharded
step function and AOT-compiles it for the target mesh (the
``--xla_force_host_platform_device_count`` trick is our cross-compilation
toolchain: building a 512-chip executable on a 1-CPU host).

Two usage modes, mirroring the paper:
  1. deploy-best: generate once for the final architecture;
  2. hardware-in-the-loop: a cost estimator generates + benchmarks every
     candidate and feeds the measurement back into the study.

``HardwareManager.benchmark`` measures wall-clock on the host backend and
returns the roofline-modelled step time for TPU targets (this container
has no TPU; on real hardware the same call times the executable).
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro import faults
from repro.compat import cost_analysis_dict
from repro.envvars import read_env
from repro.hwgen.hlo_analysis import parse_collectives, total_collective_bytes
from repro.hwgen.roofline import RooflineReport, roofline_terms
from repro.hwgen.targets import TargetSpec, get_target
from repro.kernels import schedule as ksched
from repro.launch.mesh import make_mesh


@dataclasses.dataclass
class Artifact:
    """A compiled, deployable executable + its static analysis."""

    target: TargetSpec
    compiled: Any
    flops: float
    bytes_accessed: float
    collective_bytes: float
    memory: Dict[str, int]
    roofline: RooflineReport
    example_args: Tuple = ()
    # the *effective* kernel schedules this executable was built with,
    # keyed by kernel name (None = program used no schedulable kernels)
    schedules: Optional[Dict[str, Dict[str, Any]]] = None

    @property
    def fits_memory(self) -> bool:
        peak = self.memory.get("peak_bytes_per_device")
        return peak is not None and peak <= self.target.chip.hbm_bytes


class GeneratorError(RuntimeError):
    pass


def _compile_limit() -> int:
    """Max concurrent XLA compilations (admission control).

    XLA's compiler uses its own internal thread pool, so letting every
    ParallelStudy worker compile simultaneously oversubscribes the host
    and makes *each* compile slower than running them back to back
    (measured 0.68x aggregate on a 2-core container).  Serializing
    compilation while workers overlap tracing, init and benchmarking
    turns that thrash into a pipeline.  Override with
    ``REPRO_COMPILE_CONCURRENCY`` (declared in :mod:`repro.envvars`; a
    malformed value warns and falls back rather than exploding at first
    compile deep inside a worker thread).
    """
    return read_env("REPRO_COMPILE_CONCURRENCY",
                    max(1, (os.cpu_count() or 2) // 2))


_gate_init_lock = threading.Lock()
_gate: Optional[threading.BoundedSemaphore] = None

_generate_count_lock = threading.Lock()
_generate_count = 0


def generate_call_count() -> int:
    """Process-local count of :meth:`XLAGenerator.generate` invocations
    (i.e. actual XLA compilations).  Warm-restart tests and benchmarks
    assert this stays flat when every value comes from the disk cache."""
    return _generate_count


def compile_gate() -> threading.BoundedSemaphore:
    """The shared admission-control semaphore, created on first use (not
    at import) so ``REPRO_COMPILE_CONCURRENCY`` set any time before the
    first generate/benchmark takes effect."""
    global _gate
    if _gate is None:
        with _gate_init_lock:
            if _gate is None:
                _gate = threading.BoundedSemaphore(_compile_limit())
    return _gate


class XLAGenerator:
    """Translates model instances into target-specific XLA executables."""

    def __init__(self, target: TargetSpec | str):
        self.target = get_target(target) if isinstance(target, str) else target

    # -- reflection API (paper §VI) -----------------------------------------

    def supported_ops(self) -> frozenset:
        return self.target.supported_ops

    def capabilities(self) -> Dict[str, Any]:
        return {
            "ops": sorted(self.target.supported_ops),
            "pallas": self.target.supports_pallas,
            "chips": self.target.n_chips,
            "hbm_bytes": self.target.chip.hbm_bytes,
            "measurement": self.target.measurement,
        }

    # -- generation -----------------------------------------------------------

    def _mesh(self):
        try:
            return make_mesh(self.target.mesh_shape, self.target.mesh_axes)
        except RuntimeError as e:
            raise GeneratorError(
                f"target {self.target.name} needs {self.target.n_chips} devices: {e}"
            ) from e

    def generate_cached(self, cache, key, fn: Callable, example_args: Tuple, **kw) -> Artifact:
        """Memoized :meth:`generate` through a shared
        :class:`~repro.evaluation.cache.EvaluationCache`: estimators that
        need the same candidate's artifact (latency + memory) compile it
        once; concurrent workers racing on one key compile it once too
        (single-flight)."""
        return cache.get_or_compute(key, lambda: self.generate(fn, example_args, **kw))

    def generate(
        self,
        fn: Callable,
        example_args: Tuple,
        in_shardings=None,
        out_shardings=None,
        static_argnums=(),
        schedules=None,
    ) -> Artifact:
        """``schedules`` maps kernel name -> :class:`KernelSchedule` (or a
        field mapping); it is made active for the trace so every Pallas
        kernel the program reaches launches with the tuned parameters,
        and the artifact records the *effective* (shape-clamped)
        schedules it was actually built with."""
        global _generate_count
        with _generate_count_lock:
            _generate_count += 1
        # chaos seam: a `raise` here models an XLA/toolchain crash on one
        # candidate, a `delay` models a pathological compile
        faults.fault_point("compile", key=self.target.name)
        mesh = self._mesh()
        # Admission control around the whole generate pipeline: tracing is
        # GIL-bound Python, XLA compilation oversubscribes its internal
        # pool, and the post-compile HLO analysis is GIL-bound text
        # parsing — all of them contend when every ParallelStudy worker
        # runs them at once (measured 0.68x aggregate for concurrent
        # compiles on a 2-core container).  Gating them pipelines the
        # workers; what overlaps is everything else: model build/init and
        # cache hits (wall-clock measurement takes the same gate — see
        # HardwareManager.benchmark).
        kernel_calls: Dict[Tuple[str, str], Dict[str, Any]] = {}
        with compile_gate():
            with mesh:
                jitted = jax.jit(
                    fn,
                    in_shardings=in_shardings,
                    out_shardings=out_shardings,
                    static_argnums=static_argnums,
                )
                # schedules bind at trace time (the kernel resolvers run
                # in Python during lowering), and the recorder captures
                # what each call actually launched with
                with ksched.use_schedules(schedules), \
                        ksched.record_kernel_calls(kernel_calls):
                    lowered = jitted.lower(*example_args)
                compiled = lowered.compile()
            ca = cost_analysis_dict(compiled)
            flops = float(ca.get("flops", 0.0))
            bytes_accessed = float(ca.get("bytes accessed", 0.0))
            coll = total_collective_bytes(parse_collectives(compiled.as_text()))
            try:
                ma = compiled.memory_analysis()
                memory = {
                    "argument_bytes": int(ma.argument_size_in_bytes),
                    "output_bytes": int(ma.output_size_in_bytes),
                    "temp_bytes": int(ma.temp_size_in_bytes),
                    "peak_bytes_per_device": int(
                        ma.argument_size_in_bytes + ma.output_size_in_bytes
                        + ma.temp_size_in_bytes - ma.alias_size_in_bytes
                    ),
                }
            except Exception:
                memory = {}
        roofline = roofline_terms(
            hlo_flops=flops,
            hlo_bytes=bytes_accessed,
            collective_bytes=coll,
            n_chips=1,  # per-device program quantities
            chip=self.target.chip,
        )
        built_with = {
            entry["kernel"]: entry["effective"].to_dict()
            for entry in kernel_calls.values()
        } or None
        return Artifact(
            target=self.target,
            compiled=compiled,
            flops=flops,
            bytes_accessed=bytes_accessed,
            collective_bytes=coll,
            memory=memory,
            roofline=roofline,
            example_args=example_args,
            schedules=built_with,
        )


class HardwareManager:
    """Deploys artifacts and extracts cost metrics (paper §VI).

    On measurement="wallclock" targets, executes the compiled binary with
    real inputs and times it (true hardware-in-the-loop in this
    container); on roofline targets, returns the modelled step time.
    """

    def __init__(self, warmup: int = 2, iters: int = 10):
        self.warmup = warmup
        self.iters = iters

    def benchmark(self, artifact: Artifact, concrete_args: Optional[Tuple] = None) -> Dict[str, float]:
        if artifact.target.measurement == "roofline":
            r = artifact.roofline
            return {
                "latency_s": r.bound_s,
                "compute_s": r.compute_s,
                "memory_s": r.memory_s,
                "collective_s": r.collective_s,
                "measured": 0.0,
            }
        args = concrete_args
        if args is None:
            args = tuple(
                jax.tree_util.tree_map(
                    lambda s: np.zeros(s.shape, s.dtype)
                    if hasattr(s, "shape") else s,
                    a,
                )
                for a in artifact.example_args
            )
        fn = artifact.compiled
        # Wall-clock measurement must not overlap sibling workers' XLA
        # compiles (or other measurements) — a timing taken during a
        # neighbour's compile reports scheduler contention, not the
        # architecture's latency, and the evaluation cache would freeze
        # that corrupted number.  Take the same admission gate.
        with compile_gate():
            for _ in range(self.warmup):
                out = fn(*args)
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            for _ in range(self.iters):
                out = fn(*args)
            jax.block_until_ready(out)
            dt = (time.perf_counter() - t0) / self.iters
        return {"latency_s": dt, "measured": 1.0}
