"""Losses.  Cross-entropy upcasts logits to f32; a chunked variant bounds
the (B, S, vocab) logit materialization for 150k+ vocabularies."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels, mask=None):
    """logits: (B, S, V); labels: (B, S) int32.  Mean over unmasked tokens."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_cross_entropy(h, head_w, labels, *, chunk: int = 1024, mask=None,
                          transposed: bool = False, unroll: bool = False):
    """Cross-entropy without materializing all logits.

    h: (B, S, D) final hidden states; head_w: (D, V), or (V, D) with
    ``transposed=True`` (tied embeddings).  Computes per-chunk logits
    inside a scan — peak memory drops from O(S*V) to O(chunk*V).
    """
    b, s, d = h.shape
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    hc = h.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n, chunk).transpose(1, 0, 2)
    mc = None if mask is None else mask.reshape(b, n, chunk).transpose(1, 0, 2)

    def body(carry, inp):
        if mc is None:
            hi, li = inp
            mi = jnp.ones_like(li, jnp.float32)
        else:
            hi, li, mi = inp
        if transposed:
            logits = jnp.einsum("bsd,vd->bsv", hi, head_w).astype(jnp.float32)
        else:
            logits = (hi @ head_w).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mi
        total, count = carry
        return (total + jnp.sum(nll), count + jnp.sum(mi)), None

    xs = (hc, lc) if mc is None else (hc, lc, mc)
    (total, count), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), xs,
                                     unroll=n if unroll else 1)
    return total / jnp.maximum(count, 1.0)


def shift_labels(tokens):
    """Next-token prediction: labels[t] = tokens[t+1]; last position masked."""
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones_like(tokens, jnp.float32).at[:, -1].set(0.0)
    return labels, mask
