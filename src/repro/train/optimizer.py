"""Optimizers (AdamW / SGD-momentum / Adafactor) as pure update rules.

State trees mirror the parameter tree, so parameter shardings apply
verbatim to optimizer state (fully sharded optimizer — ZeRO-style when the
params are FSDP-sharded over the data axis).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    learning_rate: Union[float, Callable[[jnp.ndarray], jnp.ndarray]] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: Optional[float] = 1.0
    momentum: float = 0.9  # sgd
    # adafactor
    decay_rate: float = 0.8
    state_dtype = jnp.float32


def _lr_at(cfg: OptimizerConfig, step):
    lr = cfg.learning_rate
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


class Optimizer:
    """Bundles init/update; pure functions of (grads, state, params)."""

    def __init__(self, cfg: OptimizerConfig):
        self.cfg = cfg

    def init(self, params):
        cfg = self.cfg
        zeros_like = lambda p: jnp.zeros_like(p, dtype=cfg.state_dtype)
        if cfg.name == "adamw":
            return {
                "step": jnp.zeros((), jnp.int32),
                "mu": jax.tree_util.tree_map(zeros_like, params),
                "nu": jax.tree_util.tree_map(zeros_like, params),
            }
        if cfg.name == "sgd":
            return {
                "step": jnp.zeros((), jnp.int32),
                "mu": jax.tree_util.tree_map(zeros_like, params),
            }
        if cfg.name == "adafactor":
            def factored(p):
                if p.ndim >= 2:
                    return {
                        "row": jnp.zeros(p.shape[:-1], cfg.state_dtype),
                        "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], cfg.state_dtype),
                    }
                return {"full": zeros_like(p)}

            return {
                "step": jnp.zeros((), jnp.int32),
                "v": jax.tree_util.tree_map(factored, params),
            }
        raise ValueError(self.cfg.name)

    def update(self, grads, state, params):
        cfg = self.cfg
        step = state["step"] + 1
        lr = _lr_at(cfg, step)
        grad_norm = None
        if cfg.grad_clip_norm is not None:
            grads, grad_norm = clip_by_global_norm(grads, cfg.grad_clip_norm)

        if cfg.name == "adamw":
            bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
            bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

            def upd(p, g, mu, nu):
                gf = g.astype(jnp.float32)
                mu_n = cfg.b1 * mu + (1 - cfg.b1) * gf
                nu_n = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(gf)
                mu_hat = mu_n / bc1
                nu_hat = nu_n / bc2
                delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
                return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu_n, nu_n

            out = jax.tree_util.tree_map(upd, params, grads, state["mu"], state["nu"])
            # out is a tree of 3-tuples; unzip
            new_params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
            new_mu = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
            new_nu = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
            new_state = {"step": step, "mu": new_mu, "nu": new_nu}
            return new_params, new_state, {"lr": lr, "grad_norm": grad_norm}

        if cfg.name == "sgd":
            def upd(p, g, mu):
                gf = g.astype(jnp.float32)
                mu_n = cfg.momentum * mu + gf
                return (p.astype(jnp.float32) - lr * mu_n).astype(p.dtype), mu_n

            out = jax.tree_util.tree_map(upd, params, grads, state["mu"])
            new_params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
            new_mu = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
            return new_params, {"step": step, "mu": new_mu}, {"lr": lr, "grad_norm": grad_norm}

        if cfg.name == "adafactor":
            decay = 1.0 - (step.astype(jnp.float32)) ** -cfg.decay_rate

            def upd(p, g, v):
                gf = g.astype(jnp.float32)
                g2 = jnp.square(gf) + 1e-30
                if p.ndim >= 2:
                    row = decay * v["row"] + (1 - decay) * jnp.mean(g2, axis=-1)
                    col = decay * v["col"] + (1 - decay) * jnp.mean(g2, axis=-2)
                    row_mean = jnp.mean(row, axis=-1, keepdims=True)
                    r = (row / jnp.maximum(row_mean, 1e-30))[..., None]
                    c = col[..., None, :]
                    vhat = r * c
                    new_v = {"row": row, "col": col}
                else:
                    full = decay * v["full"] + (1 - decay) * g2
                    vhat = full
                    new_v = {"full": full}
                update = gf * jax.lax.rsqrt(vhat + 1e-30)
                # relative step clipping
                rms = jnp.sqrt(jnp.mean(jnp.square(update)))
                update = update / jnp.maximum(1.0, rms)
                newp = p.astype(jnp.float32) - lr * (update + cfg.weight_decay * p.astype(jnp.float32))
                return newp.astype(p.dtype), new_v

            is_v = lambda x: isinstance(x, dict) and ("row" in x or "full" in x)
            out = jax.tree_util.tree_map(
                lambda v, p, g: upd(p, g, v), state["v"], params, grads, is_leaf=is_v
            )
            new_params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
            new_v = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
            return new_params, {"step": step, "v": new_v}, {"lr": lr, "grad_norm": grad_norm}

        raise ValueError(cfg.name)


def cosine_schedule(base_lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(1, warmup), 1.0)
        progress = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * progress))
        return base_lr * warm * (min_ratio + (1 - min_ratio) * cos)

    return fn
