"""Train / serve step factories — the functions that get jit-ed + sharded.

``make_train_step`` builds a pure step: (params, opt_state, batch) ->
(params, opt_state, metrics), with optional microbatch gradient
accumulation (lax.scan) and gradient compression with error feedback.
Model-family differences (decoder-only / enc-dec / vlm-prefix) are
absorbed by ``model_forward`` keyed on the batch contents.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.train.loss import cross_entropy, shift_labels
from repro.train.optimizer import Optimizer


def model_forward(model, params, batch):
    """Dispatch on batch keys: tokens / frames (enc-dec) / patch_embeds."""
    if "frames" in batch:
        enc_out = model.encode(params, batch["frames"])
        return model.apply(params, batch["tokens"], enc_out=enc_out)
    if "patch_embeds" in batch:
        return model.apply(params, batch["tokens"], prefix_embeds=batch["patch_embeds"])
    return model.apply(params, batch["tokens"])


def make_loss_fn(model, loss_chunk: int = 0, loss_unroll: bool = False):
    """loss_chunk > 0 selects the chunked-logits path (the (B,S,vocab)
    tensor never materializes — a §Perf memory-term lever for 150k+
    vocabularies).  loss_unroll unrolls the chunk scan for the dry-run
    cost variant (HloCostAnalysis counts while bodies once)."""

    def loss_fn(params, batch):
        if "labels" in batch:
            labels, mask = batch["labels"], batch.get("loss_mask")
        else:
            labels, mask = shift_labels(batch["tokens"])
        if loss_chunk and "frames" not in batch:
            from repro.train.loss import chunked_cross_entropy

            kwargs = {}
            if "patch_embeds" in batch:
                kwargs["prefix_embeds"] = batch["patch_embeds"]
            h = model.hidden(params, batch["tokens"], **kwargs)
            w, transposed = model.head_weight(params)
            chunk = min(loss_chunk, h.shape[1])
            while h.shape[1] % chunk:
                chunk //= 2
            return chunked_cross_entropy(h, w, labels, chunk=max(chunk, 1),
                                         mask=mask, transposed=transposed,
                                         unroll=loss_unroll)
        logits = model_forward(model, params, batch)
        return cross_entropy(logits, labels, mask)

    return loss_fn


def make_train_step(
    model,
    optimizer: Optimizer,
    *,
    microbatches: int = 1,
    compressor=None,
    loss_chunk: int = 0,
    loss_unroll: bool = False,
):
    loss_fn = make_loss_fn(model, loss_chunk=loss_chunk, loss_unroll=loss_unroll)
    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(params, opt_state, batch, compress_state=None):
        if microbatches <= 1:
            loss, grads = grad_fn(params, batch)
        else:
            def split_mb(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mbs = jax.tree_util.tree_map(split_mb, batch)

            def accum(carry, mb):
                loss_sum, grads_sum = carry
                loss, grads = grad_fn(params, mb)
                grads_sum = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), grads_sum, grads
                )
                return (loss_sum + loss, grads_sum), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(accum, (jnp.zeros(()), zeros), mbs)
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)

        if compressor is not None:
            grads, compress_state = compressor.compress_decompress(grads, compress_state)

        params, opt_state, opt_metrics = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss, **{k: v for k, v in opt_metrics.items() if v is not None}}
        if compressor is not None:
            return params, opt_state, metrics, compress_state
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model, last_only: bool = False):
    """Full-sequence forward (inference prefill).

    last_only=True returns only the final position's logits — serving
    semantics (the sampler needs one next-token distribution); drops the
    (B, S, vocab) logits buffer AND S-1/S of the LM-head matmul."""

    def prefill_step(params, batch):
        if last_only and "frames" not in batch:
            kwargs = {}
            if "patch_embeds" in batch:
                kwargs["prefix_embeds"] = batch["patch_embeds"]
            h = model.hidden(params, batch["tokens"], **kwargs)
            h_last = h[:, -1:]
            w, transposed = model.head_weight(params)
            if transposed:
                return jnp.einsum("bsd,vd->bsv", h_last, w)
            return jnp.einsum("bsd,dv->bsv", h_last, w)
        return model_forward(model, params, batch)

    return prefill_step


def make_decode_step(model):
    """One-token decode against the KV/state cache."""

    def decode_step(params, cache, tokens, pos):
        return model.decode(params, cache, tokens, pos)

    return decode_step


def make_eval_step(model):
    loss_fn = make_loss_fn(model)

    def eval_step(params, batch):
        return loss_fn(params, batch)

    return eval_step
