"""Small shared I/O helpers for crash-tolerant append-only JSONL stores."""
from __future__ import annotations

import os

try:
    import fcntl
except ImportError:  # pragma: no cover — non-POSIX hosts
    fcntl = None


def locked_append(path: str, line: str) -> None:
    """Append one record to ``path`` durably and atomically w.r.t. other
    processes: an OS advisory lock around a single ``write`` + flush +
    fsync, so concurrent appenders sharing the file never tear records.
    Serialization against sibling *threads* is the caller's job."""
    with open(path, "a") as f:
        if fcntl is not None:
            fcntl.flock(f.fileno(), fcntl.LOCK_EX)
        try:
            f.write(line)
            f.flush()
            os.fsync(f.fileno())
        finally:
            if fcntl is not None:
                fcntl.flock(f.fileno(), fcntl.LOCK_UN)
