"""Small shared I/O helpers for crash-tolerant append-only JSONL stores.

Locking: POSIX ``flock`` is the first choice (whole-file advisory lock,
released on close, survives fork sanely).  On NFS-style mounts — which
remote workers sharing a cache directory over a network filesystem will
hit — ``flock`` may be unsupported (``ENOLCK``/``EOPNOTSUPP``) or, on
old NFSv2/v3 setups, silently **non-exclusive** between hosts.  When
``flock`` raises, :func:`lock_file` falls back to ``fcntl.lockf`` range
locks (which NFS implements through the NLM/NFSv4 locking protocol) and
warns once per store path.  The fallback caveat: POSIX range locks are
per-process, so they serialize *processes*, not threads — callers here
already serialize sibling threads themselves — and closing *any*
descriptor of the file drops the lock, so helpers keep exactly one
descriptor open for the locked region's lifetime.
"""
from __future__ import annotations

import os
import warnings
from typing import Set

try:
    import fcntl
except ImportError:  # pragma: no cover — non-POSIX hosts
    fcntl = None

# store paths whose filesystem rejected flock: subsequent locks go
# straight to the lockf fallback without re-probing (and re-warning)
_FLOCK_UNSUPPORTED: Set[str] = set()


def lock_file(f, path: str = "") -> str:
    """Take an exclusive lock on open file object ``f``; returns the
    mechanism used (``"flock"`` | ``"lockf"`` | ``"none"``) for
    :func:`unlock_file`.  Falls back from ``flock`` to ``fcntl.lockf``
    range locks when the filesystem refuses whole-file locks (NFS-style
    mounts), warning once per ``path``."""
    if fcntl is None:  # pragma: no cover — non-POSIX hosts
        return "none"
    key = path or getattr(f, "name", "")
    if key not in _FLOCK_UNSUPPORTED:
        try:
            fcntl.flock(f.fileno(), fcntl.LOCK_EX)
            return "flock"
        except OSError:
            _FLOCK_UNSUPPORTED.add(key)
            warnings.warn(
                f"flock unsupported for {key!r} (NFS-style mount?); falling "
                f"back to fcntl range locks — cross-host exclusion now relies "
                f"on the filesystem's POSIX-lock support",
                RuntimeWarning, stacklevel=3)
    fcntl.lockf(f.fileno(), fcntl.LOCK_EX)
    return "lockf"


def unlock_file(f, how: str) -> None:
    """Release a lock taken by :func:`lock_file`."""
    if fcntl is None or how == "none":  # pragma: no cover — non-POSIX hosts
        return
    if how == "flock":
        fcntl.flock(f.fileno(), fcntl.LOCK_UN)
    else:
        fcntl.lockf(f.fileno(), fcntl.LOCK_UN)


def locked_append(path: str, line: str) -> None:
    """Append one record to ``path`` durably and atomically w.r.t. other
    processes: an OS advisory lock around a single ``write`` + flush +
    fsync, so concurrent appenders sharing the file never tear records.
    Serialization against sibling *threads* is the caller's job.

    Crash hardening: a writer killed mid-append leaves a torn tail with
    no trailing newline; appending straight onto it would concatenate
    the new record into the garbage and lose *both*.  Under the lock we
    check the last byte and seal a torn tail with a newline first, so
    corruption stays confined to the one record that was actually torn.
    """
    data = line.encode("utf-8")
    with open(path, "ab+") as f:
        how = lock_file(f, path)
        try:
            f.seek(0, os.SEEK_END)
            end = f.tell()
            if end > 0:
                f.seek(end - 1)
                if f.read(1) != b"\n":
                    data = b"\n" + data
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        finally:
            unlock_file(f, how)
