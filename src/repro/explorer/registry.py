"""String-keyed component registries for the Explorer facade.

Every pluggable piece of the NAS pipeline — samplers, executors,
estimators, pruners, hardware targets — is published here under a stable
string key, so a declarative :class:`~repro.explorer.experiment.ExperimentSpec`
can name components without importing their classes, and third-party
code can plug in new ones without touching the engine:

    from repro.explorer.registry import register

    @register("sampler", "simulated_annealing")
    class SimulatedAnnealingSampler(BaseSampler):
        ...

The built-in classes self-register at import time (see
``repro/search/samplers.py``, ``repro/search/executors.py``,
``repro/search/pruners.py``, ``repro/evaluation/estimators.py``,
``repro/hwgen/targets.py``); :func:`ensure_builtins` imports those
modules on first lookup so a registry consulted before anything else is
imported still sees the full built-in set.

This module must stay import-light (stdlib only): the registering
modules import it at class-definition time, so any import of repro
internals here would be circular.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional


class ExplorerError(ValueError):
    """Base class for facade configuration errors."""


class UnknownComponentError(ExplorerError):
    """A spec named a component key that no registry entry matches."""

    def __init__(self, kind: str, name: str, known: List[str]):
        super().__init__(
            f"unknown {kind} {name!r}; registered {kind}s: {known or '(none)'}"
        )
        self.kind, self.name, self.known = kind, name, known


class Registry:
    """One string-keyed component namespace (e.g. all samplers)."""

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: Dict[str, Any] = {}

    def register(self, name: str, obj: Any = None):
        """Register ``obj`` under ``name``.  Usable as a decorator
        (``@SAMPLERS.register("random")``) or a direct call
        (``TARGETS.register("host_cpu", spec)``).  Re-registering the same
        object is a no-op; a different object under a taken key raises —
        silent shadowing of a built-in would make specs ambiguous."""

        def _add(target: Any) -> Any:
            key = str(name)
            existing = self._entries.get(key)
            if existing is not None and existing is not target:
                raise ExplorerError(
                    f"{self.kind} key {key!r} already registered to "
                    f"{existing!r}; pick a different key for {target!r}"
                )
            self._entries[key] = target
            return target

        if obj is None:
            return _add
        return _add(obj)

    def get(self, name: str) -> Any:
        ensure_builtins()
        try:
            return self._entries[str(name)]
        except KeyError:
            raise UnknownComponentError(self.kind, str(name), self.names()) from None

    def names(self) -> List[str]:
        ensure_builtins()
        return sorted(self._entries)

    def __contains__(self, name: object) -> bool:
        ensure_builtins()
        return str(name) in self._entries

    def __repr__(self) -> str:
        return f"Registry({self.kind!r}, {sorted(self._entries)})"


SAMPLERS = Registry("sampler")
EXECUTORS = Registry("executor")
ESTIMATORS = Registry("estimator")
PRUNERS = Registry("pruner")
TARGETS = Registry("target")

REGISTRIES: Dict[str, Registry] = {
    "sampler": SAMPLERS,
    "executor": EXECUTORS,
    "estimator": ESTIMATORS,
    "pruner": PRUNERS,
    "target": TARGETS,
}


def register(kind: str, name: str, obj: Any = None):
    """Plugin entry point: ``@register("sampler", "my_sampler")``."""
    try:
        registry = REGISTRIES[kind]
    except KeyError:
        raise ExplorerError(
            f"unknown registry kind {kind!r}; known kinds: {sorted(REGISTRIES)}"
        ) from None
    return registry.register(name, obj)


_builtins_loaded = False


def ensure_builtins() -> None:
    """Import the modules whose classes self-register, exactly once.

    The flag is set before importing so the registration decorators
    running inside those imports (which may consult other registries)
    cannot recurse into a second load."""
    global _builtins_loaded
    if _builtins_loaded:
        return
    _builtins_loaded = True
    import repro.evaluation.estimators  # noqa: F401
    import repro.evaluation.proxies  # noqa: F401
    import repro.evaluation.serving  # noqa: F401
    import repro.hwgen.targets  # noqa: F401
    import repro.search.executors  # noqa: F401
    import repro.search.pruners  # noqa: F401
    import repro.search.remote.executor  # noqa: F401
    import repro.search.samplers  # noqa: F401
