"""Run a YAML experiment — or fan one across a sweep — from the shell::

    PYTHONPATH=src python -m repro.explorer examples/experiments/quickstart.yaml
    PYTHONPATH=src python -m repro.explorer sweep examples/experiments/sweep_small.yaml
    PYTHONPATH=src python -m repro.explorer --list-components

Overrides exist for the knobs CI and quick local smoke runs need to
shrink without editing the experiment/sweep file.
"""
from __future__ import annotations

import argparse
from typing import List, Optional


def _run_experiment(argv: List[str]) -> int:
    from repro.explorer.experiment import ExperimentSpec
    from repro.explorer.explorer import Explorer

    p = argparse.ArgumentParser(
        prog="python -m repro.explorer",
        description="Run a declarative NAS experiment (YAML) through the Explorer facade.",
    )
    p.add_argument("experiment", help="path to the experiment YAML")
    p.add_argument("--trials", type=int, default=None, help="override budget.n_trials")
    p.add_argument("--backend", default=None, help="override executor.backend")
    p.add_argument("--workers", type=int, default=None, help="override executor.n_workers")
    p.add_argument("--schedule", default=None,
                   choices=("auto", "batch", "sliding_window"),
                   help="override schedule.mode")
    p.add_argument("--tell-order", default=None, choices=("trial", "completion"),
                   help="override schedule.tell_order")
    p.add_argument("--report-dir", default=None, help="override report_dir")
    p.add_argument("--remote-workers", default=None, metavar="HOST:PORT,...",
                   help="override executor.workers (comma-separated worker "
                        "daemons) and switch the backend to remote")
    args = p.parse_args(argv)

    spec = ExperimentSpec.from_yaml(args.experiment)
    if args.trials is not None:
        spec.budget.n_trials = max(1, args.trials)
    if args.backend is not None:
        spec.executor.backend = args.backend
    if args.remote_workers is not None:
        spec.executor.workers = [
            w for w in (s.strip() for s in args.remote_workers.split(",")) if w]
        spec.executor.backend = "remote"
        if args.workers is None:
            spec.executor.n_workers = max(1, len(spec.executor.workers))
    if args.workers is not None:
        spec.executor.n_workers = max(1, args.workers)
    if args.schedule is not None:
        spec.schedule.mode = args.schedule
    if args.tell_order is not None:
        spec.schedule.tell_order = args.tell_order
    if args.report_dir is not None:
        spec.report_dir = args.report_dir

    report = Explorer.from_spec(spec).run()
    best = report.best
    print(f"experiment {report.experiment!r}: {report.n_trials} trials "
          f"({report.states}) in {report.wall_clock_s:.1f}s "
          f"on {report.backend}/{report.n_workers} "
          f"(schedule={report.schedule['mode']})")
    if best is not None:
        print(f"best trial #{best['number']}: values={best['values']} "
              f"arch={best['signature']}")
    if report.cache:
        print(f"cache: {report.cache}")
    print(f"report: {report.artifact}")
    return 0


def _run_sweep(argv: List[str]) -> int:
    from repro.explorer.sweep import SweepError, SweepSpec, run_sweep

    p = argparse.ArgumentParser(
        prog="python -m repro.explorer sweep",
        description="Fan one experiment across axes and merge the reports.",
    )
    p.add_argument("sweep", help="path to the sweep YAML")
    p.add_argument("--axis", action="append", default=[], metavar="KEY=V1,V2",
                   help="replace one axis with comma-separated scalar values "
                        "(e.g. --axis target=host_cpu,edge_npu); repeatable")
    p.add_argument("--trials", type=int, default=None,
                   help="override every cell's budget.n_trials")
    p.add_argument("--workers", type=int, default=None,
                   help="override every cell's executor.n_workers")
    p.add_argument("--report-dir", default=None, help="override report_dir")
    p.add_argument("--no-resume", action="store_true",
                   help="re-run every cell even when a completed report exists")
    p.add_argument("--cell-workers", default=None, metavar="HOST:PORT,...",
                   help="fan non-resumed cells across these worker daemons "
                        "(comma-separated; overrides the sweep's `workers:`)")
    args = p.parse_args(argv)

    spec = SweepSpec.from_yaml(args.sweep)
    for override in args.axis:
        key, eq, values = override.partition("=")
        if not eq or not values:
            p.error(f"--axis expects KEY=V1[,V2...], got {override!r}")
        from repro.explorer.sweep import AXIS_ALIASES
        spec.axes[AXIS_ALIASES.get(key, key)] = [
            v for v in (s.strip() for s in values.split(",")) if v]
    # shrink knobs are applied AFTER each cell's axis values, so they win
    # even over a whole-section `budget:`/`executor:` axis
    overrides = {}
    if args.trials is not None:
        overrides["budget.n_trials"] = max(1, args.trials)
        spec.axes.pop("budget.n_trials", None)  # now-constant axis
    if args.workers is not None:
        overrides["executor.n_workers"] = max(1, args.workers)
        spec.axes.pop("executor.n_workers", None)
    if args.report_dir is not None:
        spec.report_dir = args.report_dir

    cell_workers = None
    if args.cell_workers is not None:
        cell_workers = [
            w for w in (s.strip() for s in args.cell_workers.split(",")) if w]

    try:
        report = run_sweep(spec, resume=not args.no_resume,
                           overrides=overrides or None, workers=cell_workers)
    except SweepError as e:
        p.error(str(e))
    print(f"sweep {report.sweep!r}: {report.n_cells} cells "
          f"({report.n_resumed} resumed) in {report.wall_clock_s:.1f}s")
    for cell in report.cells:
        best = cell["best"] or {}
        tag = " (resumed)" if cell["resumed"] else ""
        print(f"  {cell['name']}: best #{best.get('number')} "
              f"values={best.get('values')}{tag}")
    for profile, ranked in report.target_rankings.items():
        if ranked:
            order = " > ".join(r["target"] for r in ranked)
            print(f"  wins[{profile}]: {order}")
    if report.cache:
        print(f"  cache: {report.cache}")
    print(f"report: {report.artifact}")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    import sys

    argv = list(sys.argv[1:] if argv is None else argv)
    if "--list-components" in argv:
        from repro.explorer.docgen import list_components_text

        print(list_components_text(), end="")
        return 0
    if argv and argv[0] == "sweep":
        return _run_sweep(argv[1:])
    return _run_experiment(argv)


if __name__ == "__main__":
    raise SystemExit(main())
