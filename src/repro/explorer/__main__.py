"""Run a YAML experiment from the command line::

    PYTHONPATH=src python -m repro.explorer examples/experiments/quickstart.yaml

Overrides exist for the knobs CI and quick local smoke runs need to
shrink without editing the experiment file.
"""
from __future__ import annotations

import argparse

from repro.explorer.experiment import ExperimentSpec
from repro.explorer.explorer import Explorer


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.explorer",
        description="Run a declarative NAS experiment (YAML) through the Explorer facade.",
    )
    p.add_argument("experiment", help="path to the experiment YAML")
    p.add_argument("--trials", type=int, default=None, help="override budget.n_trials")
    p.add_argument("--backend", default=None, help="override executor.backend")
    p.add_argument("--workers", type=int, default=None, help="override executor.n_workers")
    p.add_argument("--schedule", default=None,
                   choices=("auto", "batch", "sliding_window"),
                   help="override schedule.mode")
    p.add_argument("--tell-order", default=None, choices=("trial", "completion"),
                   help="override schedule.tell_order")
    p.add_argument("--report-dir", default=None, help="override report_dir")
    args = p.parse_args(argv)

    spec = ExperimentSpec.from_yaml(args.experiment)
    if args.trials is not None:
        spec.budget.n_trials = max(1, args.trials)
    if args.backend is not None:
        spec.executor.backend = args.backend
    if args.workers is not None:
        spec.executor.n_workers = max(1, args.workers)
    if args.schedule is not None:
        spec.schedule.mode = args.schedule
    if args.tell_order is not None:
        spec.schedule.tell_order = args.tell_order
    if args.report_dir is not None:
        spec.report_dir = args.report_dir

    report = Explorer.from_spec(spec).run()
    best = report.best
    print(f"experiment {report.experiment!r}: {report.n_trials} trials "
          f"({report.states}) in {report.wall_clock_s:.1f}s "
          f"on {report.backend}/{report.n_workers} "
          f"(schedule={report.schedule['mode']})")
    if best is not None:
        print(f"best trial #{best['number']}: values={best['values']} "
              f"arch={best['signature']}")
    if report.cache:
        print(f"cache: {report.cache}")
    print(f"report: {report.artifact}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
