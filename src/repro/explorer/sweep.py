"""Experiment sweeps: fan one :class:`ExperimentSpec` across axes and
merge the per-cell reports into one comparative :class:`SweepReport`.

The paper positions the framework as a *unified* interface across
heterogeneous accelerator platforms; the payoff of that unification is
the cross-target comparison (Once-for-All's train-once/specialize-per-
platform, HW-NAS-Bench's tabular cross-device tables), not any single
run.  A ``SweepSpec`` is the meta-spec for exactly that::

    name: sweep-small
    base: {file: quickstart.yaml}      # or an inline experiment mapping
    axes:
      target: [host_cpu, edge_npu, tpu_v5e_pod]
      sampler: [{name: random, seed: 0}, {name: tpe, seed: 0}]
      budget.n_trials: [8]             # any dotted key is an axis
    cache: results/cache               # shared disk store for every cell
    report_dir: results

``expand()`` takes the cross product of the axes, applies each
combination to the base experiment as dotted-key overrides, and
validates every child eagerly — a bad axis value fails before anything
runs, naming the axis.  ``run_sweep()`` then drives each cell through
the ordinary :class:`~repro.explorer.explorer.Explorer` (so at a fixed
seed a cell's best trial is identical to running that child spec
standalone) with every cell sharing one disk cache — compile-derived
values are scoped by mesh topology, so a second target whose topology
matches recompiles nothing — and merges the reports: a per-criterion
best-value matrix (target x sampler), the cross-target Pareto union,
aggregated cache/compaction hygiene, and per-criterion target rankings.

**Resume.**  Each cell's report is written under
``<report_dir>/<sweep>.cells/`` before the next cell starts, and a cell
whose persisted report still matches its spec (the report embeds the
full spec) is skipped on re-run — a killed sweep restarts where it
stopped instead of re-paying completed cells.
"""
from __future__ import annotations

import copy
import dataclasses
import hashlib
import itertools
import json
import os
import re
import time
from typing import Any, Dict, List, Mapping, Optional, Tuple

import yaml

from repro.explorer.experiment import (
    TOP_LEVEL_KEYS,
    ExperimentError,
    ExperimentSpec,
    _require_mapping,
)
from repro.explorer.registry import ExplorerError


class SweepError(ExplorerError):
    """A sweep spec failed validation (bad axis, bad cell, bad key)."""


# plural conveniences for the common comparison axes; any other axis key
# must be a (dotted) path into the experiment document itself
AXIS_ALIASES = {"targets": "target", "samplers": "sampler",
                "schedules": "schedule", "executors": "executor"}

SWEEP_KEYS = ("name", "base", "axes", "cache", "report_dir", "workers")


def _set_dotted(doc: Dict[str, Any], dotted: str, value: Any) -> None:
    """Apply one ``a.b.c = value`` override, creating intermediate
    mappings; a non-mapping intermediate is an axis error."""
    parts = dotted.split(".")
    node = doc
    for part in parts[:-1]:
        child = node.get(part)
        if child is None:
            child = node[part] = {}
        elif not isinstance(child, dict):
            raise SweepError(
                f"axis {dotted!r} descends through {part!r}, which is "
                f"{type(child).__name__}, not a mapping")
        node = child
    node[parts[-1]] = copy.deepcopy(value)


def _axis_label(value: Any) -> str:
    """Short, filesystem-safe label for one axis value (used in cell
    names): component mappings label by their name/mode/backend key, with
    a content hash suffix when extra options would otherwise collide."""
    if isinstance(value, Mapping):
        label = None
        for probe in ("name", "mode", "backend"):
            if probe in value:
                label = str(value[probe])
                extra = {k: v for k, v in value.items() if k != probe}
                break
        if label is None:
            label, extra = "cfg", dict(value)
        if extra and all(isinstance(v, (str, int, float, bool))
                         for v in extra.values()) and len(extra) <= 3:
            # short scalar options read better inline: "tpe-seed0"
            label += "".join(f"-{k}{v}" for k, v in sorted(extra.items()))
        elif extra:
            digest = hashlib.sha1(
                json.dumps(extra, sort_keys=True, default=str).encode()
            ).hexdigest()[:6]
            label = f"{label}-{digest}"
    else:
        label = str(value)
    return re.sub(r"[^A-Za-z0-9._-]+", "-", label) or "value"


@dataclasses.dataclass
class SweepCell:
    """One point of the cross product: a fully validated child spec."""

    name: str
    axes: Dict[str, str]          # axis key -> value label (for humans)
    axis_values: Dict[str, Any]   # axis key -> raw value (for machines)
    spec: ExperimentSpec

    @property
    def report_path(self) -> str:
        return os.path.join(self.spec.report_dir, f"{self.name}.report.json")


@dataclasses.dataclass
class SweepSpec:
    """A validated sweep: base experiment + axes, YAML/dict round-trip."""

    name: str
    base: Dict[str, Any]          # resolved experiment dict (space inlined)
    axes: Dict[str, List[Any]]    # normalized axis key -> values, in order
    cache: Optional[str] = None   # shared disk store forced into every cell
    report_dir: str = "results"
    workers: Optional[List[str]] = None  # worker daemons to fan cells across

    FIELD_DOCS = {
        "name": "sweep name; names `<report_dir>/<name>.sweep.json` and "
                "the per-cell directory `<report_dir>/<name>.cells/` "
                "(default: `sweep`)",
        "base": "**required** — the experiment every cell starts from: an "
                "inline experiment mapping or `{file: experiment.yaml}` "
                "(validated eagerly; search-space refs are inlined)",
        "axes": "**required** — non-empty mapping of axis -> list of "
                "values; `target`/`sampler`/`schedule`/`executor` (or "
                "their plural aliases) override those sections whole, any "
                "other dotted key (e.g. `budget.n_trials`) overrides one "
                "leaf; the cross product of all axes defines the cells",
        "cache": "shared disk-cache directory forced into **every** cell "
                 "(so cross-target cells reuse compiles); omit to inherit "
                 "the base experiment's cache section unchanged",
        "report_dir": "directory for the merged sweep report and the "
                      "per-cell reports (default `results`)",
        "workers": "worker-daemon addresses (`[\"host:port\", ...]`) to fan "
                   "independent cells across (see `python -m repro.worker`); "
                   "cells are resubmitted on worker failure and fall back "
                   "to local sequential execution when no worker is "
                   "reachable.  Omit (default) to run cells locally",
    }

    @classmethod
    def from_dict(cls, raw: Mapping[str, Any],
                  base_dir: Optional[str] = None) -> "SweepSpec":
        raw = _require_mapping(raw, "sweep")
        unknown = sorted(set(raw) - set(SWEEP_KEYS))
        if unknown:
            raise SweepError(
                f"unknown key(s) {unknown} in sweep; allowed keys: "
                f"{sorted(SWEEP_KEYS)}")

        base_raw = raw.get("base")
        if base_raw is None:
            raise SweepError(
                "missing 'base'; provide an inline experiment mapping or "
                "{file: experiment.yaml}")
        if isinstance(base_raw, Mapping) and set(base_raw) == {"file"}:
            path = str(base_raw["file"])
            if base_dir and not os.path.isabs(path):
                path = os.path.join(base_dir, path)
            if not os.path.exists(path):
                raise SweepError(f"base experiment file not found: {path!r}")
            with open(path) as f:
                base_raw = yaml.safe_load(f.read())
            base_dir = os.path.dirname(os.path.abspath(path))
        base_raw = _require_mapping(base_raw, "sweep.base")
        try:
            # validate once and keep the *resolved* form: search-space
            # file refs come back inlined and shorthands normalized, so
            # dotted-key overrides always land on mappings
            base = ExperimentSpec.from_dict(base_raw, base_dir=base_dir).to_dict()
        except ExperimentError as e:
            raise SweepError(f"sweep.base: {e}") from e

        axes_raw = raw.get("axes")
        if not isinstance(axes_raw, Mapping) or not axes_raw:
            raise SweepError(
                "axes must be a non-empty mapping of axis -> list of values "
                "(e.g. target: [host_cpu, edge_npu])")
        axes: Dict[str, List[Any]] = {}
        for key, values in axes_raw.items():
            norm = AXIS_ALIASES.get(str(key), str(key))
            head = norm.split(".", 1)[0]
            if head not in TOP_LEVEL_KEYS:
                raise SweepError(
                    f"axis {key!r} does not name an experiment key: "
                    f"{head!r} is not one of {sorted(TOP_LEVEL_KEYS)}")
            if head in ("name", "report_dir"):
                raise SweepError(
                    f"axis {key!r} is not sweepable: the sweep owns cell "
                    f"{head}s (they key resume detection and report paths)")
            if not isinstance(values, (list, tuple)) or not values:
                raise SweepError(
                    f"axis {key!r} must map to a non-empty list of values, "
                    f"got {values!r}")
            if norm in axes:
                raise SweepError(
                    f"axis {key!r} duplicates axis {norm!r} "
                    f"(plural aliases normalize: {AXIS_ALIASES})")
            axes[norm] = list(values)

        cache = raw.get("cache")
        if isinstance(cache, Mapping):
            unknown = sorted(set(cache) - {"dir"})
            if unknown:
                raise SweepError(
                    f"unknown key(s) {unknown} in sweep.cache; allowed: ['dir']")
            cache = cache.get("dir")
        if cache is True:  # same shorthand the experiment-level section takes
            from repro.evaluation.disk_cache import DEFAULT_DIR

            cache = DEFAULT_DIR
        elif cache is False:
            cache = None

        workers = raw.get("workers")
        if workers is not None:
            if (not isinstance(workers, (list, tuple)) or not workers
                    or not all(isinstance(w, str) for w in workers)):
                raise SweepError(
                    "sweep.workers must be a non-empty list of 'host:port' "
                    "strings")
            for w in workers:
                host, _, port = w.rpartition(":")
                if not host or not port.isdigit():
                    raise SweepError(
                        f"sweep.workers address {w!r} is not host:port")
            workers = [str(w) for w in workers]
        return cls(
            name=str(raw.get("name", "sweep")),
            base=base,
            axes=axes,
            cache=None if cache is None else str(cache),
            report_dir=str(raw.get("report_dir", "results")),
            workers=workers,
        )

    @classmethod
    def from_yaml(cls, path: str) -> "SweepSpec":
        with open(path) as f:
            raw = yaml.safe_load(f.read())
        return cls.from_dict(raw, base_dir=os.path.dirname(os.path.abspath(path)))

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "name": self.name,
            "base": copy.deepcopy(self.base),
            "axes": {k: copy.deepcopy(v) for k, v in self.axes.items()},
            "report_dir": self.report_dir,
        }
        if self.cache is not None:
            d["cache"] = self.cache
        if self.workers is not None:
            d["workers"] = list(self.workers)
        return d

    # -- expansion -------------------------------------------------------------

    @property
    def cells_dir(self) -> str:
        return os.path.join(self.report_dir, f"{self.name}.cells")

    def expand(self, overrides: Optional[Dict[str, Any]] = None) -> List[SweepCell]:
        """Cross product of the axes -> validated child specs, in a
        deterministic order (axes in declaration order, values in list
        order).  ``overrides`` are dotted-key constants applied to every
        cell AFTER its axis values — they win even over a whole-section
        axis (the CLI's ``--trials``/``--workers`` shrink knobs).  A
        child that fails validation raises a :class:`SweepError` naming
        the offending axis values."""
        keys = list(self.axes)
        cells: List[SweepCell] = []
        seen: Dict[str, Dict[str, str]] = {}
        for combo in itertools.product(*(self.axes[k] for k in keys)):
            doc = copy.deepcopy(self.base)
            labels = {k: _axis_label(v) for k, v in zip(keys, combo)}
            for key, value in zip(keys, combo):
                _set_dotted(doc, key, value)
            for key, value in (overrides or {}).items():
                _set_dotted(doc, key, value)
            cell_name = "--".join(
                [re.sub(r"[^A-Za-z0-9._-]+", "-", str(self.base.get("name", "experiment")))]
                + [f"{k}={labels[k]}" for k in keys])
            if cell_name in seen:
                raise SweepError(
                    f"cell name {cell_name!r} is ambiguous: axis values "
                    f"{seen[cell_name]} and {labels} produce the same label — "
                    f"give the colliding components distinguishing names")
            seen[cell_name] = labels
            doc["name"] = cell_name
            doc["report_dir"] = self.cells_dir
            if self.cache is not None:
                doc["cache"] = {"dir": self.cache}
            try:
                spec = ExperimentSpec.from_dict(doc)
            except ExplorerError as e:
                at = ", ".join(f"{k}={labels[k]}" for k in keys)
                raise SweepError(f"cell [{at}]: {e}") from e
            cells.append(SweepCell(name=cell_name, axes=labels,
                                   axis_values=dict(zip(keys, combo)), spec=spec))
        return cells


# ---------------------------------------------------------------------------
# report merging
# ---------------------------------------------------------------------------

def _better(a: float, b: float, direction: str) -> bool:
    return a < b if direction == "minimize" else a > b


def _criteria_directions(base: Dict[str, Any]) -> Dict[str, str]:
    return {c["estimator"]: c.get("direction", "minimize")
            for c in base.get("criteria", [])}


def _objective_names(base: Dict[str, Any]) -> List[str]:
    return [c["estimator"] for c in base.get("criteria", [])
            if c.get("kind", "objective") == "objective"]


def _dominates(a: List[float], b: List[float], signs: List[float]) -> bool:
    no_worse = all(sa * va <= sa * vb for sa, va, vb in zip(signs, a, b))
    better = any(sa * va < sa * vb for sa, va, vb in zip(signs, a, b))
    return no_worse and better


def _cell_axis(cell: Dict[str, Any], axis: str, fallback_key: str,
               base: Dict[str, Any]) -> str:
    """Axis label of a merged cell; cells not fanned over that axis all
    share the base spec's value (one-row / one-column matrix)."""
    label = cell["axes"].get(axis)
    if label is not None:
        return label
    node = base.get(fallback_key)
    return _axis_label(node if node is not None else "default")


@dataclasses.dataclass
class SweepReport:
    """Merged comparative view over every cell, JSON end to end."""

    sweep: str
    axes: Dict[str, List[str]]              # axis -> value labels, in order
    n_cells: int
    n_resumed: int
    cells: List[Dict[str, Any]]             # per-cell summary incl. best trial
    matrix: Dict[str, Dict[str, Dict[str, Optional[float]]]]
    pareto_union: List[Dict[str, Any]]      # cross-target non-dominated union
    target_rankings: Dict[str, List[Dict[str, Any]]]
    cache: Optional[Dict[str, Any]]
    wall_clock_s: float
    toolchain: Dict[str, str]
    spec: Dict[str, Any]                    # the sweep spec that produced this
    artifact: Optional[str] = None

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self.artifact = path
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")
        return path


def _summarize_cell(cell: SweepCell, report: Dict[str, Any],
                    resumed: bool) -> Dict[str, Any]:
    return {
        "name": cell.name,
        "axes": dict(cell.axes),
        "resumed": resumed,
        "best": report.get("best"),
        "criteria_values": report.get("criteria_values") or {},
        "pareto_front": report.get("pareto_front") or [],
        "n_trials": report.get("n_trials"),
        "states": report.get("states"),
        "wall_clock_s": report.get("wall_clock_s"),
        "cache": report.get("cache"),
        "target": report.get("target"),
        "artifact": report.get("artifact"),
    }


def merge_reports(spec: SweepSpec, summaries: List[Dict[str, Any]],
                  n_resumed: int, wall_clock_s: float) -> SweepReport:
    """Fold per-cell report dicts into the comparative views.  Pure and
    deterministic: same summaries in, same report out (asserted in
    ``tests/test_sweep.py``), so a resumed sweep merges identically to an
    uninterrupted one."""
    from repro.evaluation.disk_cache import toolchain_versions

    base = spec.base
    directions = _criteria_directions(base)
    objectives = _objective_names(base)
    signs = [1.0 if directions.get(n, "minimize") == "minimize" else -1.0
             for n in objectives]

    # -- per-criterion best-value matrix: target x sampler -------------------
    matrix: Dict[str, Dict[str, Dict[str, Optional[float]]]] = {}
    for crit, direction in directions.items():
        grid: Dict[str, Dict[str, Optional[float]]] = {}
        for cell in summaries:
            t = _cell_axis(cell, "target", "target", base)
            s = _cell_axis(cell, "sampler", "sampler", base)
            value = (cell["criteria_values"] or {}).get(crit)
            row = grid.setdefault(t, {})
            prev = row.get(s)
            if value is not None and (prev is None
                                      or _better(value, prev, direction)):
                row[s] = value
            elif s not in row:
                row[s] = value
        matrix[crit] = grid

    # -- cross-target Pareto union over the objective criteria ---------------
    points: List[Tuple[Dict[str, Any], List[float]]] = []
    for cell in summaries:
        for entry in cell["pareto_front"]:
            values = entry.get("objective_values")
            if values is None or len(values) != len(objectives):
                continue
            tagged = dict(entry)
            tagged["cell"] = cell["name"]
            tagged["target"] = _cell_axis(cell, "target", "target", base)
            tagged["sampler"] = _cell_axis(cell, "sampler", "sampler", base)
            points.append((tagged, [float(v) for v in values]))
    union = [entry for entry, vals in points
             if not any(_dominates(other, vals, signs) for _, other in points)]
    union.sort(key=lambda e: (e.get("objective_values") or [], e["cell"]))

    # -- which target wins under which criterion weighting -------------------
    rankings: Dict[str, List[Dict[str, Any]]] = {}
    profiles = [(crit, lambda c, crit=crit: (c["criteria_values"] or {}).get(crit),
                 directions[crit]) for crit in directions]
    if base.get("scalarize", True):
        # the declared weighting = the scalarized study score itself
        profiles.append(("declared_weights",
                         lambda c: (c["best"] or {}).get("values", [None])[0],
                         "minimize"))
    for profile, extract, direction in profiles:
        per_target: Dict[str, Dict[str, Any]] = {}
        for cell in summaries:
            t = _cell_axis(cell, "target", "target", base)
            value = extract(cell)
            if value is None:
                continue
            cur = per_target.get(t)
            if cur is None or _better(value, cur["value"], direction):
                per_target[t] = {"target": t, "value": float(value),
                                 "cell": cell["name"]}
        ranked = sorted(per_target.values(),
                        key=lambda r: (r["value"] if direction == "minimize"
                                       else -r["value"], r["target"]))
        rankings[profile] = ranked

    # -- aggregated cache / compaction hygiene --------------------------------
    counters = ("hits", "disk_hits", "misses",
                "compactions", "dropped_superseded", "dropped_lru")
    totals: Dict[str, Any] = dict.fromkeys(counters, 0)
    seen_any = False
    for cell in summaries:
        stats = cell.get("cache")
        if not isinstance(stats, dict):
            continue
        seen_any = True
        for k in counters:
            totals[k] += int(stats.get(k, 0))
    if seen_any:
        lookups = totals["hits"] + totals["disk_hits"] + totals["misses"]
        totals["hit_rate"] = ((totals["hits"] + totals["disk_hits"]) / lookups
                              if lookups else 0.0)

    return SweepReport(
        sweep=spec.name,
        axes={k: [_axis_label(v) for v in vs] for k, vs in spec.axes.items()},
        n_cells=len(summaries),
        n_resumed=n_resumed,
        cells=summaries,
        matrix=matrix,
        pareto_union=union,
        target_rankings=rankings,
        cache=totals if seen_any else None,
        wall_clock_s=wall_clock_s,
        toolchain=toolchain_versions(),
        spec=spec.to_dict(),
    )


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------

def _load_completed_cell(cell: SweepCell) -> Optional[Dict[str, Any]]:
    """A persisted report counts as this cell iff it embeds the identical
    spec (so editing the sweep re-runs affected cells) and already holds
    the full trial budget."""
    try:
        with open(cell.report_path) as f:
            persisted = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    if persisted.get("spec") != cell.spec.to_dict():
        return None
    n_trials = persisted.get("n_trials") or 0
    if n_trials < cell.spec.budget.n_trials:
        return None
    return persisted


def _run_cell(spec_dict: Dict[str, Any]) -> Dict[str, Any]:
    """Worker-side cell execution: rebuild the validated spec, run it,
    return the report as a plain dict (module-level, so it crosses the
    wire as a picklable ``("call", ...)`` task).  The *parent* persists
    the report — the worker's filesystem may not be the submitting
    host's."""
    from repro.explorer.explorer import Explorer

    spec = ExperimentSpec.from_dict(spec_dict)
    return Explorer.from_spec(spec).run(save_report=False).to_dict()


def _persist_cell_report(cell: SweepCell, report: Dict[str, Any]) -> None:
    """Write a remotely-computed cell report exactly where a local run
    would have (same path, same shape), so per-cell resume works
    identically whichever side executed the cell."""
    path = cell.report_path
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    report["artifact"] = path  # self-locate, like ExplorationReport.save
    with open(path, "w") as f:
        f.write(json.dumps(report, indent=2, sort_keys=True) + "\n")


def _dispatch_cells(addrs: List[str],
                    cells: List[SweepCell]) -> Dict[str, Dict[str, Any]]:
    """Fan independent cells across the worker pool; returns completed
    ``{cell name: report dict}``.  Cells whose workers die are
    resubmitted to siblings by the client; cells that still fail (or a
    pool with zero reachable workers) are simply *absent* from the
    result, and the caller runs them locally — the sweep always
    completes."""
    import pickle
    import queue as queue_module
    import warnings

    from repro.search.remote.client import RemoteClient

    client = RemoteClient(list(addrs))
    done: "queue_module.SimpleQueue" = queue_module.SimpleQueue()
    results: Dict[str, Dict[str, Any]] = {}
    try:
        if not client.connect():
            warnings.warn(
                f"no sweep workers reachable among {list(addrs)}; running "
                f"all cells locally", RuntimeWarning, stacklevel=2)
            return results
        for cell in cells:
            payload = pickle.dumps(
                ("call", (_run_cell, (cell.spec.to_dict(),), {})),
                protocol=pickle.HIGHEST_PROTOCOL)
            client.submit(cell.name, lambda payload=payload: payload,
                          lambda key, value, error, worker: done.put(
                              (key, value, error)))
        for _ in cells:
            name, value, error = done.get()
            if error is not None or not isinstance(value, dict):
                warnings.warn(
                    f"sweep cell {name!r} failed remotely "
                    f"({error!r}); re-running it locally",
                    RuntimeWarning, stacklevel=2)
                continue
            results[name] = value
    finally:
        client.close()
    return results


def run_sweep(spec: SweepSpec, resume: bool = True, save_report: bool = True,
              overrides: Optional[Dict[str, Any]] = None,
              workers: Optional[List[str]] = None) -> SweepReport:
    """Expand (applying any post-axis ``overrides``), run every cell
    through :class:`Explorer` (skipping cells a previous run already
    completed, when ``resume``), merge, and persist
    ``<report_dir>/<name>.sweep.json``.

    With ``workers`` (argument wins over ``spec.workers``), cells that
    are not resumed fan out across the worker-daemon pool as independent
    tasks: cells already carry resume fingerprints and share the disk
    cache, which is what makes them safely resubmittable on worker
    failure.  Completed-cell reports are persisted by the parent at the
    exact local paths, so a remote sweep resumes the same as a local
    one; cells the pool cannot complete fall back to local execution.
    Merged summaries stay in deterministic cell order regardless of
    remote completion order."""
    from repro.explorer.explorer import Explorer

    cells = spec.expand(overrides)
    pool = workers if workers is not None else spec.workers
    summaries: List[Dict[str, Any]] = []
    n_resumed = 0
    t0 = time.perf_counter()

    resumed: Dict[str, Dict[str, Any]] = {}
    pending: List[SweepCell] = []
    for cell in cells:
        persisted = _load_completed_cell(cell) if resume else None
        if persisted is not None:
            n_resumed += 1
            resumed[cell.name] = persisted
        else:
            pending.append(cell)

    remote: Dict[str, Dict[str, Any]] = {}
    if pool and pending:
        remote = _dispatch_cells(list(pool), pending)

    for cell in cells:
        if cell.name in resumed:
            summaries.append(_summarize_cell(cell, resumed[cell.name],
                                             resumed=True))
            continue
        report_dict = remote.get(cell.name)
        if report_dict is not None:
            _persist_cell_report(cell, report_dict)
        else:  # no pool, unreachable pool, or a cell the pool failed
            report_dict = Explorer.from_spec(cell.spec).run(
                save_report=True).to_dict()
        summaries.append(_summarize_cell(cell, report_dict, resumed=False))
    wall_clock = time.perf_counter() - t0

    merged = merge_reports(spec, summaries, n_resumed, wall_clock)
    if save_report:
        merged.save(os.path.join(spec.report_dir, f"{spec.name}.sweep.json"))
    return merged
