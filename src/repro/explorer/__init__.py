"""Unified Explorer facade: registries + declarative experiments + one
entry point from search space to deployment report.

Attribute access is lazy (PEP 562): the self-registering modules
(``repro.search.samplers`` etc.) import ``repro.explorer.registry`` at
class-definition time, so this package initializer must not eagerly pull
in :mod:`repro.explorer.explorer` (which imports them back).
"""
from __future__ import annotations

import importlib

_EXPORTS = {
    # registry layer
    "Registry": "repro.explorer.registry",
    "ExplorerError": "repro.explorer.registry",
    "UnknownComponentError": "repro.explorer.registry",
    "register": "repro.explorer.registry",
    "SAMPLERS": "repro.explorer.registry",
    "EXECUTORS": "repro.explorer.registry",
    "ESTIMATORS": "repro.explorer.registry",
    "PRUNERS": "repro.explorer.registry",
    "TARGETS": "repro.explorer.registry",
    # declarative spec layer
    "ExperimentSpec": "repro.explorer.experiment",
    "ExperimentError": "repro.explorer.experiment",
    "CriterionSpec": "repro.explorer.experiment",
    "SamplerSpec": "repro.explorer.experiment",
    "ExecutorSpec": "repro.explorer.experiment",
    "BudgetSpec": "repro.explorer.experiment",
    "CacheSpec": "repro.explorer.experiment",
    "PrunerSpec": "repro.explorer.experiment",
    # facade layer
    "Explorer": "repro.explorer.explorer",
    "ExplorationReport": "repro.explorer.explorer",
    "SpecObjective": "repro.explorer.explorer",
    # sweep layer
    "SweepSpec": "repro.explorer.sweep",
    "SweepCell": "repro.explorer.sweep",
    "SweepReport": "repro.explorer.sweep",
    "SweepError": "repro.explorer.sweep",
    "run_sweep": "repro.explorer.sweep",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    try:
        module = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module 'repro.explorer' has no attribute {name!r}") from None
    return getattr(importlib.import_module(module), name)


def __dir__():
    return __all__
