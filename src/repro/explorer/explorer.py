"""The :class:`Explorer` facade: one entry point from search space to
deployment report.

Composes exactly what the hand-wired examples build by hand —
``parse_search_space`` + ``ModelBuilder`` + estimators +
``CriteriaRunner`` + ``EvaluationCache`` + ``ParallelStudy`` + an
executor backend — from a declarative
:class:`~repro.explorer.experiment.ExperimentSpec`::

    from repro import Explorer

    report = Explorer.from_yaml("examples/experiments/quickstart.yaml").run()
    print(report.best)

The facade is sugar *over* the layered API, not a replacement: every
subsystem stays independently importable, and ``Explorer`` holds no
state the layers don't already expose (the composed ``Study`` is
available as ``.study`` after ``run()``).

Determinism contract: for a fixed sampler seed the facade reproduces the
hand-wired wiring trial-for-trial on every executor backend (the
objective, scalarization order, and sampler RNG streams are identical);
see ``tests/test_explorer.py``.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
import uuid
from typing import Any, Dict, List, Optional, Sequence

from repro.explorer.experiment import ExperimentError, ExperimentSpec
from repro.explorer.registry import TARGETS


def _canonical_spec_key(spec_dict: Dict[str, Any]) -> str:
    return json.dumps(spec_dict, sort_keys=True, separators=(",", ":"))


# Per-process lazy state keyed by (canonical spec, run token): the
# objective below holds only a JSON dict plus the token, so it pickles
# across the process boundary; each spawn worker re-imports this module
# and composes its own space/builder/runner, sharing compiled values via
# the spec's disk cache.  The token is fresh per Explorer.run(), so a
# second run of the same spec in one process rebuilds its cache/tuner
# instead of inheriting the previous run's cumulative counters (which
# would misreport e.g. a warm run's tune count as the cold run's).
_PROCESS_STATE: Dict[Any, Any] = {}


class SpecObjective:
    """Picklable study objective compiled from an :class:`ExperimentSpec`.

    Rebuilds the evaluation pipeline lazily once per process and per
    spec.  Each trial records the candidate's full architecture
    ``signature`` plus a ``worker`` attr (evaluating pid + cumulative
    cache counters) so the parent can aggregate cache behaviour across
    worker processes it cannot otherwise observe."""

    def __init__(self, spec_dict: Dict[str, Any], run_token: Optional[str] = None):
        self.spec_dict = spec_dict
        self.run_token = run_token
        self._key = (_canonical_spec_key(spec_dict), run_token)

    def _state(self):
        state = _PROCESS_STATE.get(self._key)
        if state is None:
            from repro.core.builder import ModelBuilder
            from repro.core.space import parse_search_space
            from repro.evaluation import (
                CascadeRunner,
                CriteriaRunner,
                EvaluationCache,
                FidelityStage,
                KeepRule,
                OptimizationCriteria,
            )

            spec = ExperimentSpec.from_dict(self.spec_dict)
            space = parse_search_space(dict(spec.search_space))
            builder = ModelBuilder(space.input_shape, space.output_dim)
            cache = EvaluationCache(disk=spec.cache.dir)
            target = TARGETS.get(spec.target)

            tuner = None
            kt = spec.kernel_tuning
            if kt is not None and kt.mode == "cached":
                from repro.hwgen.autotune import ScheduleTuner

                # the tuner shares the experiment cache, so tuned
                # schedules persist in the same flock-safe disk store as
                # compiled values: warm restart = zero re-tuning
                tuner = ScheduleTuner(target, cache=cache,
                                      budget=kt.budget, overrides=kt.kernels)

            def build_criterion(c):
                return OptimizationCriteria(
                    c.build_estimator(target=target, cache=cache, tuner=tuner,
                                      serving=spec.serving),
                    kind=c.kind, direction=c.direction,
                    weight=c.weight, limit=c.limit,
                )

            criteria = [build_criterion(c) for c in spec.criteria]
            if spec.fidelity is not None:
                # screening stages from the fidelity section, the
                # top-level criteria as the implicit final stage
                stages = [
                    FidelityStage(s.name, [build_criterion(c) for c in s.criteria],
                                  keep=KeepRule(**s.keep.to_dict()))
                    for s in spec.fidelity.stages
                ]
                stages.append(FidelityStage("final", criteria))
                runner = CascadeRunner(stages, cache=cache)
            else:
                runner = CriteriaRunner(criteria, cache=cache)
            # a prior run's state for the same spec is dead weight now —
            # its counters must not leak into this run's report
            for stale in [k for k in _PROCESS_STATE
                          if k[0] == self._key[0] and k != self._key]:
                del _PROCESS_STATE[stale]
            state = _PROCESS_STATE[self._key] = (
                spec, space, builder, runner, cache, tuner)
        return state

    @property
    def cache(self):
        return self._state()[4]

    @property
    def tuner(self):
        return self._state()[5]

    def build_model(self, trial):
        """Rebuild the (already sampled) model for ``trial`` — used by
        :meth:`Explorer.best_model` to hand back the winning network."""
        from repro.core.translate import sample_architecture

        _, space, builder, _, _, _ = self._state()
        return builder.build(sample_architecture(space, trial))

    def screen_cohort(self, trials):
        """Fidelity-cascade screen hook for ``ParallelStudy.optimize``:
        sample each cohort trial's architecture *in the parent* (so the
        distribution registry is complete before any worker runs), build
        the uncompiled models, and let the cascade's screening stages
        decide who gets promoted to the executor."""
        from repro.core.translate import sample_architecture
        from repro.search.parallel import ScreenDecision

        _, space, builder, runner, _, _ = self._state()
        models = []
        for trial in trials:
            arch = sample_architecture(space, trial)
            trial.set_user_attr("signature", arch.signature())
            models.append(builder.build(arch))
        result = runner.screen_cohort(models, trials=trials)
        return ScreenDecision(
            promoted=[trials[i] for i in result.promoted],
            screened=[(trials[i], stage) for i, stage in result.screened.items()],
            infeasible=[(trials[i], stage, exc)
                        for i, (stage, exc) in result.infeasible.items()],
        )

    def _suggest_schedules(self, spec, model, trial):
        """``kernel_tuning.mode: search``: expose each discovered kernel's
        schedule fields as categorical trial parameters, so the sampler
        co-optimizes architecture × schedule.  Spec-pinned kernels pass
        through fixed — they are constraints, not search dimensions."""
        import jax
        import jax.numpy as jnp

        from repro.hwgen.autotune import discover_kernel_calls
        from repro.kernels.schedule import KERNEL_FIELDS, SEARCH_CHOICES

        kt = spec.kernel_tuning
        l, c = model.input_shape[-1], model.input_shape[0]
        x = jax.ShapeDtypeStruct((1, l, c), jnp.float32)
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        calls = discover_kernel_calls(model.apply, (params, x))
        schedules: Dict[str, Dict[str, Any]] = {}
        for entry in calls.values():
            kernel = entry["kernel"]
            if kernel in schedules:
                continue
            if kernel in kt.kernels:
                schedules[kernel] = dict(kt.kernels[kernel])
                continue
            schedules[kernel] = {
                field: trial.suggest_categorical(
                    f"schedule:{kernel}:{field}", list(SEARCH_CHOICES[field]))
                for field in KERNEL_FIELDS[kernel]
            }
        return schedules or None

    def __call__(self, trial):
        from repro.core.translate import sample_architecture
        from repro.hwgen.generator import generate_call_count

        spec, space, builder, runner, cache, tuner = self._state()
        arch = sample_architecture(space, trial)
        model = builder.build(arch)
        trial.set_user_attr("signature", arch.signature())
        context: Dict[str, Any] = {"trial": trial}
        if spec.kernel_tuning is not None and spec.kernel_tuning.mode == "search":
            schedules = self._suggest_schedules(spec, model, trial)
            if schedules is not None:
                context["schedules"] = schedules
        if spec.scalarize:
            value = runner.evaluate(model, context=context, trial=trial)
        else:
            value = runner.evaluate_multi(model, context=context, trial=trial)
        # generates: cumulative XLA generator invocations in this process —
        # the report's funnel aggregates it per pid to count how many
        # candidates actually paid a compile (screened-out ones never do)
        worker = {"pid": os.getpid(), "generates": generate_call_count(),
                  **cache.stats.as_dict()}
        if cache.disk is not None:
            worker.update(cache.disk.stats())
        if tuner is not None:
            worker.update({f"tuner_{k}": v for k, v in tuner.stats().items()})
        trial.set_user_attr("worker", worker)
        return value


def _aggregate_cache_stats(trials) -> Optional[Dict[str, Any]]:
    """Sum each worker process's final cumulative cache counters (keyed
    by pid; counters are monotone, so the elementwise max per pid is that
    worker's total — same discipline as benchmarks/bench_nas.py).  The
    disk tier's compaction counters ride along when a disk store is
    configured."""
    per_pid: Dict[int, Dict[str, Any]] = {}
    counters = ("hits", "disk_hits", "misses",
                "compactions", "dropped_superseded", "dropped_lru")
    for t in trials:
        w = t.user_attrs.get("worker")
        if not isinstance(w, dict) or "pid" not in w:
            continue
        cur = per_pid.setdefault(w["pid"], dict.fromkeys(counters, 0))
        for k in counters:
            cur[k] = max(cur[k], w.get(k, 0))
    if not per_pid:
        return None
    totals: Dict[str, Any] = {k: sum(c[k] for c in per_pid.values()) for k in counters}
    lookups = totals["hits"] + totals["disk_hits"] + totals["misses"]
    totals["hit_rate"] = (totals["hits"] + totals["disk_hits"]) / lookups if lookups else 0.0
    totals["n_workers_seen"] = len(per_pid)
    return totals


def _spearman(xs: Sequence[float], ys: Sequence[float]) -> Optional[float]:
    """Tie-aware (average-rank) Spearman rank correlation, pure python —
    the report layer must not grow a scipy dependency.  Returns ``None``
    when either side is constant (correlation undefined)."""

    def ranks(vs: Sequence[float]) -> List[float]:
        order = sorted(range(len(vs)), key=lambda i: vs[i])
        out = [0.0] * len(vs)
        i = 0
        while i < len(order):
            j = i
            while j + 1 < len(order) and vs[order[j + 1]] == vs[order[i]]:
                j += 1
            avg = (i + j) / 2.0 + 1.0
            for k in range(i, j + 1):
                out[order[k]] = avg
            i = j + 1
        return out

    rx, ry = ranks(list(xs)), ranks(list(ys))
    n = len(rx)
    mx, my = sum(rx) / n, sum(ry) / n
    cov = sum((a - mx) * (b - my) for a, b in zip(rx, ry))
    vx = sum((a - mx) ** 2 for a in rx)
    vy = sum((b - my) ** 2 for b in ry)
    if vx <= 0.0 or vy <= 0.0:
        return None
    return cov / math.sqrt(vx * vy)


def _dominates(a: List[float], b: List[float], signs: List[float]) -> bool:
    """True if a is no worse than b on every objective and better on one
    (after sign-normalizing so every objective minimizes)."""
    no_worse = all(sa * va <= sa * vb for sa, va, vb in zip(signs, a, b))
    better = any(sa * va < sa * vb for sa, va, vb in zip(signs, a, b))
    return no_worse and better


def _trial_summary(trial, extra_values: Optional[List[float]] = None) -> Dict[str, Any]:
    return {
        "number": trial.number,
        "values": list(trial.values) if trial.values else None,
        "objective_values": extra_values,
        "params": dict(trial.params),
        "signature": trial.user_attrs.get("signature"),
    }


@dataclasses.dataclass
class ExplorationReport:
    """What an exploration produced, JSON-serializable end to end."""

    experiment: str
    sampler: str
    backend: str
    n_workers: int
    schedule: Dict[str, Any]
    directions: List[str]
    n_trials: int
    states: Dict[str, int]
    best: Optional[Dict[str, Any]]
    criteria_values: Dict[str, float]
    pareto_front: List[Dict[str, Any]]
    cache: Optional[Dict[str, Any]]
    wall_clock_s: float
    toolchain: Dict[str, str]
    # fidelity-cascade funnel (asked/screened/infeasible/promoted/compiled
    # counts, per-stage cut counts, proxy-vs-final Spearman); None when
    # the experiment has no fidelity section
    fidelity: Optional[Dict[str, Any]] = None
    # kernel-schedule tuning summary (mode, schedules chosen for the best
    # trial, tune/cache-hit counters, tune wall-clock); None when the
    # experiment has no kernel_tuning section or mode is off
    kernel_tuning: Optional[Dict[str, Any]] = None
    # full resolved TargetSpec (chip peak FLOPs/bandwidth, mesh, ...):
    # registered constants can be edited later, so the numbers that
    # actually produced this report must travel with it or cross-target
    # comparisons stop being interpretable
    target: Optional[Dict[str, Any]] = None
    # content-addressed executable store summary (directory + entry
    # count) when the experiment had a disk cache: everything a server
    # needs to warm-boot --from-report with zero XLA compiles
    artifacts: Optional[Dict[str, Any]] = None
    # the complete experiment spec, so the report self-describes and a
    # sweep can detect that a persisted cell still matches its spec
    spec: Optional[Dict[str, Any]] = None
    artifact: Optional[str] = None

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self.artifact = path  # before serializing, so the JSON self-locates
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")
        return path


class Explorer:
    """Single front door: ``Explorer.from_yaml(path).run()``."""

    def __init__(self, spec: ExperimentSpec):
        self.spec = spec
        self.study = None  # composed ParallelStudy, available after run()
        self._objective: Optional[SpecObjective] = None

    # -- constructors ----------------------------------------------------------

    @classmethod
    def from_yaml(cls, path: str) -> "Explorer":
        return cls(ExperimentSpec.from_yaml(path))

    @classmethod
    def from_spec(cls, spec: ExperimentSpec) -> "Explorer":
        if not isinstance(spec, ExperimentSpec):
            raise ExperimentError(
                f"from_spec expects an ExperimentSpec, got {type(spec).__name__} "
                f"(use from_dict for raw mappings)"
            )
        return cls(spec)

    @classmethod
    def from_dict(cls, raw: Dict[str, Any]) -> "Explorer":
        return cls(ExperimentSpec.from_dict(raw))

    # -- run -------------------------------------------------------------------

    def run(self, save_report: bool = True) -> ExplorationReport:
        """Execute the experiment and return (and, by default, persist
        under ``<report_dir>/``) an :class:`ExplorationReport`."""
        from repro.search.parallel import ParallelStudy

        spec = self.spec
        study = ParallelStudy(
            name=spec.name,
            sampler=spec.sampler.build(),
            pruner=spec.pruner.build() if spec.pruner else None,
            directions=spec.directions,
            storage=spec.persistence,
            n_workers=spec.executor.n_workers,
            backend=spec.executor.build(),
            schedule=spec.schedule.mode,
            tell_order=spec.schedule.tell_order,
            window=spec.schedule.window,
        )
        self.study = study
        self._objective = objective = SpecObjective(
            spec.to_dict(), run_token=uuid.uuid4().hex)

        # a faults: section arms the chaos plan for exactly this run —
        # installed in-process for serial/threaded execution, exported
        # through REPRO_FAULTS so spawned process workers inherit the
        # same seeded schedule; both undone afterwards
        restore_env = None
        if spec.faults is not None:
            from repro import faults as _faults

            restore_env = os.environ.get("REPRO_FAULTS")
            plan = _faults.install(spec.faults.plan())
            os.environ["REPRO_FAULTS"] = plan.to_string()

        # persistence resume: already-stored trials count against the budget
        remaining = spec.budget.n_trials - len(study.trials)
        t0 = time.perf_counter()
        try:
            if remaining > 0:
                # budget.timeout_s is enforced inside the scheduler —
                # per-submission under the sliding window, per-batch under the
                # batch scheduler — so a timeout can't overshoot by a whole
                # batch of slow trials
                study.optimize(objective, remaining,
                               n_workers=spec.executor.n_workers,
                               timeout_s=spec.budget.timeout_s,
                               screen=(objective.screen_cohort
                                       if spec.fidelity is not None else None),
                               cohort=(spec.fidelity.generation
                                       if spec.fidelity is not None else None))
        finally:
            if spec.faults is not None:
                from repro import faults as _faults

                _faults.uninstall()
                if restore_env is None:
                    os.environ.pop("REPRO_FAULTS", None)
                else:
                    os.environ["REPRO_FAULTS"] = restore_env
        wall_clock = time.perf_counter() - t0

        report = self._build_report(wall_clock)
        if save_report:
            report.save(os.path.join(spec.report_dir, f"{spec.name}.report.json"))
        return report

    # -- post-run accessors ----------------------------------------------------

    def best_model(self):
        """Rebuild the winning architecture as an executable BuiltModel."""
        if self.study is None or self._objective is None:
            raise ExperimentError("best_model() requires a completed run()")
        best = self.study.best_trial
        if best is None:
            raise ExperimentError("no completed trials — nothing to rebuild")
        return self._objective.build_model(best)

    # -- report assembly -------------------------------------------------------

    def _pareto(self) -> List[Dict[str, Any]]:
        """Non-dominated completed trials over the objective criteria.
        In multi-objective mode the study's own Pareto set is used; in
        scalarized mode the front is recovered from the per-criterion
        values every trial records as user attrs (so even a weighted-sum
        search reports the trade-off surface it explored)."""
        spec, study = self.spec, self.study
        objectives = spec.objective_criteria
        if not spec.scalarize:
            return [_trial_summary(t, list(t.values)) for t in study.best_trials]
        if len(objectives) < 2:
            return []
        names = [c.estimator for c in objectives]
        signs = [1.0 if c.direction == "minimize" else -1.0 for c in objectives]
        # estimator user attrs are recorded under the *estimator instance*
        # name, which matches the registry key for the built-ins
        pts = [
            (t, [float(t.user_attrs[n]) for n in names])
            for t in study.completed_trials
            if all(n in t.user_attrs for n in names)
        ]
        front = [
            (t, vals) for t, vals in pts
            if not any(_dominates(other, vals, signs) for _, other in pts)
        ]
        return [_trial_summary(t, vals) for t, vals in front]

    def _fidelity_report(self) -> Optional[Dict[str, Any]]:
        """Per-stage funnel + proxy-vs-final rank correlation.

        ``compiled`` is how many XLA generator invocations the run paid
        (per-pid max of the cumulative ``generates`` counter, summed
        across workers — same discipline as the cache aggregation): with
        a warm cache it is *below* the promoted count, and screened-out
        candidates never contribute.  ``spearman`` correlates each
        screening stage's scalarized score with the final scalarized
        value over trials that completed the full evaluation — the
        proxy-quality number the cascade's keep rules implicitly bet on."""
        from repro.evaluation.cascade import STAGE_SCORE_ATTR
        from repro.search.trial import TrialState

        spec, study = self.spec, self.study
        if spec.fidelity is None:
            return None
        screened_by_stage: Dict[str, int] = {}
        infeasible_by_stage: Dict[str, int] = {}
        promoted = 0
        for t in study.trials:
            stage = t.user_attrs.get("fidelity_stage")
            if stage is None:
                continue
            if stage == "promoted":
                promoted += 1
            elif t.state == TrialState.SCREENED:
                screened_by_stage[stage] = screened_by_stage.get(stage, 0) + 1
            elif t.state == TrialState.INFEASIBLE:
                infeasible_by_stage[stage] = infeasible_by_stage.get(stage, 0) + 1
        per_pid: Dict[int, int] = {}
        for t in study.trials:
            w = t.user_attrs.get("worker")
            if isinstance(w, dict) and "pid" in w:
                per_pid[w["pid"]] = max(per_pid.get(w["pid"], 0),
                                        int(w.get("generates", 0)))
        spearman: Dict[str, Optional[float]] = {}
        finals = [t for t in study.completed_trials if t.values]
        for s in spec.fidelity.stages:
            key = STAGE_SCORE_ATTR + s.name
            pairs = [(float(t.user_attrs[key]), float(t.values[0]))
                     for t in finals if key in t.user_attrs]
            spearman[s.name] = (_spearman([p[0] for p in pairs],
                                          [p[1] for p in pairs])
                                if len(pairs) >= 3 else None)
        return {
            "generation": spec.fidelity.generation,
            "funnel": {
                "asked": len(study.trials),
                "screened": sum(screened_by_stage.values()),
                "infeasible": sum(infeasible_by_stage.values()),
                "promoted": promoted,
                "compiled": sum(per_pid.values()),
            },
            "screened_by_stage": screened_by_stage,
            "infeasible_by_stage": infeasible_by_stage,
            "spearman": spearman,
        }

    def _kernel_tuning_report(self) -> Optional[Dict[str, Any]]:
        """Schedules chosen (best trial's per-kernel plan), sweep effort
        (tunes / cache hits / tune wall-clock, per-pid max of each
        worker's cumulative counters — same discipline as the cache
        aggregation), and which searched schedule params won."""
        spec, study = self.spec, self.study
        kt = spec.kernel_tuning
        if kt is None or kt.mode == "off":
            return None
        per_pid: Dict[int, Dict[str, Any]] = {}
        counters = ("tuner_tunes", "tuner_cache_hits", "tuner_tune_time_s")
        for t in study.trials:
            w = t.user_attrs.get("worker")
            if not isinstance(w, dict) or "pid" not in w:
                continue
            cur = per_pid.setdefault(w["pid"], dict.fromkeys(counters, 0))
            for k in counters:
                cur[k] = max(cur[k], w.get(k, 0))
        best = study.best_trial
        schedules = None
        if best is not None:
            schedules = best.user_attrs.get("kernel_schedules")
            if schedules is None and kt.mode == "search":
                # reconstruct from the winning trial's schedule params
                schedules = {}
                for name, value in best.params.items():
                    if not name.startswith("schedule:"):
                        continue
                    _, kernel, field = name.split(":", 2)
                    schedules.setdefault(kernel, {})[field] = value
                schedules = schedules or None
        return {
            "mode": kt.mode,
            "budget": kt.budget,
            "overrides": {k: dict(v) for k, v in kt.kernels.items()} or None,
            "schedules": schedules,
            "tunes": sum(c["tuner_tunes"] for c in per_pid.values()),
            "cache_hits": sum(c["tuner_cache_hits"] for c in per_pid.values()),
            "tune_time_s": sum(c["tuner_tune_time_s"] for c in per_pid.values()),
        }

    def _artifacts_report(self) -> Optional[Dict[str, Any]]:
        """Executable-store summary: where the compiled programs live and
        how many the exploration persisted (what serve --from-report
        warm-loads).  None without a disk cache tier."""
        from repro.evaluation.artifact_store import ArtifactStore, store_enabled

        if self.spec.cache.dir is None or not store_enabled():
            return None
        store = ArtifactStore(self.spec.cache.dir)
        return {"dir": store.path, "entries": len(store)}

    def _build_report(self, wall_clock: float) -> ExplorationReport:
        from repro.evaluation.disk_cache import toolchain_versions

        spec, study = self.spec, self.study
        states: Dict[str, int] = {}
        for t in study.trials:
            states[t.state.value] = states.get(t.state.value, 0) + 1
        best = study.best_trial
        criterion_names = [c.estimator for c in spec.criteria]
        criteria_values = {}
        if best is not None:
            criteria_values = {
                n: float(best.user_attrs[n])
                for n in criterion_names if n in best.user_attrs
            }
        return ExplorationReport(
            experiment=spec.name,
            sampler=spec.sampler.name,
            backend=spec.executor.backend,
            n_workers=spec.executor.n_workers,
            schedule=spec.schedule.to_dict(),
            directions=list(spec.directions),
            n_trials=len(study.trials),
            states=states,
            best=_trial_summary(best) if best is not None else None,
            criteria_values=criteria_values,
            pareto_front=self._pareto(),
            cache=_aggregate_cache_stats(study.trials),
            fidelity=self._fidelity_report(),
            kernel_tuning=self._kernel_tuning_report(),
            artifacts=self._artifacts_report(),
            wall_clock_s=wall_clock,
            toolchain=toolchain_versions(),
            target=TARGETS.get(spec.target).to_dict(),
            spec=spec.to_dict(),
        )
